"""L2 model: shapes, flatten invariants, schedule, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import (
    MODEL_PRESETS, TRAIN_PRESETS, model_config, train_config,
)

jax.config.update("jax_platform_name", "cpu")

CFG = model_config("nano")
TC = train_config("nano")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def batch(seed=0, b=None, s=None):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    b = b or TC.batch_size
    s = s or CFG.seq_len
    tok = jax.random.randint(k1, (b, s), 0, CFG.vocab_size)
    tgt = jax.random.randint(k2, (b, s), 0, CFG.vocab_size)
    return tok, tgt


class TestFlatten:
    def test_roundtrip(self, params):
        leaves = M.flatten(params)
        rebuilt = M.unflatten(params, leaves)
        for (n1, a), (n2, b) in zip(
            M.flatten_spec(params), M.flatten_spec(rebuilt)
        ):
            assert n1 == n2
            np.testing.assert_array_equal(a, b)

    def test_order_is_deterministic(self, params):
        assert M.leaf_names(params) == M.leaf_names(params)

    def test_names_are_canonical(self, params):
        names = M.leaf_names(params)
        assert "embed.w" in names
        assert "blocks.0.attn.wq" in names
        assert f"blocks.{CFG.n_layers - 1}.mlp.w2" in names
        assert len(names) == len(set(names)), "duplicate leaf names"

    def test_extra_leaves_rejected(self, params):
        leaves = M.flatten(params)
        with pytest.raises(ValueError):
            M.unflatten(params, leaves + [leaves[0]])


class TestParamCount:
    @pytest.mark.parametrize("name", sorted(MODEL_PRESETS))
    def test_param_count_formula(self, name):
        """ModelConfig.param_count must equal the actual init tree size."""
        cfg = MODEL_PRESETS[name]
        if cfg.param_count() > 5_000_000:
            shapes = jax.eval_shape(lambda: M.init_params(cfg))
            n = sum(np.prod(l.shape) for _, l in M.flatten_spec(shapes))
        else:
            n = sum(l.size for _, l in M.flatten_spec(M.init_params(cfg)))
        assert n == cfg.param_count()

    def test_paper_sizes_are_plausible(self):
        """Table 1 presets land near their nominal sizes."""
        assert 40e6 < model_config("60m").param_count() < 90e6
        assert 100e6 < model_config("150m").param_count() < 200e6
        assert 280e6 < model_config("400m").param_count() < 520e6


class TestForward:
    def test_logit_shape(self, params):
        tok, _ = batch()
        logits = M.forward(params, tok, CFG, __import__(
            "compile.kernels", fromlist=["select"]).select("ref"))
        assert logits.shape == (TC.batch_size, CFG.seq_len, CFG.vocab_size)

    def test_causality(self, params):
        """Changing future tokens must not change past logits."""
        from compile import kernels
        kern = kernels.select("ref")
        tok, _ = batch()
        cut = CFG.seq_len // 2
        tok2 = tok.at[:, cut:].set((tok[:, cut:] + 1) % CFG.vocab_size)
        l1 = M.forward(params, tok, CFG, kern)
        l2 = M.forward(params, tok2, CFG, kern)
        np.testing.assert_allclose(l1[:, :cut], l2[:, :cut], atol=1e-4)

    def test_initial_loss_near_log_vocab(self, params):
        """Untrained model ≈ uniform predictor ⇒ loss ≈ log V."""
        from compile import kernels
        kern = kernels.select("ref")
        tok, tgt = batch()
        loss = M.loss_fn(params, tok, tgt, CFG, kern)
        assert abs(float(loss) - np.log(CFG.vocab_size)) < 0.5


class TestSchedule:
    def test_warmup_starts_at_zero(self):
        assert float(M.lr_schedule(jnp.asarray(0.0), TC)) == 0.0

    def test_peak_after_warmup(self):
        lr = float(M.lr_schedule(jnp.asarray(float(TC.warmup_steps)), TC))
        assert abs(lr - TC.peak_lr) / TC.peak_lr < 1e-5

    def test_decays_to_ten_percent(self):
        lr = float(M.lr_schedule(jnp.asarray(float(TC.total_steps)), TC))
        assert abs(lr - 0.1 * TC.peak_lr) / TC.peak_lr < 1e-5

    def test_monotone_decay_after_peak(self):
        steps = jnp.linspace(TC.warmup_steps, TC.total_steps, 50)
        lrs = [float(M.lr_schedule(s, TC)) for s in steps]
        assert all(a >= b - 1e-9 for a, b in zip(lrs, lrs[1:]))


class TestTrainStep:
    def test_loss_decreases(self, params):
        step_fn = jax.jit(M.make_train_step(CFG, TC))
        m = M.zeros_like_tree(params)
        v = M.zeros_like_tree(params)
        tok, tgt = batch()
        p = params
        first = None
        for i in range(30):
            p, m, v, loss = step_fn(p, m, v, float(i), tok, tgt)
            if first is None:
                first = float(loss)
        assert float(loss) < first - 0.5, (first, float(loss))

    def test_grad_step_plus_apply_equals_train_step(self, params):
        """grad_step → apply_update must be bitwise-equivalent to train_step."""
        m = M.zeros_like_tree(params)
        v = M.zeros_like_tree(params)
        tok, tgt = batch(3)
        fused = jax.jit(M.make_train_step(CFG, TC))
        gstep = jax.jit(M.make_grad_step(CFG, TC))
        apply = jax.jit(M.make_apply_update(CFG, TC))
        p1, m1, v1, loss1 = fused(params, m, v, 5.0, tok, tgt)
        grads, loss2 = gstep(params, tok, tgt)
        p2, m2, v2 = apply(params, m, v, grads, 5.0)
        np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-6)
        for a, b in zip(M.flatten(p1), M.flatten(p2)):
            np.testing.assert_allclose(a, b, atol=1e-7)
        for a, b in zip(M.flatten(m1), M.flatten(m2)):
            np.testing.assert_allclose(a, b, atol=1e-7)

    def test_train_chunk_equals_stepwise(self, params):
        """lax.scan chunk of C steps ≡ C sequential train_steps."""
        import jax.numpy as jnp
        c = 3
        m = M.zeros_like_tree(params)
        v = M.zeros_like_tree(params)
        k1, k2 = jax.random.split(jax.random.PRNGKey(5))
        toks = jax.random.randint(
            k1, (c, TC.batch_size, CFG.seq_len), 0, CFG.vocab_size
        )
        tgts = jax.random.randint(
            k2, (c, TC.batch_size, CFG.seq_len), 0, CFG.vocab_size
        )
        chunk = jax.jit(M.make_train_chunk(CFG, TC, "ref", c))
        pc, mc, vc, losses = chunk(params, m, v, 2.0, toks, tgts)
        step = jax.jit(M.make_train_step(CFG, TC))
        ps, ms, vs = params, m, v
        manual = []
        for i in range(c):
            ps, ms, vs, loss = step(ps, ms, vs, 2.0 + i, toks[i], tgts[i])
            manual.append(float(loss))
        np.testing.assert_allclose(losses, manual, atol=1e-5)
        for a, b in zip(M.flatten(pc), M.flatten(ps)):
            np.testing.assert_allclose(a, b, atol=1e-5)

    def test_eval_step_counts_tokens(self, params):
        eval_fn = jax.jit(M.make_eval_step(CFG))
        tok, tgt = batch(1)
        s, n = eval_fn(params, tok, tgt)
        assert float(n) == TC.batch_size * CFG.seq_len
        assert float(s) / float(n) == pytest.approx(
            float(M.loss_fn(
                params, tok, tgt, CFG,
                __import__("compile.kernels", fromlist=["select"]).select("ref"),
            )),
            rel=1e-5,
        )


class TestOuterStep:
    def test_matches_manual_nesterov(self, params):
        outer = M.make_outer_step("ref")
        delta = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.01, params)
        mom = M.zeros_like_tree(params)
        p2, m2 = outer(params, delta, mom, 0.7, 0.9)
        for a, b, d in zip(M.flatten(p2), M.flatten(params), M.flatten(delta)):
            # mom'=Δ; θ' = θ - 0.7(Δ + 0.9Δ) = θ - 1.33Δ
            np.testing.assert_allclose(a, b - 0.7 * 1.9 * d, atol=1e-6)

    def test_pallas_ref_agree(self, params):
        k = jax.random.PRNGKey(9)
        delta = jax.tree_util.tree_map(
            lambda p: jax.random.normal(k, p.shape) * 0.01, params
        )
        mom = jax.tree_util.tree_map(
            lambda p: jax.random.normal(k, p.shape) * 0.1, params
        )
        p_r, m_r = M.make_outer_step("ref")(params, delta, mom, 0.7, 0.9)
        p_p, m_p = M.make_outer_step("pallas")(params, delta, mom, 0.7, 0.9)
        for a, b in zip(M.flatten(p_r), M.flatten(p_p)):
            np.testing.assert_allclose(a, b, atol=1e-5)


class TestKernelParity:
    """The pallas-built model must match the ref-built model numerically."""

    def test_forward_parity(self, params):
        from compile import kernels
        tok, _ = batch(7)
        l_ref = M.forward(params, tok, CFG, kernels.select("ref"))
        l_pal = M.forward(params, tok, CFG, kernels.select("pallas"))
        np.testing.assert_allclose(l_ref, l_pal, atol=1e-3)

    def test_train_step_parity(self, params):
        m = M.zeros_like_tree(params)
        v = M.zeros_like_tree(params)
        tok, tgt = batch(8)
        f_ref = M.make_train_step(CFG, TC, "ref")
        f_pal = M.make_train_step(CFG, TC, "pallas")
        p1, m1, v1, l1 = f_ref(params, m, v, 2.0, tok, tgt)
        p2, m2, v2, l2 = f_pal(params, m, v, 2.0, tok, tgt)
        np.testing.assert_allclose(float(l1), float(l2), atol=1e-4)
        for a, b in zip(M.flatten(p1), M.flatten(p2)):
            np.testing.assert_allclose(a, b, atol=1e-4)
