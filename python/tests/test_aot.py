"""AOT pipeline: manifest structure, HLO validity, input/output ordering."""

import json
import os

import jax
import pytest

from compile import aot
from compile import model as M
from compile.configs import model_config, train_config

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build("nano", "ref", out)
    with open(os.path.join(out, "nano.manifest.json")) as f:
        return out, json.load(f)


ALL_ARTIFACTS = [
    "train_step", "train_chunk_5", "train_chunk_25", "eval_step",
    "outer_step", "grad_step", "apply_update", "fwd_logits", "init_params",
]


class TestManifest:
    def test_all_artifacts_present(self, built):
        _, man = built
        assert sorted(man["artifacts"]) == sorted(ALL_ARTIFACTS)

    def test_files_exist_and_are_hlo(self, built):
        out, man = built
        for art in man["artifacts"].values():
            path = os.path.join(out, art["file"])
            assert os.path.exists(path)
            head = open(path).read(200)
            assert "HloModule" in head

    def test_config_echo(self, built):
        _, man = built
        cfg = model_config("nano")
        assert man["config"]["param_count"] == cfg.param_count()
        assert man["config"]["vocab_size"] == cfg.vocab_size
        assert man["config"]["seq_len"] == cfg.seq_len

    def test_param_list_matches_model(self, built):
        _, man = built
        params = jax.eval_shape(lambda: M.init_params(model_config("nano")))
        want = [
            {"name": n, "shape": list(l.shape), "dtype": "f32"}
            for n, l in M.flatten_spec(params)
        ]
        assert man["params"] == want

    def test_train_step_io_layout(self, built):
        """inputs = params, m, v, step, tokens, targets; outputs mirror."""
        _, man = built
        art = man["artifacts"]["train_step"]
        n = len(man["params"])
        ins = art["inputs"]
        assert len(ins) == 3 * n + 3
        assert [i["role"] for i in ins[:n]] == ["param"] * n
        assert [i["role"] for i in ins[n:2 * n]] == ["opt_m"] * n
        assert [i["role"] for i in ins[2 * n:3 * n]] == ["opt_v"] * n
        assert [i["role"] for i in ins[3 * n:]] == [
            "step", "batch_tokens", "batch_targets",
        ]
        outs = art["outputs"]
        assert len(outs) == 3 * n + 1
        assert outs[-1]["role"] == "loss"

    def test_hlo_parameter_count_matches_manifest(self, built):
        """The HLO entry computation must declare exactly the manifest inputs."""
        out, man = built
        for key, art in man["artifacts"].items():
            text = open(os.path.join(out, art["file"])).read()
            entry = text.split("ENTRY")[1]
            body = entry.split("\n")
            declared = sum(
                1 for line in body if " parameter(" in line
            )
            assert declared == len(art["inputs"]), key

    def test_sha256_matches_file(self, built):
        import hashlib
        out, man = built
        for art in man["artifacts"].values():
            digest = hashlib.sha256(
                open(os.path.join(out, art["file"]), "rb").read()
            ).hexdigest()
            assert digest == art["sha256"]


class TestHloParses:
    """Round-trip every emitted HLO text through XLA's parser — catches
    lowerings that write but cannot be re-read (the failure mode the
    HLO-text interchange exists to avoid). Actual *execution* of the
    artifacts is covered by the Rust integration tests, which exercise the
    same xla_extension parser+compiler the production path uses."""

    def test_all_artifacts_reparse(self, built):
        out, man = built
        from jax._src.lib import xla_client as xc

        for key, art in man["artifacts"].items():
            text = open(os.path.join(out, art["file"])).read()
            mod = xc._xla.hlo_module_from_text(text)
            # The parsed module must preserve the entry parameter count.
            reparsed = mod.to_string()
            entry = reparsed.split("ENTRY")[1]
            declared = sum(
                1 for line in entry.split("\n") if " parameter(" in line
            )
            assert declared == len(art["inputs"]), key
