"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Fixed-shape exactness checks plus hypothesis sweeps over shapes/dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adamw, attention, nesterov, ref, xent

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

class TestAttention:
    def test_matches_ref_default_shape(self):
        kq, kk, kv = keys(0, 3)
        q = jax.random.normal(kq, (2, 4, 32, 16))
        k = jax.random.normal(kk, (2, 4, 32, 16))
        v = jax.random.normal(kv, (2, 4, 32, 16))
        np.testing.assert_allclose(
            attention.causal_attention(q, k, v),
            ref.causal_attention(q, k, v),
            atol=ATOL,
        )

    def test_causality(self):
        """Output at position t must not depend on inputs at positions > t."""
        kq, kk, kv, kp = keys(1, 4)
        q = jax.random.normal(kq, (1, 2, 32, 16))
        k = jax.random.normal(kk, (1, 2, 32, 16))
        v = jax.random.normal(kv, (1, 2, 32, 16))
        out = attention.causal_attention(q, k, v)
        # Perturb the future half of k/v; prefix output must be unchanged.
        noise = jax.random.normal(kp, (1, 2, 16, 16)) * 10
        k2 = k.at[:, :, 16:].add(noise)
        v2 = v.at[:, :, 16:].add(noise)
        out2 = attention.causal_attention(q, k2, v2)
        np.testing.assert_allclose(out[:, :, :16], out2[:, :, :16], atol=ATOL)

    def test_grad_matches_ref(self):
        kq, kk, kv = keys(2, 3)
        q = jax.random.normal(kq, (1, 2, 32, 16))
        k = jax.random.normal(kk, (1, 2, 32, 16))
        v = jax.random.normal(kv, (1, 2, 32, 16))

        def loss_pallas(q, k, v):
            return jnp.sum(attention.causal_attention(q, k, v) ** 2)

        def loss_ref(q, k, v):
            return jnp.sum(ref.causal_attention(q, k, v) ** 2)

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gp, gr):
            np.testing.assert_allclose(a, b, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        b=st.integers(1, 3),
        h=st.integers(1, 4),
        s_tiles=st.integers(1, 4),
        d=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, b, h, s_tiles, d, seed):
        s = 16 * s_tiles
        kq, kk, kv = keys(seed, 3)
        q = jax.random.normal(kq, (b, h, s, d))
        k = jax.random.normal(kk, (b, h, s, d))
        v = jax.random.normal(kv, (b, h, s, d))
        np.testing.assert_allclose(
            attention.causal_attention(q, k, v),
            ref.causal_attention(q, k, v),
            atol=ATOL,
        )

    def test_rejects_misaligned_seq(self):
        q = jnp.zeros((1, 1, 10, 8))
        with pytest.raises(ValueError):
            attention.causal_attention(q, q, q)


# --------------------------------------------------------------------------
# Softmax cross-entropy
# --------------------------------------------------------------------------

class TestXent:
    def test_matches_ref(self):
        kl, kt = keys(3, 2)
        logits = jax.random.normal(kl, (64, 100)) * 3
        targets = jax.random.randint(kt, (64,), 0, 100)
        np.testing.assert_allclose(
            xent.softmax_xent(logits, targets),
            ref.softmax_xent(logits, targets)[0],
            atol=ATOL,
        )

    def test_grad_matches_ref(self):
        kl, kt = keys(4, 2)
        logits = jax.random.normal(kl, (32, 50))
        targets = jax.random.randint(kt, (32,), 0, 50)
        gp = jax.grad(lambda l: jnp.mean(xent.softmax_xent(l, targets)))(logits)
        gr = jax.grad(
            lambda l: jnp.mean(ref.softmax_xent(l, targets)[0])
        )(logits)
        np.testing.assert_allclose(gp, gr, atol=ATOL)

    def test_uniform_logits_is_log_v(self):
        """nll of uniform logits must be exactly log(V)."""
        v = 128
        logits = jnp.zeros((32, v))
        targets = jnp.arange(32, dtype=jnp.int32)
        nll = xent.softmax_xent(logits, targets)
        np.testing.assert_allclose(nll, np.log(v), rtol=1e-6)

    def test_extreme_logits_stable(self):
        """No overflow for large-magnitude logits (online max-subtract)."""
        logits = jnp.array([[1e4, -1e4, 0.0, 5.0]] * 32, jnp.float32)
        targets = jnp.zeros((32,), jnp.int32)
        nll = xent.softmax_xent(logits, targets)
        assert np.all(np.isfinite(nll))
        np.testing.assert_allclose(nll, 0.0, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        n_blocks=st.integers(1, 4),
        v=st.sampled_from([17, 64, 311]),
        scale=st.floats(0.1, 10.0),
        seed=st.integers(0, 2**16),
    )
    def test_shape_sweep(self, n_blocks, v, scale, seed):
        n = 32 * n_blocks
        kl, kt = keys(seed, 2)
        logits = jax.random.normal(kl, (n, v)) * scale
        targets = jax.random.randint(kt, (n,), 0, v)
        np.testing.assert_allclose(
            xent.softmax_xent(logits, targets),
            ref.softmax_xent(logits, targets)[0],
            atol=1e-4,
        )


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------

HP = dict(lr=3e-4, b1=0.9, b2=0.999, eps=1e-8, wd=0.1)


class TestAdamW:
    def test_matches_ref(self):
        kp, kg, km, kv = keys(5, 4)
        p = jax.random.normal(kp, (5000,))
        g = jax.random.normal(kg, (5000,))
        m = jax.random.normal(km, (5000,)) * 0.1
        v = jax.random.normal(kv, (5000,)) ** 2
        got = adamw.adamw_update(p, g, m, v, step=7.0, **HP)
        want = ref.adamw_update(p, g, m, v, step=7.0, **HP)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=ATOL)

    def test_non_multiple_of_block(self):
        """Padding path: n not divisible by the VMEM block size."""
        kp, kg = keys(6, 2)
        n = 4096 + 37
        p = jax.random.normal(kp, (n,))
        g = jax.random.normal(kg, (n,))
        z = jnp.zeros((n,))
        got = adamw.adamw_update(p, g, z, z, step=1.0, **HP)
        want = ref.adamw_update(p, g, z, z, step=1.0, **HP)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=ATOL)

    def test_zero_grad_pure_decay(self):
        """g=0, m=v=0 ⇒ update is exactly the decoupled weight-decay term."""
        p = jnp.ones((100,))
        z = jnp.zeros((100,))
        p2, m2, v2 = adamw.adamw_update(p, z, z, z, step=1.0, **HP)
        np.testing.assert_allclose(p2, p * (1 - HP["lr"] * HP["wd"]), atol=1e-7)
        np.testing.assert_allclose(m2, 0.0)
        np.testing.assert_allclose(v2, 0.0)

    @settings(max_examples=8, deadline=None)
    @given(
        n=st.integers(1, 9000),
        step=st.integers(1, 1000),
        seed=st.integers(0, 2**16),
    )
    def test_size_sweep(self, n, step, seed):
        kp, kg, km, kv = keys(seed, 4)
        p = jax.random.normal(kp, (n,))
        g = jax.random.normal(kg, (n,))
        m = jax.random.normal(km, (n,)) * 0.01
        v = jax.random.normal(kv, (n,)) ** 2
        got = adamw.adamw_update(p, g, m, v, step=float(step), **HP)
        want = ref.adamw_update(p, g, m, v, step=float(step), **HP)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=1e-4)


# --------------------------------------------------------------------------
# Outer Nesterov
# --------------------------------------------------------------------------

class TestNesterov:
    def test_matches_ref(self):
        kp, kd, km = keys(7, 3)
        p = jax.random.normal(kp, (5000,))
        d = jax.random.normal(kd, (5000,))
        m = jax.random.normal(km, (5000,))
        got = nesterov.nesterov_update(p, d, m, lr=0.7, mu=0.9)
        want = ref.nesterov_update(p, d, m, lr=0.7, mu=0.9)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=ATOL)

    def test_mu_zero_is_sgd(self):
        """μ=0 must reduce Nesterov to plain SGD: θ' = θ - lr·Δ."""
        kp, kd = keys(8, 2)
        p = jax.random.normal(kp, (1000,))
        d = jax.random.normal(kd, (1000,))
        p2, m2 = nesterov.nesterov_update(p, d, jnp.zeros_like(p), lr=0.5, mu=0.0)
        np.testing.assert_allclose(p2, p - 0.5 * d, atol=1e-6)
        np.testing.assert_allclose(m2, d, atol=1e-6)

    def test_zero_delta_decays_momentum_only(self):
        p = jnp.ones((100,))
        m = jnp.ones((100,))
        p2, m2 = nesterov.nesterov_update(p, jnp.zeros_like(p), m, lr=0.7, mu=0.9)
        np.testing.assert_allclose(m2, 0.9, atol=1e-6)
        np.testing.assert_allclose(p2, 1.0 - 0.7 * 0.81, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(
        n=st.integers(1, 9000),
        lr=st.floats(0.01, 1.0),
        mu=st.floats(0.0, 0.99),
        seed=st.integers(0, 2**16),
    )
    def test_size_sweep(self, n, lr, mu, seed):
        kp, kd, km = keys(seed, 3)
        p = jax.random.normal(kp, (n,))
        d = jax.random.normal(kd, (n,))
        m = jax.random.normal(km, (n,))
        got = nesterov.nesterov_update(p, d, m, lr=lr, mu=mu)
        want = ref.nesterov_update(p, d, m, lr=lr, mu=mu)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, atol=1e-4)
