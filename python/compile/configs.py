"""Model / training configuration presets.

Paper-parity presets (``60m``/``150m``/``400m``) follow Table 1 of the
DiLoCo paper (chinchilla-style decoder-only transformers). Scaled tiers
(``nano``/``micro``/``tiny``) preserve the architecture family at sizes a
single-core CPU PJRT client can train; the scale map lives in DESIGN.md §6.

Everything here is *build-time only*: these dataclasses parameterize the
AOT lowering in ``aot.py`` and are echoed into the artifact manifest so the
Rust side (``config::presets``) can assert it agrees.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer architecture (chinchilla-style)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_head: int  # K/V size per head (Table 1)
    vocab_size: int
    seq_len: int
    d_ff_mult: int = 4  # MLP hidden = d_ff_mult * d_model

    @property
    def d_ff(self) -> int:
        return self.d_ff_mult * self.d_model

    def param_count(self) -> int:
        """Exact parameter count of init_params() for this config."""
        d, dh, nh, v, s = (
            self.d_model,
            self.d_head,
            self.n_heads,
            self.vocab_size,
            self.seq_len,
        )
        attn = d * (nh * dh) * 3 + (nh * dh) * d  # wq wk wv + wo
        mlp = d * self.d_ff + self.d_ff + self.d_ff * d + d
        ln = 2 * d  # gain + bias
        block = attn + mlp + 2 * ln
        embed = v * d + s * d  # token + learned positional
        head = d * v
        return embed + self.n_layers * block + 2 * d + head


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Inner-optimization hyperparameters (paper Table 5, scaled)."""

    batch_size: int
    peak_lr: float = 4e-4
    warmup_steps: int = 1000
    total_steps: int = 88_000  # cosine decay horizon
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0  # 0.0 disables


# --- Paper-parity presets (Table 1; batch 512, seq 1024, Table 5) -------
_PAPER = {
    "60m": ModelConfig("60m", 3, 896, 16, 64, 32_000, 1024),
    "150m": ModelConfig("150m", 12, 896, 16, 64, 32_000, 1024),
    "400m": ModelConfig("400m", 12, 1536, 12, 128, 32_000, 1024),
}

# --- Scaled tiers for the 1-core CPU testbed (DESIGN.md §6) -------------
_SCALED = {
    "nano": ModelConfig("nano", 2, 64, 4, 16, 256, 32),
    "micro": ModelConfig("micro", 4, 128, 4, 32, 512, 64),
    "tiny": ModelConfig("tiny", 8, 256, 8, 32, 2048, 128),
}

MODEL_PRESETS: Dict[str, ModelConfig] = {**_PAPER, **_SCALED}

TRAIN_PRESETS: Dict[str, TrainConfig] = {
    "60m": TrainConfig(batch_size=512),
    "150m": TrainConfig(batch_size=512),
    "400m": TrainConfig(batch_size=512),
    # Scaled: shorter horizons, proportional warmup; batch sized for 1 core.
    "nano": TrainConfig(batch_size=8, warmup_steps=20, total_steps=1_600),
    "micro": TrainConfig(batch_size=8, warmup_steps=40, total_steps=3_200),
    "tiny": TrainConfig(batch_size=16, warmup_steps=60, total_steps=2_400),
}


def model_config(name: str) -> ModelConfig:
    try:
        return MODEL_PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown model preset {name!r}; have {sorted(MODEL_PRESETS)}"
        ) from None


def train_config(name: str) -> TrainConfig:
    try:
        return TRAIN_PRESETS[name]
    except KeyError:
        raise KeyError(
            f"unknown train preset {name!r}; have {sorted(TRAIN_PRESETS)}"
        ) from None
