"""Outer Nesterov-momentum update as a Pallas kernel.

Same fused-elementwise pattern as the AdamW kernel: one VMEM pass over
(θ, Δ, μ-buffer) per tile. This backs the ``outer_step`` artifact — the
XLA-accelerated alternative to the Rust-native outer optimizer
(``coordinator::opt``), cross-checked against it in the Rust tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _nesterov_kernel(p_ref, d_ref, m_ref, lr_ref, mu_ref, po_ref, mo_ref):
    p = p_ref[...].astype(jnp.float32)
    delta = d_ref[...].astype(jnp.float32)
    mom = m_ref[...].astype(jnp.float32)
    lr = lr_ref[0]
    mu = mu_ref[0]
    mom_new = mu * mom + delta
    p_new = p - lr * (delta + mu * mom_new)
    po_ref[...] = p_new.astype(po_ref.dtype)
    mo_ref[...] = mom_new.astype(mo_ref.dtype)


def nesterov_update(p, delta, mom, *, lr, mu, block=DEFAULT_BLOCK):
    """Fused Nesterov outer step on flat f32 tensors → (θ', μ')."""
    (n,) = p.shape
    pad = (-n) % block
    if pad:
        zeros = jnp.zeros((pad,), p.dtype)
        p, delta, mom = (jnp.concatenate([t, zeros]) for t in (p, delta, mom))
    npad = n + pad
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    mu_arr = jnp.asarray(mu, jnp.float32).reshape(1)
    p2, m2 = pl.pallas_call(
        _nesterov_kernel,
        grid=(npad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((npad,), p.dtype)] * 2,
        interpret=True,
    )(p, delta, mom, lr_arr, mu_arr)
    if pad:
        p2, m2 = p2[:n], m2[:n]
    return p2, m2
