"""Fused softmax cross-entropy as Pallas kernels (fwd + bwd).

Fuses log-softmax with the target gather so the (tokens × vocab) logit
matrix never round-trips to HBM twice. Both directions are Pallas kernels:
the forward emits per-token nll plus the logsumexp residual; the backward
consumes (logits, lse, targets, cotangent) and emits d(logits) in one pass
— the ``(softmax - onehot) * g`` recurrence.

Grid: one cell per row-block of ``block_n`` tokens; the full vocab row for
each token sits in VMEM (vocab tiles would be the next refinement for very
large V; at paper scale V=32k × 4B = 128KiB/row-block ≤ VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 32


def _xent_fwd_kernel(logits_ref, targets_ref, nll_ref, lse_ref):
    logits = logits_ref[...].astype(jnp.float32)  # (block_n, V)
    targets = targets_ref[...]  # (block_n,)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[:, None]), axis=-1))
    n, v = logits.shape
    onehot = targets[:, None] == jax.lax.iota(jnp.int32, v)[None, :]
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll_ref[...] = (lse - picked).astype(nll_ref.dtype)
    lse_ref[...] = lse.astype(lse_ref.dtype)


def _xent_bwd_kernel(logits_ref, lse_ref, targets_ref, g_ref, dlogits_ref):
    logits = logits_ref[...].astype(jnp.float32)
    lse = lse_ref[...].astype(jnp.float32)
    targets = targets_ref[...]
    g = g_ref[...].astype(jnp.float32)
    probs = jnp.exp(logits - lse[:, None])
    n, v = logits.shape
    onehot = (targets[:, None] == jax.lax.iota(jnp.int32, v)[None, :]).astype(
        jnp.float32
    )
    dlogits_ref[...] = ((probs - onehot) * g[:, None]).astype(dlogits_ref.dtype)


def _check(n, block_n):
    if n % block_n:
        raise ValueError(f"token count {n} must divide block_n {block_n}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def softmax_xent(logits, targets, block_n=DEFAULT_BLOCK_N):
    """Per-token nll: logits (N, V), targets (N,) → nll (N,)."""
    nll, _ = _fwd_call(logits, targets, block_n)
    return nll


def _fwd_call(logits, targets, block_n):
    n, v = logits.shape
    _check(n, block_n)
    return pl.pallas_call(
        _xent_fwd_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), logits.dtype),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(logits, targets)


def _fwd(logits, targets, block_n):
    nll, lse = _fwd_call(logits, targets, block_n)
    return nll, (logits, lse, targets)


def _bwd(block_n, res, g):
    logits, lse, targets = res
    n, v = logits.shape
    dlogits = pl.pallas_call(
        _xent_bwd_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, v), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, v), logits.dtype),
        interpret=True,
    )(logits, lse, targets, g)
    return dlogits, None


softmax_xent.defvjp(_fwd, _bwd)
