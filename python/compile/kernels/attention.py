"""Fused causal flash-attention as a Pallas kernel (TPU-shaped, interpret on CPU).

Hardware adaptation (DESIGN.md §7): the CUDA flash-attention insight — never
materialize the S×S score matrix in HBM, stream K/V tiles through fast
memory with an online softmax — maps onto TPU as BlockSpec-driven HBM→VMEM
tile streaming with per-tile ``jnp.dot`` contractions feeding the MXU. The
grid is (batch·heads, q_tiles); K/V tiles stream in an inner ``fori_loop``.
Online-softmax accumulators (running max ``m``, normalizer ``l``, weighted
sum ``acc``) live in VMEM for the lifetime of one q-tile.

On this backend Pallas must run with ``interpret=True`` (CPU PJRT cannot
execute Mosaic custom-calls), so this path is a *correctness + composition*
artifact; the ref path produces the default fast artifacts.

The public entry ``causal_attention`` carries a ``jax.custom_vjp``: forward
is the Pallas kernel, backward is the exact flash backward recurrence in
pure jnp (re-computing probabilities tile-free — fine at build time, and
numerically identical to differentiating the reference).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_Q = 16
DEFAULT_BLOCK_K = 16


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, seq_len):
    """One (batch·head, q-tile) grid cell of causal flash attention.

    q_ref: (block_q, d) VMEM tile; k_ref/v_ref: (S, d) — the full K/V rows
    for this head, streamed block_k at a time; o_ref: (block_q, d) output.
    """
    block_q = q_ref.shape[0]
    d = q_ref.shape[1]
    q_tile_idx = pl.program_id(1)
    q_start = q_tile_idx * block_q

    q = q_ref[...].astype(jnp.float32) * scale

    # Online-softmax accumulators (the VMEM-resident state of flash attn).
    m0 = jnp.full((block_q,), ref.NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)

    # Causality: q row (q_start + i) attends keys <= q_start + i, so K tiles
    # beyond the current q tile's last row contribute nothing — skip them.
    num_k_tiles = (q_start + block_q + block_k - 1) // block_k

    def body(kt, carry):
        m_prev, l_prev, acc_prev = carry
        k_start = kt * block_k
        k_tile = jax.lax.dynamic_slice_in_dim(k_ref[...], k_start, block_k, 0)
        v_tile = jax.lax.dynamic_slice_in_dim(v_ref[...], k_start, block_k, 0)
        s = jnp.dot(  # (block_q, block_k) — MXU contraction on real TPU
            q, k_tile.astype(jnp.float32).T, preferred_element_type=jnp.float32
        )
        # Causal mask within the tile.
        q_ids = q_start + jax.lax.iota(jnp.int32, block_q)
        k_ids = k_start + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where(q_ids[:, None] >= k_ids[None, :], s, ref.NEG_INF)

        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(
            p, v_tile.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_k_tiles, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def causal_attention(q, k, v, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K):
    """Pallas causal attention; q/k/v: (B, H, S, Dh) → (B, H, S, Dh)."""
    return _forward(q, k, v, block_q, block_k)


def _forward(q, k, v, block_q, block_k):
    b, h, s, d = q.shape
    if s % block_q or s % block_k:
        raise ValueError(f"seq_len {s} must divide block sizes {block_q}/{block_k}")
    scale = 1.0 / (d**0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, block_k=block_k, seq_len=s
        ),
        grid=(b * h, s // block_q),
        in_specs=[
            # Q streams one (block_q, d) tile per grid cell…
            pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
            # …K/V expose the whole row for this head; the kernel's inner
            # fori_loop is the HBM→VMEM tile schedule.
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def _fwd(q, k, v, block_q, block_k):
    out = _forward(q, k, v, block_q, block_k)
    return out, (q, k, v)


def _bwd(block_q, block_k, res, g):
    # Exact attention backward in jnp (build-time only; see module docstring).
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.causal_attention(q_, k_, v_), q, k, v)
    return vjp(g)


causal_attention.defvjp(_fwd, _bwd)
