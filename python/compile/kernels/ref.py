"""Pure-jnp correctness oracles for every Pallas kernel.

These are the semantic ground truth: each Pallas kernel in this package is
validated against the function of the same name here (pytest +
hypothesis sweeps in ``python/tests/test_kernels.py``). They are also the
default implementation compiled into the AOT artifacts (``--kernels ref``),
since XLA:CPU fuses them well while Pallas must run in interpret mode on
this backend (see DESIGN.md §7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def causal_attention(q, k, v, scale=None):
    """Multi-head causal attention.

    q, k, v: (B, H, S, Dh). Returns (B, H, S, Dh).
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = q.shape[2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def softmax_xent(logits, targets):
    """Token-level negative log-likelihood.

    logits: (N, V) float; targets: (N,) int32. Returns nll (N,) and
    logsumexp (N,) — the latter is the residual reused by the bwd kernel.
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return lse - picked, lse


def softmax_xent_grad(logits, lse, targets, g):
    """Gradient of ``softmax_xent`` nll wrt logits.

    d nll_i / d logits_ij = softmax(logits)_ij - 1[j == targets_i],
    scaled by the incoming cotangent g (N,).
    """
    probs = jnp.exp(logits - lse[:, None])
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    return (probs - onehot) * g[:, None]


def adamw_update(p, g, m, v, *, lr, b1, b2, eps, wd, step):
    """One fused AdamW step on a flat tensor.

    Decoupled weight decay (Loshchilov & Hutter 2019): the decay term uses
    the *pre-update* parameters scaled by lr. ``step`` is 1-based.
    """
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / (1.0 - b1**step)
    v_hat = v_new / (1.0 - b2**step)
    p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * p)
    return p_new, m_new, v_new


def nesterov_update(p, delta, mom, *, lr, mu):
    """One outer Nesterov step (Sutskever et al. 2013, PyTorch convention).

    ``delta`` is the averaged outer gradient Δ = mean_i(θ_prev - θ_i),
    treated as a gradient: new_mom = μ·mom + Δ; θ' = θ - lr·(Δ + μ·new_mom).
    """
    mom_new = mu * mom + delta
    p_new = p - lr * (delta + mu * mom_new)
    return p_new, mom_new
