"""L1 Pallas kernels for the DiLoCo compute hot-spots.

``ref`` holds the pure-jnp oracles; each sibling module implements the same
contract as a Pallas kernel (interpret-mode on CPU). ``select(impl)``
returns the kernel namespace the L2 model should call — ``"ref"`` for the
fast XLA-fused default artifacts, ``"pallas"`` for the composition-proof
artifacts.
"""

from __future__ import annotations

import types

from . import adamw, attention, nesterov, ref, xent


def select(impl: str) -> types.SimpleNamespace:
    """Kernel namespace with a uniform surface for the L2 model."""
    if impl == "ref":
        return types.SimpleNamespace(
            causal_attention=lambda q, k, v: ref.causal_attention(q, k, v),
            softmax_xent=lambda lg, tg: ref.softmax_xent(lg, tg)[0],
            adamw_update=ref.adamw_update,
            nesterov_update=ref.nesterov_update,
        )
    if impl == "pallas":
        return types.SimpleNamespace(
            causal_attention=lambda q, k, v: attention.causal_attention(q, k, v),
            softmax_xent=lambda lg, tg: xent.softmax_xent(lg, tg),
            adamw_update=adamw.adamw_update,
            nesterov_update=nesterov.nesterov_update,
        )
    raise ValueError(f"unknown kernel impl {impl!r} (want 'ref' or 'pallas')")
