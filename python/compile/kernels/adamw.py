"""Fused AdamW update as a Pallas kernel.

The unfused update reads/writes (p, g, m, v) in four separate elementwise
passes — pure HBM bandwidth waste. This kernel makes one pass per
``block``-sized tile: load (p, g, m, v) into VMEM, compute the full AdamW
recurrence on the VPU, store (p', m', v'). No grad flows through it (it is
the optimizer), so no custom_vjp is needed.

Operates on flat 1-D tensors; the model layer flattens each leaf before
calling and reshapes after (layout is irrelevant for elementwise math).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 4096


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, step_ref, lr_ref,
                  po_ref, mo_ref, vo_ref, *, b1, b2, eps, wd):
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    step = step_ref[0]
    lr = lr_ref[0]

    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / (1.0 - b1**step)
    v_hat = v_new / (1.0 - b2**step)
    p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * p)

    po_ref[...] = p_new.astype(po_ref.dtype)
    mo_ref[...] = m_new.astype(mo_ref.dtype)
    vo_ref[...] = v_new.astype(vo_ref.dtype)


def adamw_update(p, g, m, v, *, lr, b1, b2, eps, wd, step,
                 block=DEFAULT_BLOCK):
    """Fused AdamW on a flat f32 tensor; returns (p', m', v').

    ``lr`` and ``step`` may be traced scalars (they are passed as 1-element
    operands); β/ε/wd are baked constants.
    """
    (n,) = p.shape
    pad = (-n) % block
    if pad:
        zeros = jnp.zeros((pad,), p.dtype)
        p, g, m, v = (jnp.concatenate([t, zeros]) for t in (p, g, m, v))
    npad = n + pad
    step_arr = jnp.asarray(step, jnp.float32).reshape(1)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1)
    p2, m2, v2 = pl.pallas_call(
        functools.partial(_adamw_kernel, b1=b1, b2=b2, eps=eps, wd=wd),
        grid=(npad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),  # step broadcast to all tiles
            pl.BlockSpec((1,), lambda i: (0,)),  # lr broadcast
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((npad,), p.dtype)] * 3,
        interpret=True,
    )(p, g, m, v, step_arr, lr_arr)
    if pad:
        p2, m2, v2 = p2[:n], m2[:n], v2[:n]
    return p2, m2, v2
