"""AOT lowering: jax step functions → HLO text + JSON manifest.

The interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is lowered from a *flat-signature* wrapper whose positional
arguments follow the canonical leaf order of ``model.flatten_spec`` — so
HLO parameter index i is, by construction, manifest input i. The manifest
records name/role/shape/dtype per input and output; the Rust runtime binds
buffers by role and never hard-codes the architecture.

Usage (from ``python/``):
    python -m compile.aot --config micro --out-dir ../artifacts
    python -m compile.aot --config nano --kernels pallas --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import ModelConfig, TrainConfig, model_config, train_config

DTYPE_NAMES = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32"}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _iospec(name: str, role: str, aval) -> Dict[str, Any]:
    return {
        "name": name,
        "role": role,
        "shape": list(aval.shape),
        "dtype": DTYPE_NAMES[jnp.dtype(aval.dtype)],
    }


def _spec_leaves(tree, role: str, prefix: str) -> List[Dict[str, Any]]:
    return [
        _iospec(f"{prefix}{name}", role, leaf)
        for name, leaf in M.flatten_spec(tree)
    ]


class ArtifactBuilder:
    """Lowers one config's artifact set and accumulates the manifest."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, impl: str,
                 out_dir: str):
        self.cfg, self.tc, self.impl, self.out_dir = cfg, tc, impl, out_dir
        self.params_t = jax.eval_shape(lambda: M.init_params(cfg))
        self.manifest: Dict[str, Any] = {
            "config": {
                "name": cfg.name,
                "kernels": impl,
                "n_layers": cfg.n_layers,
                "d_model": cfg.d_model,
                "n_heads": cfg.n_heads,
                "d_head": cfg.d_head,
                "vocab_size": cfg.vocab_size,
                "seq_len": cfg.seq_len,
                "d_ff": cfg.d_ff,
                "batch_size": tc.batch_size,
                "param_count": cfg.param_count(),
                "peak_lr": tc.peak_lr,
                "warmup_steps": tc.warmup_steps,
                "total_steps": tc.total_steps,
                "weight_decay": tc.weight_decay,
                "b1": tc.b1,
                "b2": tc.b2,
                "eps": tc.eps,
                "grad_clip": tc.grad_clip,
            },
            "params": [
                {"name": n, "shape": list(l.shape), "dtype": "f32"}
                for n, l in M.flatten_spec(self.params_t)
            ],
            "artifacts": {},
        }

    # -- shape helpers ----------------------------------------------------
    def _batch_avals(self):
        b, s = self.tc.batch_size, self.cfg.seq_len
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        tgt = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return tok, tgt

    def _tree_avals(self):
        return jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), self.params_t
        )

    # -- artifact writers -------------------------------------------------
    def _write(self, key: str, hlo: str, inputs, outputs):
        fname = f"{self.cfg.name}.{key}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(hlo)
        self.manifest["artifacts"][key] = {
            "file": fname,
            "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"  {fname}: {len(hlo) / 1e6:.2f} MB, "
              f"{len(inputs)} inputs, {len(outputs)} outputs")

    def build_train_step(self):
        step_fn = M.make_train_step(self.cfg, self.tc, self.impl)
        pt = self._tree_avals()
        tok, tgt = self._batch_avals()
        n_leaves = len(M.flatten(pt))

        def flat(*args):
            p = M.unflatten(pt, list(args[:n_leaves]))
            m = M.unflatten(pt, list(args[n_leaves:2 * n_leaves]))
            v = M.unflatten(pt, list(args[2 * n_leaves:3 * n_leaves]))
            step, tokens, targets = args[3 * n_leaves:]
            np_, nm, nv, loss = step_fn(p, m, v, step, tokens, targets)
            return tuple(M.flatten(np_) + M.flatten(nm) + M.flatten(nv) + [loss])

        leaves = M.flatten(pt)
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        args = leaves * 3 + [scalar, tok, tgt]
        lowered = jax.jit(flat).lower(*args)
        inputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "opt_m", "m.")
            + _spec_leaves(pt, "opt_v", "v.")
            + [_iospec("step", "step", scalar),
               _iospec("tokens", "batch_tokens", tok),
               _iospec("targets", "batch_targets", tgt)]
        )
        outputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "opt_m", "m.")
            + _spec_leaves(pt, "opt_v", "v.")
            + [_iospec("loss", "loss", scalar)]
        )
        self._write("train_step", to_hlo_text(lowered), inputs, outputs)

    def build_train_chunk(self, chunk: int):
        step_fn = M.make_train_chunk(self.cfg, self.tc, self.impl, chunk)
        pt = self._tree_avals()
        b, s = self.tc.batch_size, self.cfg.seq_len
        tok = jax.ShapeDtypeStruct((chunk, b, s), jnp.int32)
        tgt = jax.ShapeDtypeStruct((chunk, b, s), jnp.int32)
        n_leaves = len(M.flatten(pt))

        def flat(*args):
            p = M.unflatten(pt, list(args[:n_leaves]))
            m = M.unflatten(pt, list(args[n_leaves:2 * n_leaves]))
            v = M.unflatten(pt, list(args[2 * n_leaves:3 * n_leaves]))
            step, tokens, targets = args[3 * n_leaves:]
            np_, nm, nv, losses = step_fn(p, m, v, step, tokens, targets)
            return tuple(
                M.flatten(np_) + M.flatten(nm) + M.flatten(nv) + [losses]
            )

        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        leaves = M.flatten(pt)
        lowered = jax.jit(flat).lower(*(leaves * 3 + [scalar, tok, tgt]))
        losses = jax.ShapeDtypeStruct((chunk,), jnp.float32)
        inputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "opt_m", "m.")
            + _spec_leaves(pt, "opt_v", "v.")
            + [_iospec("step", "step", scalar),
               _iospec("tokens", "batch_tokens", tok),
               _iospec("targets", "batch_targets", tgt)]
        )
        outputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "opt_m", "m.")
            + _spec_leaves(pt, "opt_v", "v.")
            + [_iospec("losses", "loss", losses)]
        )
        self._write(f"train_chunk_{chunk}", to_hlo_text(lowered),
                    inputs, outputs)

    def build_eval_step(self):
        step_fn = M.make_eval_step(self.cfg, self.impl)
        pt = self._tree_avals()
        tok, tgt = self._batch_avals()
        n_leaves = len(M.flatten(pt))

        def flat(*args):
            p = M.unflatten(pt, list(args[:n_leaves]))
            tokens, targets = args[n_leaves:]
            return step_fn(p, tokens, targets)

        lowered = jax.jit(flat).lower(*(M.flatten(pt) + [tok, tgt]))
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        inputs = _spec_leaves(pt, "param", "") + [
            _iospec("tokens", "batch_tokens", tok),
            _iospec("targets", "batch_targets", tgt),
        ]
        outputs = [
            _iospec("sum_nll", "sum_nll", scalar),
            _iospec("token_count", "token_count", scalar),
        ]
        self._write("eval_step", to_hlo_text(lowered), inputs, outputs)

    def build_outer_step(self):
        step_fn = M.make_outer_step(self.impl)
        pt = self._tree_avals()
        n_leaves = len(M.flatten(pt))

        def flat(*args):
            p = M.unflatten(pt, list(args[:n_leaves]))
            d = M.unflatten(pt, list(args[n_leaves:2 * n_leaves]))
            m = M.unflatten(pt, list(args[2 * n_leaves:3 * n_leaves]))
            lr, mu = args[3 * n_leaves:]
            np_, nm = step_fn(p, d, m, lr, mu)
            return tuple(M.flatten(np_) + M.flatten(nm))

        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        leaves = M.flatten(pt)
        lowered = jax.jit(flat).lower(*(leaves * 3 + [scalar, scalar]))
        inputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "outer_delta", "delta.")
            + _spec_leaves(pt, "outer_mom", "mom.")
            + [_iospec("lr", "outer_lr", scalar),
               _iospec("mu", "outer_mu", scalar)]
        )
        outputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "outer_mom", "mom.")
        )
        self._write("outer_step", to_hlo_text(lowered), inputs, outputs)

    def build_grad_step(self):
        step_fn = M.make_grad_step(self.cfg, self.tc, self.impl)
        pt = self._tree_avals()
        tok, tgt = self._batch_avals()
        n_leaves = len(M.flatten(pt))

        def flat(*args):
            p = M.unflatten(pt, list(args[:n_leaves]))
            tokens, targets = args[n_leaves:]
            grads, loss = step_fn(p, tokens, targets)
            return tuple(M.flatten(grads) + [loss])

        lowered = jax.jit(flat).lower(*(M.flatten(pt) + [tok, tgt]))
        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        inputs = _spec_leaves(pt, "param", "") + [
            _iospec("tokens", "batch_tokens", tok),
            _iospec("targets", "batch_targets", tgt),
        ]
        outputs = _spec_leaves(pt, "grad", "g.") + [
            _iospec("loss", "loss", scalar)
        ]
        self._write("grad_step", to_hlo_text(lowered), inputs, outputs)

    def build_apply_update(self):
        step_fn = M.make_apply_update(self.cfg, self.tc, self.impl)
        pt = self._tree_avals()
        n_leaves = len(M.flatten(pt))

        def flat(*args):
            p = M.unflatten(pt, list(args[:n_leaves]))
            m = M.unflatten(pt, list(args[n_leaves:2 * n_leaves]))
            v = M.unflatten(pt, list(args[2 * n_leaves:3 * n_leaves]))
            g = M.unflatten(pt, list(args[3 * n_leaves:4 * n_leaves]))
            step = args[4 * n_leaves]
            np_, nm, nv = step_fn(p, m, v, g, step)
            return tuple(M.flatten(np_) + M.flatten(nm) + M.flatten(nv))

        scalar = jax.ShapeDtypeStruct((), jnp.float32)
        leaves = M.flatten(pt)
        lowered = jax.jit(flat).lower(*(leaves * 4 + [scalar]))
        inputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "opt_m", "m.")
            + _spec_leaves(pt, "opt_v", "v.")
            + _spec_leaves(pt, "grad", "g.")
            + [_iospec("step", "step", scalar)]
        )
        outputs = (
            _spec_leaves(pt, "param", "")
            + _spec_leaves(pt, "opt_m", "m.")
            + _spec_leaves(pt, "opt_v", "v.")
        )
        self._write("apply_update", to_hlo_text(lowered), inputs, outputs)

    def build_fwd_logits(self):
        fwd = M.make_fwd_logits(self.cfg, self.impl)
        pt = self._tree_avals()
        tok, _ = self._batch_avals()
        n_leaves = len(M.flatten(pt))

        def flat(*args):
            p = M.unflatten(pt, list(args[:n_leaves]))
            return (fwd(p, args[n_leaves]),)

        lowered = jax.jit(flat).lower(*(M.flatten(pt) + [tok]))
        logits = jax.ShapeDtypeStruct(
            (self.tc.batch_size, self.cfg.seq_len, self.cfg.vocab_size),
            jnp.float32,
        )
        inputs = _spec_leaves(pt, "param", "") + [
            _iospec("tokens", "batch_tokens", tok)
        ]
        outputs = [_iospec("logits", "logits", logits)]
        self._write("fwd_logits", to_hlo_text(lowered), inputs, outputs)

    def build_init_params(self, seed: int = 0):
        """Init as an artifact too, so Rust runs with zero numpy on its side."""
        def flat():
            return tuple(M.flatten(M.init_params(self.cfg, seed)))

        lowered = jax.jit(flat).lower()
        pt = self._tree_avals()
        self._write("init_params", to_hlo_text(lowered), [],
                    _spec_leaves(pt, "param", ""))

    def finalize(self):
        path = os.path.join(self.out_dir, f"{self.cfg.name}.manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"  {os.path.basename(path)} written")


def build(config_name: str, impl: str, out_dir: str,
          batch_size: int | None = None, seq_len: int | None = None,
          chunks: tuple = (5, 25)):
    cfg = model_config(config_name)
    tc = train_config(config_name)
    if batch_size is not None:
        tc = type(tc)(**{**tc.__dict__, "batch_size": batch_size})
    if seq_len is not None:
        cfg = type(cfg)(**{**cfg.__dict__, "seq_len": seq_len})
    if impl == "pallas":
        # Distinct artifact-set name so the pallas build never clobbers the
        # ref build; rust loads it as model "<name>_pallas".
        cfg = type(cfg)(**{**cfg.__dict__, "name": f"{cfg.name}_pallas"})
    print(f"building artifacts: config={cfg.name} kernels={impl} "
          f"params={cfg.param_count():,}")
    b = ArtifactBuilder(cfg, tc, impl, out_dir)
    b.build_train_step()
    for chunk in chunks:
        b.build_train_chunk(chunk)
    b.build_eval_step()
    b.build_outer_step()
    b.build_grad_step()
    b.build_apply_update()
    b.build_fwd_logits()
    b.build_init_params()
    b.finalize()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="micro",
                    help="model preset, or comma list (nano,micro,tiny)")
    ap.add_argument("--kernels", default="ref", choices=["ref", "pallas"])
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--chunks", default="5,25",
                    help="train_chunk scan lengths, comma list")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    chunks = tuple(int(c) for c in args.chunks.split(",") if c.strip())
    for name in args.config.split(","):
        build(name.strip(), args.kernels, args.out_dir,
              args.batch_size, args.seq_len, chunks)


if __name__ == "__main__":
    main()
