"""Build-time compile package: L2 jax model + L1 Pallas kernels + AOT lowering.

Never imported at runtime — the Rust binary only consumes the HLO text and
manifest files this package writes into ``artifacts/``.
"""
