"""L2: chinchilla-style decoder-only transformer LM + fused train/eval steps.

This module is build-time only. ``aot.py`` lowers the step functions defined
here to HLO text; the Rust runtime executes them. Parameters are a nested
dict pytree; :func:`flatten_spec` defines the *canonical leaf order* (sorted
depth-first) that both the lowered HLO signature and the Rust-side manifest
share — the Rust coordinator binds buffers by this order and never
hard-codes the architecture.

The compute hot-spots (attention, softmax-xent, AdamW, outer Nesterov) are
delegated to the L1 kernel namespace selected by ``kernels.select(impl)``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import kernels
from .configs import ModelConfig, TrainConfig

Tree = Any


# --------------------------------------------------------------------------
# Pytree flattening with stable, named leaf order
# --------------------------------------------------------------------------

def flatten_spec(tree: Tree, prefix: str = "") -> List[Tuple[str, Any]]:
    """Depth-first, key-sorted (name, leaf) pairs — the canonical order."""
    if isinstance(tree, dict):
        out: List[Tuple[str, Any]] = []
        for key in sorted(tree):
            out.extend(flatten_spec(tree[key], f"{prefix}{key}."))
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for i, sub in enumerate(tree):
            out.extend(flatten_spec(sub, f"{prefix}{i}."))
        return out
    return [(prefix[:-1], tree)]


def flatten(tree: Tree) -> List[Any]:
    return [leaf for _, leaf in flatten_spec(tree)]


def leaf_names(tree: Tree) -> List[str]:
    return [name for name, _ in flatten_spec(tree)]


def unflatten(template: Tree, leaves: List[Any]) -> Tree:
    """Rebuild a tree shaped like ``template`` from canonical-order leaves."""
    it = iter(leaves)

    def go(node):
        if isinstance(node, dict):
            return {k: go(node[k]) for k in sorted(node)}
        if isinstance(node, (list, tuple)):
            return type(node)(go(s) for s in node)
        return next(it)

    out = go(template)
    rest = list(it)
    if rest:
        raise ValueError(f"{len(rest)} extra leaves in unflatten")
    return out


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Tree:
    """GPT-2-style init: normal(0.02) matrices, zero biases, unit LN gains."""
    key = jax.random.PRNGKey(seed)
    d, dh, nh, v, s, ff = (
        cfg.d_model, cfg.d_head, cfg.n_heads, cfg.vocab_size,
        cfg.seq_len, cfg.d_ff,
    )

    def norm(key, shape, std=0.02):
        return (jax.random.normal(key, shape) * std).astype(jnp.float32)

    keys = iter(jax.random.split(key, 4 + 10 * cfg.n_layers))
    blocks = []
    for _ in range(cfg.n_layers):
        blocks.append({
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "attn": {
                "wq": norm(next(keys), (d, nh * dh)),
                "wk": norm(next(keys), (d, nh * dh)),
                "wv": norm(next(keys), (d, nh * dh)),
                # residual-branch projections scaled down per GPT-2
                "wo": norm(next(keys), (nh * dh, d),
                           std=0.02 / (2 * cfg.n_layers) ** 0.5),
            },
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "mlp": {
                "w1": norm(next(keys), (d, ff)),
                "b1": jnp.zeros((ff,)),
                "w2": norm(next(keys), (ff, d),
                           std=0.02 / (2 * cfg.n_layers) ** 0.5),
                "b2": jnp.zeros((d,)),
            },
        })
        for _ in range(4):  # burn the per-block spare keys deterministically
            next(keys)
    return {
        "embed": {"w": norm(next(keys), (v, d))},
        "pos": {"w": norm(next(keys), (s, d), std=0.01)},
        "blocks": blocks,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "head": {"w": norm(next(keys), (d, v))},
    }


def zeros_like_tree(tree: Tree) -> Tree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def forward(params: Tree, tokens: jnp.ndarray, cfg: ModelConfig,
            kern) -> jnp.ndarray:
    """tokens (B, S) int32 → logits (B, S, V)."""
    b, s = tokens.shape
    nh, dh = cfg.n_heads, cfg.d_head
    x = params["embed"]["w"][tokens] + params["pos"]["w"][None, :s]
    for blk in params["blocks"]:
        h = _layer_norm(x, blk["ln1"]["g"], blk["ln1"]["b"])
        q = (h @ blk["attn"]["wq"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        k = (h @ blk["attn"]["wk"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        v = (h @ blk["attn"]["wv"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
        att = kern.causal_attention(q, k, v)  # L1 hot-spot
        att = att.transpose(0, 2, 1, 3).reshape(b, s, nh * dh)
        x = x + att @ blk["attn"]["wo"]
        h = _layer_norm(x, blk["ln2"]["g"], blk["ln2"]["b"])
        h = jax.nn.gelu(h @ blk["mlp"]["w1"] + blk["mlp"]["b1"])
        x = x + h @ blk["mlp"]["w2"] + blk["mlp"]["b2"]
    x = _layer_norm(x, params["ln_f"]["g"], params["ln_f"]["b"])
    return x @ params["head"]["w"]


def loss_fn(params: Tree, tokens, targets, cfg: ModelConfig, kern):
    """Mean next-token nll over all positions."""
    logits = forward(params, tokens, cfg, kern)
    n = logits.shape[0] * logits.shape[1]
    nll = kern.softmax_xent(
        logits.reshape(n, cfg.vocab_size), targets.reshape(n)
    )  # L1 hot-spot
    return jnp.mean(nll)


# --------------------------------------------------------------------------
# Inner step: fwd/bwd + fused AdamW, lr schedule baked in
# --------------------------------------------------------------------------

def lr_schedule(step, tc: TrainConfig):
    """Linear warmup → cosine decay to 10% of peak (chinchilla-style)."""
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - tc.warmup_steps)
        / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return tc.peak_lr * warm * cos


def _global_norm(tree: Tree):
    return jnp.sqrt(
        sum(jnp.sum(leaf**2) for leaf in flatten(tree))
    )


def make_train_step(cfg: ModelConfig, tc: TrainConfig, impl: str = "ref"):
    """(params, m, v, step, tokens, targets) → (params', m', v', loss)."""
    kern = kernels.select(impl)

    def train_step(params, m, v, step, tokens, targets):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, tokens, targets, cfg, kern
        )
        if tc.grad_clip > 0.0:
            gn = _global_norm(grads)
            scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-12))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        lr = lr_schedule(step, tc)
        step1 = step + 1.0  # AdamW bias correction is 1-based

        new_p, new_m, new_v = [], [], []
        for (name, p_leaf), g_leaf, m_leaf, v_leaf in zip(
            flatten_spec(params), flatten(grads), flatten(m), flatten(v)
        ):
            shape = p_leaf.shape
            pn, mn, vn = kern.adamw_update(  # L1 hot-spot
                p_leaf.reshape(-1), g_leaf.reshape(-1),
                m_leaf.reshape(-1), v_leaf.reshape(-1),
                lr=lr, b1=tc.b1, b2=tc.b2, eps=tc.eps,
                wd=tc.weight_decay, step=step1,
            )
            new_p.append(pn.reshape(shape))
            new_m.append(mn.reshape(shape))
            new_v.append(vn.reshape(shape))
        return (
            unflatten(params, new_p),
            unflatten(m, new_m),
            unflatten(v, new_v),
            loss,
        )

    return train_step


def make_eval_step(cfg: ModelConfig, impl: str = "ref"):
    """(params, tokens, targets) → (sum_nll, token_count)."""
    kern = kernels.select(impl)

    def eval_step(params, tokens, targets):
        logits = forward(params, tokens, cfg, kern)
        n = logits.shape[0] * logits.shape[1]
        nll = kern.softmax_xent(
            logits.reshape(n, cfg.vocab_size), targets.reshape(n)
        )
        return jnp.sum(nll), jnp.asarray(float(n), jnp.float32)

    return eval_step


def make_fwd_logits(cfg: ModelConfig, impl: str = "ref"):
    """(params, tokens) → logits — debug / greedy-decode artifact."""
    kern = kernels.select(impl)

    def fwd_logits(params, tokens):
        return forward(params, tokens, cfg, kern)

    return fwd_logits


def make_train_chunk(cfg: ModelConfig, tc: TrainConfig, impl: str = "ref",
                     chunk: int = 25):
    """(params, m, v, step0, tokens[C,B,S], targets[C,B,S])
    → (params', m', v', losses[C]).

    ``chunk`` inner AdamW steps fused into one XLA execution via
    ``lax.scan``. This is the production inner loop: PJRT executions return
    a single tuple buffer (host readback per call), so running C steps per
    call amortizes the host round-trip to 1/C per step — and DiLoCo's
    round structure (H ≫ 1 local steps between communications) makes the
    boundary free: the coordinator needs the post-round parameters on the
    host anyway to form the outer gradient.
    """
    step_fn = make_train_step(cfg, tc, impl)

    def chunk_fn(params, m, v, step0, tokens, targets):
        def body(carry, xs):
            p, m_, v_, s = carry
            tok, tgt = xs
            p, m_, v_, loss = step_fn(p, m_, v_, s, tok, tgt)
            return (p, m_, v_, s + 1.0), loss

        (p, m_, v_, _), losses = jax.lax.scan(
            body, (params, m, v, step0), (tokens, targets)
        )
        return p, m_, v_, losses

    return chunk_fn


def make_grad_step(cfg: ModelConfig, tc: TrainConfig, impl: str = "ref"):
    """(params, tokens, targets) → (grads, loss) — no optimizer update.

    Backs the data-parallel / microbatching baselines (Table 2): the L3
    coordinator averages gradients across microbatches or simulated DP
    replicas, then applies one ``apply_update`` step.
    """
    kern = kernels.select(impl)

    def grad_step(params, tokens, targets):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, tokens, targets, cfg, kern
        )
        return grads, loss

    return grad_step


def make_apply_update(cfg: ModelConfig, tc: TrainConfig, impl: str = "ref"):
    """(params, m, v, grads, step) → (params', m', v') — AdamW on given grads."""
    kern = kernels.select(impl)

    def apply_update(params, m, v, grads, step):
        if tc.grad_clip > 0.0:
            gn = _global_norm(grads)
            scale = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-12))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        lr = lr_schedule(step, tc)
        step1 = step + 1.0
        new_p, new_m, new_v = [], [], []
        for p_leaf, g_leaf, m_leaf, v_leaf in zip(
            flatten(params), flatten(grads), flatten(m), flatten(v)
        ):
            shape = p_leaf.shape
            pn, mn, vn = kern.adamw_update(
                p_leaf.reshape(-1), g_leaf.reshape(-1),
                m_leaf.reshape(-1), v_leaf.reshape(-1),
                lr=lr, b1=tc.b1, b2=tc.b2, eps=tc.eps,
                wd=tc.weight_decay, step=step1,
            )
            new_p.append(pn.reshape(shape))
            new_m.append(mn.reshape(shape))
            new_v.append(vn.reshape(shape))
        return (
            unflatten(params, new_p),
            unflatten(m, new_m),
            unflatten(v, new_v),
        )

    return apply_update


# --------------------------------------------------------------------------
# Outer step (Nesterov) over the whole parameter tree
# --------------------------------------------------------------------------

def make_outer_step(impl: str = "ref"):
    """(params, delta, momentum, lr, mu) → (params', momentum')."""
    kern = kernels.select(impl)

    def outer_step(params, delta, momentum, lr, mu):
        new_p, new_m = [], []
        for p_leaf, d_leaf, m_leaf in zip(
            flatten(params), flatten(delta), flatten(momentum)
        ):
            shape = p_leaf.shape
            pn, mn = kern.nesterov_update(
                p_leaf.reshape(-1), d_leaf.reshape(-1), m_leaf.reshape(-1),
                lr=lr, mu=mu,
            )
            new_p.append(pn.reshape(shape))
            new_m.append(mn.reshape(shape))
        return unflatten(params, new_p), unflatten(momentum, new_m)

    return outer_step
