//! Golden-trace regression suite — the tier-1 safety net for the
//! default (monolithic, full-precision) sync path.
//!
//! `golden_trace_default_config` runs the tiny nano preset for 3 rounds
//! and asserts the *exact* per-round eval-loss / drop / comm-byte trace
//! against `tests/golden/diloco_nano_tiny.json`. Floats are serialized
//! with shortest-roundtrip formatting, so comparison is bit-exact: any
//! change to the default hot path — averaging order, drop keying,
//! billing, optimizer arithmetic — trips this test.
//!
//! Regeneration (only after an *intentional* trace change, with the diff
//! reviewed):
//!
//! ```text
//! UPDATE_GOLDEN=1 cargo test --release --test golden_trace -- --ignored
//! ```
//!
//! The suite needs the AOT artifacts (`make artifacts`) and is `#[ignore]`d
//! so plain `cargo test` stays artifact-free; CI runs it via
//! `cargo test --release -- --ignored` (see .github/workflows/ci.yml).

use diloco::config::{ComputeSchedule, ExperimentConfig};
use diloco::coordinator::{Coordinator, DilocoReport};
use diloco::runtime::Runtime;
use diloco::util::json::Json;
use std::collections::BTreeMap;
use std::sync::Arc;

fn artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
        .join("diloco_nano_tiny.json")
}

fn runtime() -> Option<Arc<Runtime>> {
    let dir = artifacts_dir();
    std::path::Path::new(&dir)
        .join("nano.manifest.json")
        .exists()
        .then(|| Arc::new(Runtime::load(&dir, "nano").unwrap()))
}

/// The tiny golden preset: 2 workers × 3 rounds × 5 inner steps on nano,
/// evaluated every round. Deliberately small — the suite must stay fast
/// enough to run on every push.
fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(&artifacts_dir(), "nano");
    cfg.seed = 0;
    cfg.workers = 2;
    cfg.schedule = ComputeSchedule::Constant(2);
    cfg.inner_steps = 5;
    cfg.rounds = 3;
    cfg.pretrain_steps = 0;
    cfg.eval_every_rounds = 1;
    cfg.eval_batches = 1;
    cfg.data.n_docs = 60;
    cfg.data.doc_len = 120;
    cfg
}

/// Serialize the per-round trace of a finished run. Every number here is
/// deterministic given the config seed; floats round-trip bit-exactly
/// through `util::json`.
fn trace_json(cfg: &ExperimentConfig, report: &DilocoReport) -> Json {
    let m = &report.metrics;
    assert_eq!(m.eval_curve.len(), cfg.rounds, "one eval point per round");
    assert_eq!(report.comm_per_round.len(), cfg.rounds);
    let rounds: Vec<Json> = (0..cfg.rounds)
        .map(|t| {
            let c = &report.comm_per_round[t];
            let losses =
                &m.loss_curve[t * cfg.inner_steps..(t + 1) * cfg.inner_steps];
            let loss_mean =
                losses.iter().map(|&x| x as f64).sum::<f64>() / losses.len() as f64;
            let mut r = BTreeMap::new();
            r.insert("round".into(), Json::Num(t as f64));
            r.insert("eval_nll".into(), Json::Num(m.eval_curve[t].mean_nll));
            r.insert("loss_mean".into(), Json::Num(loss_mean));
            r.insert("bytes_up".into(), Json::Num(c.bytes_up as f64));
            r.insert("bytes_down".into(), Json::Num(c.bytes_down as f64));
            r.insert("messages".into(), Json::Num(c.messages as f64));
            r.insert("dropped".into(), Json::Num(c.dropped as f64));
            Json::Obj(r)
        })
        .collect();
    let mut o = BTreeMap::new();
    o.insert("rounds".into(), Json::Arr(rounds));
    o.insert("final_param_l2".into(), Json::Num(report.final_params.l2_norm()));
    o.insert("comm_dropped_total".into(), Json::Num(m.comm_dropped as f64));
    o.insert(
        "drops_per_worker".into(),
        Json::Arr(
            report
                .drops_per_worker
                .iter()
                .map(|&d| Json::Num(d as f64))
                .collect(),
        ),
    );
    Json::Obj(o)
}

fn run_trace(cfg: ExperimentConfig, rt: Arc<Runtime>) -> Json {
    let coord = Coordinator::new(cfg.clone(), rt).unwrap();
    let report = coord.run().unwrap();
    trace_json(&cfg, &report)
}

/// The tier-1 golden check. `#[ignore]`d: needs artifacts; run with
/// `cargo test --release -- --ignored` (locally or in the CI golden job).
#[test]
#[ignore]
fn golden_trace_default_config() {
    let Some(rt) = runtime() else {
        eprintln!("skipping golden trace: run `make artifacts` first");
        return;
    };

    // Two regimes: the bitwise-pinned default, and a seeded drop-injection
    // variant that additionally pins the keyed-drop pattern.
    let mut drops_cfg = tiny_cfg();
    drops_cfg.seed = 11;
    drops_cfg.comm.drop_prob = 0.35;
    let mut traces = BTreeMap::new();
    traces.insert("default".to_string(), run_trace(tiny_cfg(), rt.clone()));
    traces.insert("drops".to_string(), run_trace(drops_cfg, rt));
    let got = Json::Obj(traces);

    let path = golden_path();
    if std::env::var("UPDATE_GOLDEN").is_ok() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.dump() + "\n").unwrap();
        eprintln!("golden trace rewritten at {}", path.display());
        return;
    }
    let Ok(text) = std::fs::read_to_string(&path) else {
        // First run on a machine with artifacts: seed the snapshot so
        // subsequent runs enforce it, and say so loudly.
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.dump() + "\n").unwrap();
        eprintln!(
            "golden trace BOOTSTRAPPED at {} — commit it; future runs enforce it",
            path.display()
        );
        return;
    };
    let want = Json::parse(text.trim()).expect("golden snapshot parses");
    assert_eq!(
        got,
        want,
        "\ndefault-path trace diverged from the golden snapshot.\n\
         If (and only if) this change is intentional, regenerate with\n\
         UPDATE_GOLDEN=1 cargo test --release --test golden_trace -- --ignored\n\
         got:  {}\nwant: {}",
        got.dump(),
        want.dump()
    );
}

/// The golden trace pins the DEFAULT reduction path. `[engine]
/// fast_math` (the opt-in pairwise reduction) reorders float ops and is
/// tolerance-gated, not bitwise — it must stay off in the golden preset,
/// or the snapshot would silently pin the wrong path. The fused chunked
/// kernels themselves are bitwise-equal to the legacy scale/axpy
/// multi-pass (property-pinned in coordinator::average and util::math),
/// so with fast_math off this trace reproduces the pre-optimization
/// (PR 5) trace keys exactly.
#[test]
fn golden_preset_keeps_fast_math_off() {
    assert!(!tiny_cfg().fast_math, "golden preset must pin the default path");
}

/// Runs without artifacts: if a snapshot is checked in, it must parse
/// and have the golden shape (guards against hand-edited snapshots).
#[test]
fn golden_snapshot_schema_if_present() {
    let Ok(text) = std::fs::read_to_string(golden_path()) else {
        return;
    };
    let v = Json::parse(text.trim()).expect("golden snapshot parses");
    for key in ["default", "drops"] {
        let trace = v.get(key).unwrap_or_else(|| panic!("missing trace {key:?}"));
        let rounds = trace.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 3, "{key}: tiny preset runs 3 rounds");
        for r in rounds {
            for field in [
                "round", "eval_nll", "loss_mean", "bytes_up", "bytes_down",
                "messages", "dropped",
            ] {
                assert!(r.get(field).is_some(), "{key}: round missing {field:?}");
            }
        }
        assert!(trace.get("final_param_l2").is_some());
        assert!(trace.get("drops_per_worker").is_some());
    }
}

/// The comparison is only as strong as the serialization: every f64 must
/// survive dump → parse bit-exactly (shortest-roundtrip formatting).
#[test]
fn trace_floats_roundtrip_bit_exactly() {
    for x in [
        0.1f64,
        1.0 / 3.0,
        2.0f64.sqrt(),
        6.02e23,
        1e-17,
        123456789.123456789,
        f64::MIN_POSITIVE,
        4096.0,
    ] {
        let dumped = Json::Num(x).dump();
        let parsed = Json::parse(&dumped).unwrap();
        let Json::Num(y) = parsed else { panic!("not a number: {dumped}") };
        assert_eq!(x.to_bits(), y.to_bits(), "{x} -> {dumped} -> {y}");
    }
}
