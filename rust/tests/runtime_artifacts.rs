//! Integration: every AOT artifact loads, compiles, and executes through
//! the production PJRT path, and the HLO outer step agrees with the
//! rust-native outer optimizer. This is the layer-composition proof the
//! pytest suite cannot give (it never touches xla_extension 0.5.1).

use diloco::config::OuterOptConfig;
use diloco::coordinator::opt::OuterOpt;
use diloco::runtime::{Runtime, Tensors, Value};
use std::sync::Arc;

fn artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

fn runtime(model: &str) -> Option<Arc<Runtime>> {
    let dir = artifacts_dir();
    std::path::Path::new(&dir)
        .join(format!("{model}.manifest.json"))
        .exists()
        .then(|| Arc::new(Runtime::load(&dir, model).expect("runtime loads")))
}

fn batch(rt: &Runtime, steps: usize, shift: i32) -> (Vec<i32>, Vec<i32>) {
    let c = &rt.manifest.config;
    let n = steps * c.batch_size * c.seq_len;
    let vocab = c.vocab_size as i32;
    let tokens: Vec<i32> = (0..n).map(|i| (i as i32 + shift) % vocab).collect();
    let targets: Vec<i32> = (0..n).map(|i| (i as i32 + shift + 1) % vocab).collect();
    (tokens, targets)
}

#[test]
fn every_artifact_executes() {
    let Some(rt) = runtime("nano") else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let n = rt.manifest.params.len();
    let params = rt.init_params().unwrap();
    let zeros = Tensors::zeros(&rt.manifest);
    let (tokens, targets) = batch(&rt, 1, 0);

    // train_step
    let mut inputs = params.to_values();
    inputs.extend(zeros.to_values());
    inputs.extend(zeros.to_values());
    inputs.push(Value::F32(vec![0.0]));
    inputs.push(Value::I32(tokens.clone()));
    inputs.push(Value::I32(targets.clone()));
    let out = rt.execute("train_step", &inputs).unwrap();
    assert_eq!(out.len(), 3 * n + 1);
    let loss = out.last().unwrap().scalar_f32().unwrap();
    assert!(loss.is_finite() && loss > 0.0);

    // eval_step
    let (s, c) = rt.eval_batch(&params, &tokens, &targets).unwrap();
    assert!(s > 0.0 && c > 0.0);

    // grad_step + apply_update
    let mut ginputs = params.to_values();
    ginputs.push(Value::I32(tokens.clone()));
    ginputs.push(Value::I32(targets.clone()));
    let gout = rt.execute("grad_step", &ginputs).unwrap();
    assert_eq!(gout.len(), n + 1);
    let mut ainputs = params.to_values();
    ainputs.extend(zeros.to_values());
    ainputs.extend(zeros.to_values());
    ainputs.extend(gout[..n].iter().cloned());
    ainputs.push(Value::F32(vec![0.0]));
    let aout = rt.execute("apply_update", &ainputs).unwrap();
    assert_eq!(aout.len(), 3 * n);

    // fwd_logits
    let mut finputs = params.to_values();
    finputs.push(Value::I32(tokens));
    let fout = rt.execute("fwd_logits", &finputs).unwrap();
    let cfg = &rt.manifest.config;
    assert_eq!(
        fout[0].as_f32().unwrap().len(),
        cfg.batch_size * cfg.seq_len * cfg.vocab_size
    );

    // outer_step (exercised in depth below)
    assert!(rt.has_artifact("outer_step"));
    // chunked train paths
    assert_eq!(rt.chunk_sizes(), vec![5, 25]);
}

#[test]
fn train_step_and_grad_apply_agree() {
    // The fused train_step must equal grad_step→apply_update exactly
    // (same HLO math, different artifact split).
    let Some(rt) = runtime("nano") else { return };
    let n = rt.manifest.params.len();
    let params = rt.init_params().unwrap();
    let zeros = Tensors::zeros(&rt.manifest);
    let (tokens, targets) = batch(&rt, 1, 3);

    let mut fused_in = params.to_values();
    fused_in.extend(zeros.to_values());
    fused_in.extend(zeros.to_values());
    fused_in.push(Value::F32(vec![7.0]));
    fused_in.push(Value::I32(tokens.clone()));
    fused_in.push(Value::I32(targets.clone()));
    let fused = rt.execute("train_step", &fused_in).unwrap();

    let mut gin = params.to_values();
    gin.push(Value::I32(tokens));
    gin.push(Value::I32(targets));
    let gout = rt.execute("grad_step", &gin).unwrap();
    let mut ain = params.to_values();
    ain.extend(zeros.to_values());
    ain.extend(zeros.to_values());
    ain.extend(gout[..n].iter().cloned());
    ain.push(Value::F32(vec![7.0]));
    let split = rt.execute("apply_update", &ain).unwrap();

    for (i, (a, b)) in fused[..3 * n].iter().zip(&split).enumerate() {
        let (a, b) = (a.as_f32().unwrap(), b.as_f32().unwrap());
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x - y).abs() < 1e-5,
                "output {i} differs: {x} vs {y}"
            );
        }
    }
}

#[test]
fn hlo_outer_step_matches_rust_nesterov() {
    let Some(rt) = runtime("nano") else { return };
    let params = rt.init_params().unwrap();
    let mut delta = params.clone();
    delta.scale(0.01);
    let mut mom = params.clone();
    mom.scale(0.1);
    let (lr, mu) = (0.7f32, 0.9f32);

    // HLO path.
    let mut inputs = params.to_values();
    inputs.extend(delta.to_values());
    inputs.extend(mom.to_values());
    inputs.push(Value::F32(vec![lr]));
    inputs.push(Value::F32(vec![mu]));
    let out = rt.execute("outer_step", &inputs).unwrap();
    let hlo_params = Tensors::from_values(&rt.manifest, out).unwrap();

    // Rust path. Seed the optimizer's momentum with the same state.
    let mut rust_params = params.clone();
    let mut opt = OuterOpt::new(
        &OuterOptConfig::Nesterov { lr, mu },
        &Tensors::zeros(&rt.manifest),
    );
    // First step with a zero delta and pre-seeded momentum is awkward via
    // the public API; replicate the recurrence directly instead:
    // mom' = μ·mom + Δ ; θ' = θ - lr·(Δ + μ·mom')
    let mut mom2 = mom.clone();
    mom2.scale(mu);
    mom2.axpy(1.0, &delta);
    rust_params.axpy(-lr, &delta);
    rust_params.axpy(-lr * mu, &mom2);
    let _ = &mut opt;

    for (a, b) in hlo_params.leaves().iter().zip(rust_params.leaves()) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5, "HLO vs rust outer step: {x} vs {y}");
        }
    }
}

#[test]
fn pallas_artifacts_match_ref_artifacts() {
    // The composition proof: a model built through the Pallas kernels
    // (interpret-lowered) must agree numerically with the ref build.
    let (Some(rt_ref), Some(rt_pal)) = (runtime("nano"), runtime("nano_pallas")) else {
        eprintln!("skipping: nano_pallas artifacts not built");
        return;
    };
    assert_eq!(rt_pal.manifest.config.kernels, "pallas");
    let params = rt_ref.init_params().unwrap();
    let params_pal = rt_pal.init_params().unwrap();
    // Same seed at lowering time ⇒ identical init.
    for (a, b) in params.leaves().iter().zip(params_pal.leaves()) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-6, "init differs: {x} vs {y}");
        }
    }

    let (tokens, targets) = batch(&rt_ref, 1, 11);
    let (s_ref, c_ref) = rt_ref.eval_batch(&params, &tokens, &targets).unwrap();
    let (s_pal, c_pal) = rt_pal.eval_batch(&params, &tokens, &targets).unwrap();
    assert_eq!(c_ref, c_pal);
    assert!(
        ((s_ref - s_pal) / c_ref).abs() < 1e-3,
        "pallas vs ref eval nll: {} vs {}",
        s_ref / c_ref,
        s_pal / c_pal
    );

    // One train step through each build.
    let zeros = Tensors::zeros(&rt_ref.manifest);
    let run = |rt: &Runtime| -> (f32, Tensors) {
        let mut inputs = params.to_values();
        inputs.extend(zeros.to_values());
        inputs.extend(zeros.to_values());
        inputs.push(Value::F32(vec![0.0]));
        inputs.push(Value::I32(tokens.clone()));
        inputs.push(Value::I32(targets.clone()));
        let out = rt.execute("train_step", &inputs).unwrap();
        let loss = out.last().unwrap().scalar_f32().unwrap();
        let p = Tensors::from_values(&rt.manifest, out).unwrap();
        (loss, p)
    };
    let (l_ref, p_ref) = run(&rt_ref);
    let (l_pal, p_pal) = run(&rt_pal);
    assert!((l_ref - l_pal).abs() < 1e-3, "loss: {l_ref} vs {l_pal}");
    let mut max_d = 0f32;
    for (a, b) in p_ref.leaves().iter().zip(p_pal.leaves()) {
        for (x, y) in a.iter().zip(b) {
            max_d = max_d.max((x - y).abs());
        }
    }
    assert!(max_d < 1e-3, "param drift after 1 step: {max_d}");
}
