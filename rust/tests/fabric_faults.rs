//! Fault-injection suite for the TCP fabric (DESIGN.md §14): kill or
//! hang real worker processes mid-run and assert the coordinator
//! degrades into the existing `[churn]`/drop machinery — bounded by the
//! configured timeouts — instead of crashing or hanging the round.
//!
//! The faults are injected through `fabric.spawn_extra`: per-slot argv
//! appended to the spawned `diloco worker` processes (`--die-after-phases`,
//! `--die-mid-phase`, `--hang-mid-phase`). A respawned replacement
//! inherits its slot's flags, so a die-after worker also exercises the
//! leave → respawn → rejoin cycle.
//!
//! Needs the AOT artifacts (`make artifacts`), hence `#[ignore]`; CI
//! runs it via `cargo test --release --test fabric_faults -- --ignored`
//! (the fabric-equivalence job).

use diloco::config::{ComputeSchedule, ExperimentConfig, FabricKind};
use diloco::coordinator::{Coordinator, DilocoReport};
use diloco::runtime::Runtime;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

fn runtime() -> Option<Arc<Runtime>> {
    let dir = artifacts_dir();
    std::path::Path::new(&dir)
        .join("nano.manifest.json")
        .exists()
        .then(|| Arc::new(Runtime::load(&dir, "nano").unwrap()))
}

/// Tiny loopback-TCP preset: 2 workers × 3 rounds × 5 inner steps,
/// drop-free, workers spawned from this build's own binary.
fn tcp_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(&artifacts_dir(), "nano");
    cfg.seed = 0;
    cfg.workers = 2;
    cfg.schedule = ComputeSchedule::Constant(2);
    cfg.inner_steps = 5;
    cfg.rounds = 3;
    cfg.pretrain_steps = 0;
    cfg.eval_every_rounds = 1;
    cfg.eval_batches = 1;
    cfg.data.n_docs = 60;
    cfg.data.doc_len = 120;
    cfg.fabric.kind = FabricKind::Tcp;
    cfg.fabric.host = "127.0.0.1".to_string();
    cfg.fabric.port = 0;
    cfg.fabric.spawn = true;
    cfg.fabric.worker_bin = Some(env!("CARGO_BIN_EXE_diloco").to_string());
    cfg
}

/// Inject per-slot worker argv (slot 1 gets `flag value`).
fn fault_on_slot_1(mut cfg: ExperimentConfig, flag: &str, value: &str) -> ExperimentConfig {
    cfg.fabric.spawn_extra = vec![
        Vec::new(),
        vec![flag.to_string(), value.to_string()],
    ];
    cfg
}

fn run(cfg: ExperimentConfig, rt: Arc<Runtime>) -> DilocoReport {
    Coordinator::new(cfg, rt).unwrap().run().unwrap()
}

fn active_per_round(report: &DilocoReport) -> Vec<usize> {
    report.round_stats.iter().map(|rs| rs.active_workers).collect()
}

/// Worker 1 exits cleanly after replying to its first phase. The
/// coordinator's next-round heartbeat books it as a `[churn]` leave,
/// respawns the slot, and the replacement rejoins one round later — the
/// full leave/rejoin cycle, with every round still producing an outer
/// step.
#[test]
#[ignore]
fn clean_worker_death_books_churn_leave_and_rejoin() {
    let Some(rt) = runtime() else {
        eprintln!("skipping fabric faults: run `make artifacts` first");
        return;
    };
    let cfg = fault_on_slot_1(tcp_cfg(), "--die-after-phases", "1");
    let report = run(cfg, rt);
    // Round 0: both run (then worker 1 exits). Round 1: heartbeat books
    // the leave → solo round. Round 2: the respawn has rejoined. (The
    // replacement inherits the flag, so it exits again after round 2 —
    // past the end of the run.)
    assert_eq!(active_per_round(&report), vec![2, 1, 2]);
    // The death was clean (after the reply): no sync was ever dropped.
    assert_eq!(report.drops_per_worker, vec![0, 0]);
    assert_eq!(report.metrics.loss_curve.len(), 3 * 5);
    assert!(report.final_params.all_finite());
}

/// Worker 1 exits *without replying* on its second phase (round 1): the
/// phase books it as vanished — its sync is a drop, its loss rows are
/// excluded — and the round completes on the survivor. The next round's
/// heartbeat turns the dead socket into a churn leave + respawn.
#[test]
#[ignore]
fn mid_phase_death_is_a_drop_not_a_crash() {
    let Some(rt) = runtime() else {
        eprintln!("skipping fabric faults: run `make artifacts` first");
        return;
    };
    let cfg = fault_on_slot_1(tcp_cfg(), "--die-mid-phase", "1");
    let report = run(cfg, rt);
    // Round 1 starts with both alive (the death happens inside the
    // phase), round 2 books the leave; the respawned replacement dies on
    // *its* second phase, which never comes in a 3-round run.
    assert_eq!(active_per_round(&report), vec![2, 2, 1]);
    assert_eq!(report.drops_per_worker, vec![0, 1], "the vanish books as a drop");
    assert_eq!(report.metrics.loss_curve.len(), 3 * 5);
    assert!(report.final_params.all_finite());
}

/// Worker 1 hangs forever inside its second phase: the configured
/// `phase_timeout_s` bounds the stall, the hang books exactly like a
/// mid-phase death (vanish → drop → churn leave → respawn), and the
/// whole run finishes in bounded time instead of deadlocking.
#[test]
#[ignore]
fn hung_worker_is_bounded_by_phase_timeout() {
    let Some(rt) = runtime() else {
        eprintln!("skipping fabric faults: run `make artifacts` first");
        return;
    };
    let mut cfg = fault_on_slot_1(tcp_cfg(), "--hang-mid-phase", "1");
    // Generous enough for a real nano phase on a slow runner, small
    // enough that the test proves the bound.
    cfg.fabric.phase_timeout_s = 20.0;
    let t0 = Instant::now();
    let report = run(cfg, rt);
    assert!(
        t0.elapsed() < Duration::from_secs(240),
        "run took {:?} — the phase timeout did not bound the hung worker",
        t0.elapsed()
    );
    assert_eq!(active_per_round(&report), vec![2, 2, 1]);
    assert_eq!(report.drops_per_worker, vec![0, 1]);
    assert!(report.final_params.all_finite());
}
