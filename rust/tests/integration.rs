//! End-to-end integration: the full DiLoCo stack over real artifacts,
//! metamorphic algorithm identities, and the checkpoint round-trip.

use diloco::checkpoint;
use diloco::comm::codec::Codec;
use diloco::config::{
    AdversaryConfig, AggregateConfig, ChurnConfig, ComputeSchedule, EngineConfig,
    ExperimentConfig, OuterOptConfig, SpeedConfig, StreamConfig, SyncConfig,
    SyncSchedule, TopologyConfig,
};
use diloco::coordinator::Coordinator;
use diloco::data::batch::BatchIter;
use diloco::metrics::RunMetrics;
use diloco::runtime::{Runtime, Tensors};
use diloco::util::rng::Rng;
use diloco::worker::Worker;
use std::sync::Arc;

fn artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

fn runtime() -> Option<Arc<Runtime>> {
    let dir = artifacts_dir();
    std::path::Path::new(&dir)
        .join("nano.manifest.json")
        .exists()
        .then(|| Arc::new(Runtime::load(&dir, "nano").unwrap()))
}

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(&artifacts_dir(), "nano");
    cfg.workers = 4;
    cfg.schedule = ComputeSchedule::Constant(4);
    cfg.inner_steps = 10;
    cfg.rounds = 4;
    cfg.pretrain_steps = 10;
    cfg.eval_batches = 2;
    cfg.data.n_docs = 120;
    cfg.data.doc_len = 140;
    cfg
}

#[test]
fn diloco_learns_end_to_end() {
    let Some(rt) = runtime() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let coord = Coordinator::new(small_cfg(), rt).unwrap();
    let report = coord.run().unwrap();
    let m = &report.metrics;
    // The model must actually learn the synthetic language.
    let first = m.eval_curve.first().unwrap().ppl;
    let last = m.final_ppl();
    assert!(
        last < first * 0.8,
        "no learning: first ppl {first}, final {last}"
    );
    // Loss curve covers pretrain + all rounds.
    assert_eq!(m.loss_curve.len(), 10 + 4 * 10);
    // Communication exactly k×T up + k×T down messages.
    assert_eq!(m.comm_messages, 2 * 4 * 4);
    // Coordinator (non-compute) overhead must stay small even at nano
    // scale — the §Perf L3 target (<15% here; <5% at micro+).
    assert!(
        m.phases.overhead_fraction() < 0.35,
        "coordinator overhead {:.1}%",
        100.0 * m.phases.overhead_fraction()
    );
}

#[test]
fn sgd_lr1_k1_round_equals_worker_trajectory() {
    // Metamorphic identity: with k=1 and OuterOpt = SGD(lr=1),
    // θ(t) = θ(t-1) - 1·(θ(t-1) - θ_worker) = θ_worker — DiLoCo reduces
    // to the worker's own trajectory ("souping" degenerate case).
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.workers = 1;
    cfg.schedule = ComputeSchedule::Constant(1);
    cfg.outer_opt = OuterOptConfig::Sgd { lr: 1.0 };
    cfg.pretrain_steps = 0;
    cfg.comm.drop_prob = 0.0;
    let coord = Coordinator::new(cfg.clone(), rt.clone()).unwrap();
    let init = rt.init_params().unwrap();
    let report = coord.run_from(Some(init.clone())).unwrap();

    // Replicate the single worker's trajectory by hand: same shard, same
    // rng stream (worker 0 uses seed child(100)), same step offset.
    let mcfg = &rt.manifest.config;
    let mut w = Worker::new(
        0,
        init,
        Tensors::zeros(&rt.manifest),
        BatchIter::new(
            coord.dataset.shards[0].clone(),
            mcfg.batch_size,
            mcfg.seq_len,
            cfg.rng().child(100),
        ),
    );
    let mut losses = Vec::new();
    w.run_inner_steps(&rt, cfg.rounds * cfg.inner_steps, &mut losses)
        .unwrap();
    for (a, b) in report.final_params.leaves().iter().zip(w.params.leaves()) {
        for (x, y) in a.iter().zip(b) {
            assert!(
                (x - y).abs() < 1e-5,
                "k=1 SGD(lr=1) DiLoCo must equal the raw trajectory: {x} vs {y}"
            );
        }
    }
}

#[test]
fn nesterov_beats_frozen_model() {
    // Sanity on optimizer direction: one DiLoCo run must end with lower
    // eval nll than the frozen pretrained model.
    let Some(rt) = runtime() else { return };
    let cfg = small_cfg();
    let coord = Coordinator::new(cfg, rt.clone()).unwrap();
    let init = rt.init_params().unwrap();
    let frozen = coord.evaluate(&init).unwrap();
    let report = coord.run_from(Some(init)).unwrap();
    assert!(report.metrics.final_nll() < frozen.mean_nll - 0.3);
}

#[test]
fn checkpoint_roundtrip_through_training() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 1;
    cfg.pretrain_steps = 0;
    let coord = Coordinator::new(cfg, rt.clone()).unwrap();
    let report = coord.run().unwrap();
    let path = std::env::temp_dir().join("diloco_integration.ckpt");
    let path = path.to_str().unwrap();
    checkpoint::save(path, &rt.manifest, &report.final_params).unwrap();
    let loaded = checkpoint::load(path, &rt.manifest).unwrap();
    assert_eq!(&loaded, &report.final_params);
    // Evaluation of the reloaded params must match exactly.
    let a = coord.evaluate(&report.final_params).unwrap();
    let b = coord.evaluate(&loaded).unwrap();
    assert_eq!(a.mean_nll, b.mean_nll);
    std::fs::remove_file(path).ok();
}

#[test]
fn weighted_vs_uniform_average_differ_on_imbalanced_shards() {
    // With heavily imbalanced non-iid shards, §6.1 weighting must change
    // the outcome (guards against weights being silently dropped).
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.workers = 2;
    cfg.schedule = ComputeSchedule::Constant(2);
    cfg.rounds = 2;
    cfg.pretrain_steps = 0;
    cfg.data.n_topics = 2;
    cfg.data.n_docs = 90; // topic imbalance comes from doc lengths
    cfg.data.doc_len = 100;
    cfg.data.mix = 0.4; // reassignments create count imbalance
    cfg.seed = 3;

    let mut uniform_cfg = cfg.clone();
    uniform_cfg.weighted_average = false;
    let init = rt.init_params().unwrap();

    let weighted = Coordinator::new(cfg, rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    let uniform = Coordinator::new(uniform_cfg, rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    let max_diff = weighted
        .final_params
        .leaves()
        .iter()
        .zip(uniform.final_params.leaves())
        .flat_map(|(a, b)| a.iter().zip(b).map(|(x, y)| (x - y).abs()))
        .fold(0f32, f32::max);
    assert!(
        max_diff > 1e-6,
        "weighted averaging had no effect on imbalanced shards"
    );
}

#[test]
fn drop_injection_is_seeded_and_counted() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.comm.drop_prob = 0.5;
    cfg.rounds = 6;
    cfg.pretrain_steps = 0;
    cfg.seed = 11;
    let r1 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();
    let r2 = Coordinator::new(cfg, rt).unwrap().run().unwrap();
    assert_eq!(r1.drops_per_worker, r2.drops_per_worker);
    let total: usize = r1.drops_per_worker.iter().sum();
    assert_eq!(total as u64, r1.metrics.comm_dropped);
    // 4 workers × 6 rounds × p=0.5 ⇒ expect drops, but not all 24.
    assert!(total > 0 && total < 24, "drops {total}");
}

#[test]
fn pruning_reduces_billed_communication() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 2;
    cfg.pretrain_steps = 0;
    let init = rt.init_params().unwrap();
    let full = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    cfg.prune_frac = 0.75;
    let pruned = Coordinator::new(cfg, rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    // Uploads shrink to ~28% (25% values + bitmap); downloads (full
    // parameter broadcast) are unchanged, so total lands near 64%.
    assert!(
        (pruned.metrics.comm_bytes as f64) < 0.72 * full.metrics.comm_bytes as f64,
        "75% pruning must cut upload bytes: {} vs {}",
        pruned.metrics.comm_bytes,
        full.metrics.comm_bytes
    );
    // …and the model still learns.
    assert!(pruned.metrics.final_ppl().is_finite());
}

#[test]
fn micro_model_composes_too() {
    // Second artifact set (table 4 path): one short run on micro.
    let dir = artifacts_dir();
    if !std::path::Path::new(&dir).join("micro.manifest.json").exists() {
        eprintln!("skipping: micro artifacts not built");
        return;
    }
    let rt = Arc::new(Runtime::load(&dir, "micro").unwrap());
    let mut cfg = ExperimentConfig::paper_default(&dir, "micro");
    cfg.workers = 2;
    cfg.schedule = ComputeSchedule::Constant(2);
    cfg.inner_steps = 5;
    cfg.rounds = 1;
    cfg.pretrain_steps = 0;
    cfg.eval_batches = 1;
    cfg.data.n_docs = 80;
    cfg.data.doc_len = 200;
    let coord = Coordinator::new(cfg, rt).unwrap();
    let report = coord.run().unwrap();
    assert!(report.metrics.final_ppl().is_finite());
    assert_eq!(report.metrics.loss_curve.len(), 5);
}

#[test]
fn parallel_matches_sequential_bitwise() {
    // The engine acceptance criterion: ParallelIslands must reproduce the
    // Sequential reference path *bitwise* — final params, loss curves,
    // and communication outcomes — for a k=4 run with drop injection
    // (keyed drops are what make this possible under reordering).
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.comm.drop_prob = 0.3;
    cfg.seed = 5;
    let init = rt.init_params().unwrap();

    let run = |engine: EngineConfig| {
        let mut cfg = cfg.clone();
        cfg.engine = engine;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let seq = run(EngineConfig::Sequential);
    for threads in [0, 2, 4] {
        let par = run(EngineConfig::Parallel { threads });
        assert_eq!(
            par.final_params, seq.final_params,
            "threads={threads}: final params diverged"
        );
        assert_eq!(
            par.metrics.loss_curve, seq.metrics.loss_curve,
            "threads={threads}: loss curves diverged"
        );
        assert_eq!(par.metrics.eval_curve.len(), seq.metrics.eval_curve.len());
        for (a, b) in par.metrics.eval_curve.iter().zip(&seq.metrics.eval_curve) {
            assert_eq!(a.mean_nll, b.mean_nll, "threads={threads}: eval diverged");
        }
        assert_eq!(par.metrics.comm_messages, seq.metrics.comm_messages);
        assert_eq!(par.metrics.comm_bytes, seq.metrics.comm_bytes);
        assert_eq!(par.metrics.comm_dropped, seq.metrics.comm_dropped);
        assert_eq!(par.drops_per_worker, seq.drops_per_worker);
        assert_eq!(par.round_stats.len(), seq.round_stats.len());
    }
}

#[test]
fn fragmented_every_round_matches_monolithic_bitwise() {
    // The streaming acceptance criterion, one level up from the unit
    // props: with the every-round schedule, the f32 codec, and no drops,
    // fragmenting the sync must be invisible — final params, losses, and
    // eval points bitwise equal to the monolithic P=1 run; only message
    // granularity (and not byte totals) may change.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 3;
    let init = rt.init_params().unwrap();
    let run = |fragments: usize| {
        let mut cfg = cfg.clone();
        cfg.stream.fragments = fragments;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let mono = run(1);
    for p in [2, 4, 7] {
        let frag = run(p);
        assert_eq!(
            frag.final_params, mono.final_params,
            "P={p}: final params diverged"
        );
        assert_eq!(frag.metrics.loss_curve, mono.metrics.loss_curve);
        for (a, b) in frag
            .metrics
            .eval_curve
            .iter()
            .zip(&mono.metrics.eval_curve)
        {
            assert_eq!(a.mean_nll, b.mean_nll, "P={p}: eval diverged");
        }
        assert_eq!(frag.metrics.comm_bytes_up, mono.metrics.comm_bytes_up);
        assert_eq!(frag.metrics.comm_bytes, mono.metrics.comm_bytes);
        assert_eq!(
            frag.metrics.comm_messages,
            mono.metrics.comm_messages * p as u64,
            "P={p}: one message per fragment in each direction"
        );
        assert_eq!(frag.metrics.codec_err_l2, 0.0);
        for rs in &frag.round_stats {
            assert_eq!(rs.fragments_synced, p);
        }
        // Fragmenting must never *reduce* the simulated barrier: one
        // worker's fragments serialize on its link, so P messages cost
        // the monolithic serialization plus P-1 extra latencies.
        assert!(
            frag.metrics.sim_comm_seconds > mono.metrics.sim_comm_seconds,
            "P={p}: {} vs {}",
            frag.metrics.sim_comm_seconds,
            mono.metrics.sim_comm_seconds
        );
    }
}

#[test]
fn staggered_schedule_cuts_per_round_bytes() {
    // staggered(P) ships one fragment (≈1/P of the model) per round in
    // each direction, so total bytes shrink by ≈P× — while the run still
    // learns and every fragment keeps syncing, once every P rounds.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 8; // two full staggered cycles at P=4
    let init = rt.init_params().unwrap();
    let baseline = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    cfg.stream = StreamConfig {
        fragments: 4,
        schedule: SyncSchedule::Staggered,
        codec: Codec::F32,
        error_feedback: false,
    };
    let stag = Coordinator::new(cfg, rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    let (b, s) = (
        baseline.metrics.comm_bytes_up as f64,
        stag.metrics.comm_bytes_up as f64,
    );
    assert!(
        s < 0.30 * b,
        "staggered(4) must cut upload bytes ≈4×: {s} vs {b}"
    );
    assert!(stag.metrics.final_ppl().is_finite());
    assert_eq!(stag.round_stats.len(), 8);
    for rs in &stag.round_stats {
        assert_eq!(rs.fragments_synced, 1, "one fragment per staggered round");
    }
}

#[test]
fn q8_codec_cuts_bytes_and_reports_error() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 2;
    cfg.pretrain_steps = 0;
    let init = rt.init_params().unwrap();
    let f32_run = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    cfg.stream.codec = Codec::Q8;
    cfg.stream.fragments = 4;
    let q8 = Coordinator::new(cfg, rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    // Uploads shrink to ~1/4 (1 byte/element + per-slice sidecars);
    // downloads stay full precision, so totals land in between.
    assert!(
        (q8.metrics.comm_bytes_up as f64) < 0.30 * f32_run.metrics.comm_bytes_up as f64,
        "q8 upload bytes: {} vs {}",
        q8.metrics.comm_bytes_up,
        f32_run.metrics.comm_bytes_up
    );
    assert!(q8.metrics.up_savings_factor() > 3.0);
    // Lossy encoding is accounted: a deterministic, nonzero error per
    // synced round, and the run still trains to a finite perplexity.
    assert!(q8.metrics.codec_err_l2 > 0.0);
    for rs in &q8.round_stats {
        assert!(rs.codec_err_l2 > 0.0, "round {}", rs.round);
    }
    assert!(f32_run.metrics.codec_err_l2 == 0.0);
    assert!(q8.metrics.final_ppl().is_finite());
    assert!(q8.final_params.all_finite());
}

#[test]
fn overlapped_schedule_hides_barrier_not_math() {
    // Overlapped streaming changes *accounting only*: the sync math is
    // every-round, so params match the default bitwise, while the
    // simulated communication barrier nearly vanishes (deferred
    // transfers hide behind the next round's compute; only the final
    // round's transfer remains a barrier).
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.pretrain_steps = 0;
    let init = rt.init_params().unwrap();
    let blocking = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    cfg.stream.schedule = SyncSchedule::Overlapped;
    let overlapped = Coordinator::new(cfg.clone(), rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    assert_eq!(overlapped.final_params, blocking.final_params);
    assert_eq!(overlapped.metrics.loss_curve, blocking.metrics.loss_curve);
    assert_eq!(overlapped.metrics.comm_bytes, blocking.metrics.comm_bytes);
    assert!(
        overlapped.metrics.sim_comm_seconds < blocking.metrics.sim_comm_seconds / 2.0,
        "overlap must hide most of the barrier: {} vs {}",
        overlapped.metrics.sim_comm_seconds,
        blocking.metrics.sim_comm_seconds
    );
    // Billing rows: every deferred round records zero barrier; the final
    // round has no next phase to hide behind, so it closes as a barrier.
    let rows = &overlapped.comm_per_round;
    assert!(rows[..rows.len() - 1].iter().all(|r| r.barrier_s == 0.0));
    assert!(rows.last().unwrap().barrier_s > 0.0);
    assert!(blocking.comm_per_round.iter().all(|r| r.barrier_s > 0.0));
    // Per-round barrier rows account for the whole barrier bill.
    let row_sum: f64 = rows.iter().map(|r| r.barrier_s).sum();
    assert!((row_sum - overlapped.metrics.sim_comm_seconds).abs() < 1e-12);
}

#[test]
fn fragment_drops_desync_independently() {
    // With P=2 and heavy drops, a worker can lose one fragment and land
    // the other; per-fragment desync must keep every run deterministic
    // and the drop totals consistent between report and fabric.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.comm.drop_prob = 0.5;
    cfg.pretrain_steps = 0;
    cfg.rounds = 6;
    cfg.seed = 9;
    cfg.stream.fragments = 2;
    let r1 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();
    let r2 = Coordinator::new(cfg, rt).unwrap().run().unwrap();
    assert_eq!(r1.final_params, r2.final_params);
    assert_eq!(r1.drops_per_worker, r2.drops_per_worker);
    assert_eq!(r1.metrics.comm_dropped, r2.metrics.comm_dropped);
    // Fragment messages dropped ≥ worker-rounds affected (a worker-round
    // can lose both fragments).
    let worker_rounds: usize = r1.drops_per_worker.iter().sum();
    assert!(r1.metrics.comm_dropped as usize >= worker_rounds);
    assert!(worker_rounds > 0, "p=0.5 over 48 fragment sends must drop some");
    assert!(r1.metrics.final_ppl().is_finite());
}

#[test]
fn star_topology_is_the_pr2_loop_bitwise() {
    // `topology = "star"` must be *the* monolithic coordinator loop —
    // same math, same billing, same drop keys — not a reimplementation:
    // an explicitly-parsed star config reproduces the default config's
    // run trace bitwise, drops included (the golden-trace suite pins the
    // same path against its snapshot).
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.comm.drop_prob = 0.3;
    cfg.seed = 7;
    let default_run = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();
    cfg.topology = TopologyConfig::parse("star").unwrap();
    let star = Coordinator::new(cfg, rt).unwrap().run().unwrap();
    assert_eq!(star.final_params, default_run.final_params);
    assert_eq!(star.metrics.loss_curve, default_run.metrics.loss_curve);
    assert_eq!(star.metrics.comm_bytes, default_run.metrics.comm_bytes);
    assert_eq!(star.metrics.comm_messages, default_run.metrics.comm_messages);
    assert_eq!(star.drops_per_worker, default_run.drops_per_worker);
    assert_eq!(star.comm_per_round, default_run.comm_per_round);
    assert!(star.replica_params.is_empty() && star.replica_evals.is_empty());
}

#[test]
fn ring_replicas_match_star_bitwise() {
    // The topology acceptance criterion: with no drops and the exact
    // codec, the ring all-reduce computes the same weighted average as
    // the star through the same scalar-op order, so every ring replica
    // must equal the star's global model *bitwise* — only the billing
    // pattern (2(k−1) chunked hops, no hub, no broadcast) differs.
    let Some(rt) = runtime() else { return };
    let cfg = small_cfg();
    let init = rt.init_params().unwrap();
    let run = |topology: TopologyConfig| {
        let mut cfg = cfg.clone();
        cfg.topology = topology;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let star = run(TopologyConfig::Star);
    let ring = run(TopologyConfig::Ring);
    assert_eq!(ring.replica_params.len(), 4);
    for (r, params) in ring.replica_params.iter().enumerate() {
        assert_eq!(params, &star.final_params, "replica {r} diverged from star");
    }
    assert_eq!(ring.metrics.loss_curve, star.metrics.loss_curve);
    assert_eq!(ring.replica_evals.len(), 4);
    // Identical replicas ⇒ consensus distance is float noise at most.
    for rs in &ring.round_stats {
        assert!(rs.consensus_dist < 1e-4, "round {}: {}", rs.round, rs.consensus_dist);
    }
    assert!(star.round_stats.iter().all(|rs| rs.consensus_dist == 0.0));
    // Billing: 2(k−1) chunk hops per worker per round, nothing down.
    let payload = rt.manifest.param_bytes() as u64;
    let (k, rounds) = (4u64, cfg.rounds as u64);
    assert_eq!(ring.metrics.comm_bytes_up, rounds * 2 * (k - 1) * payload);
    assert_eq!(ring.metrics.comm_bytes, ring.metrics.comm_bytes_up);
    assert_eq!(ring.metrics.comm_messages, rounds * 2 * (k - 1) * k);
    assert_eq!(ring.metrics.comm_dropped, 0);
}

#[test]
fn gossip_halves_star_traffic_and_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 6;
    cfg.topology = TopologyConfig::Gossip;
    let init = rt.init_params().unwrap();
    let r1 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    let r2 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    assert_eq!(r1.final_params, r2.final_params, "gossip pairing must be seeded");
    assert_eq!(r1.metrics.loss_curve, r2.metrics.loss_curve);
    // Each of the k workers sends its payload to its partner once per
    // round; nothing is broadcast back — exactly half the star's bytes
    // (star: k up + k down).
    let payload = rt.manifest.param_bytes() as u64;
    let (k, rounds) = (4u64, cfg.rounds as u64);
    assert_eq!(r1.metrics.comm_bytes_up, rounds * k * payload);
    assert_eq!(r1.metrics.comm_bytes, r1.metrics.comm_bytes_up, "no downloads");
    assert_eq!(r1.metrics.comm_messages, rounds * k);
    // Pairwise-only mixing leaves genuine disagreement between replicas.
    assert!(r1.round_stats.last().unwrap().consensus_dist > 0.0);
    assert_eq!(r1.replica_params.len(), 4);
    assert_eq!(r1.replica_evals.len(), 4);
    assert!(r1.metrics.final_ppl().is_finite());
    for p in &r1.replica_evals {
        assert!(p.ppl.is_finite());
    }
    assert!(r1.final_params.all_finite());
}

#[test]
fn gossip_drops_are_keyed_and_counted() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.topology = TopologyConfig::Gossip;
    cfg.comm.drop_prob = 0.5;
    cfg.pretrain_steps = 0;
    cfg.rounds = 6;
    cfg.seed = 9;
    let r1 = Coordinator::new(cfg.clone(), rt.clone()).unwrap().run().unwrap();
    let r2 = Coordinator::new(cfg, rt).unwrap().run().unwrap();
    assert_eq!(r1.final_params, r2.final_params);
    assert_eq!(r1.drops_per_worker, r2.drops_per_worker);
    // One send per worker per round (P = 1), so dropped messages and
    // per-worker drop rounds tally exactly.
    let total: usize = r1.drops_per_worker.iter().sum();
    assert_eq!(total as u64, r1.metrics.comm_dropped);
    assert!(total > 0 && total < 24, "p=0.5 over 24 sends: {total}");
    assert!(r1.metrics.final_ppl().is_finite());
}

#[test]
fn hierarchical_matches_star_math_with_fewer_wan_bytes() {
    // DiLoCoX's two-level sync changes *routing only*: with no drops the
    // contributor set and the flat weighted average are identical to
    // star, so params and curves match bitwise while the billed WAN
    // carries G leader flows instead of k worker flows.
    let Some(rt) = runtime() else { return };
    let cfg = small_cfg();
    let init = rt.init_params().unwrap();
    let run = |topology: TopologyConfig| {
        let mut cfg = cfg.clone();
        cfg.topology = topology;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let star = run(TopologyConfig::Star);
    let hier = run(TopologyConfig::Hierarchical { groups: 2 });
    assert_eq!(hier.final_params, star.final_params);
    assert_eq!(hier.metrics.loss_curve, star.metrics.loss_curve);
    for (a, b) in hier.metrics.eval_curve.iter().zip(&star.metrics.eval_curve) {
        assert_eq!(a.mean_nll, b.mean_nll);
    }
    let payload = rt.manifest.param_bytes() as u64;
    let (g, rounds) = (2u64, cfg.rounds as u64);
    assert_eq!(hier.metrics.comm_bytes_up, rounds * g * payload);
    assert_eq!(hier.metrics.comm_bytes, rounds * 2 * g * payload);
    assert_eq!(hier.metrics.comm_messages, rounds * 2 * g);
    assert!(hier.metrics.comm_bytes < star.metrics.comm_bytes);
    assert!(hier.replica_params.is_empty(), "centralized: one global replica");
}

#[test]
fn hierarchical_leader_drop_desyncs_whole_group() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.topology = TopologyConfig::Hierarchical { groups: 2 };
    cfg.comm.drop_prob = 0.5;
    cfg.pretrain_steps = 0;
    cfg.rounds = 6;
    cfg.seed = 11;
    let r1 = Coordinator::new(cfg.clone(), rt.clone()).unwrap().run().unwrap();
    let r2 = Coordinator::new(cfg, rt).unwrap().run().unwrap();
    assert_eq!(r1.final_params, r2.final_params);
    assert_eq!(r1.drops_per_worker, r2.drops_per_worker);
    // Groups are [0,1] and [2,3]: a dropped leader hop affects every
    // member of its group identically.
    assert_eq!(r1.drops_per_worker[0], r1.drops_per_worker[1]);
    assert_eq!(r1.drops_per_worker[2], r1.drops_per_worker[3]);
    // Each dropped leader message counts against both group members.
    let total: usize = r1.drops_per_worker.iter().sum();
    assert_eq!(total as u64, 2 * r1.metrics.comm_dropped);
    assert!(total > 0, "p=0.5 over 12 leader hops must drop some");
    assert!(r1.metrics.final_ppl().is_finite());
}

#[test]
fn gossip_composes_with_staggered_fragments() {
    // Topology × streaming: gossip over a staggered 2-fragment schedule
    // ships one fragment per worker per round and stays deterministic.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.topology = TopologyConfig::Gossip;
    cfg.stream = StreamConfig {
        fragments: 2,
        schedule: SyncSchedule::Staggered,
        codec: Codec::F32,
        error_feedback: false,
    };
    cfg.rounds = 4;
    let init = rt.init_params().unwrap();
    let r1 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    let r2 = Coordinator::new(cfg.clone(), rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    assert_eq!(r1.final_params, r2.final_params);
    // One due fragment per round ⇒ k messages per round, ≈half the
    // payload each round; totals must cover ~1/2 of a full-sync run.
    assert_eq!(r1.metrics.comm_messages, 4 * 4);
    let full = 4u64 * 4 * rt.manifest.param_bytes() as u64;
    assert!(
        r1.metrics.comm_bytes_up < full * 6 / 10,
        "staggered(2) gossip: {} vs full {}",
        r1.metrics.comm_bytes_up,
        full
    );
    assert!(r1.metrics.final_ppl().is_finite());
    for rs in &r1.round_stats {
        assert_eq!(rs.fragments_synced, 1);
    }
}

fn tmp_state_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("diloco_state_{tag}_{}.bin", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// Assert two reports agree bitwise on params and the given eval tail.
fn assert_bitwise_tail(
    straight: &diloco::coordinator::DilocoReport,
    resumed: &diloco::coordinator::DilocoReport,
    tail_evals: usize,
    what: &str,
) {
    assert_eq!(
        resumed.final_params, straight.final_params,
        "{what}: resumed final params diverged"
    );
    let s_tail =
        &straight.metrics.eval_curve[straight.metrics.eval_curve.len() - tail_evals..];
    let r_tail =
        &resumed.metrics.eval_curve[resumed.metrics.eval_curve.len() - tail_evals..];
    for (a, b) in s_tail.iter().zip(r_tail) {
        assert_eq!(a.step, b.step, "{what}: eval steps diverged");
        assert_eq!(a.mean_nll, b.mean_nll, "{what}: eval nll diverged");
    }
    assert_eq!(
        resumed.drops_per_worker, straight.drops_per_worker,
        "{what}: drop history diverged (it is checkpointed)"
    );
}

#[test]
fn resume_matches_straight_run_bitwise_star() {
    // THE determinism contract (DESIGN.md §10): 2R rounds straight ==
    // R rounds + TrainState checkpoint + resume for R more, bit for bit
    // — with Nesterov momentum, per-worker AdamW state, RNG cursors, and
    // keyed drop injection all crossing the save/load boundary.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    cfg.comm.drop_prob = 0.3;
    cfg.seed = 5;

    let straight = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();

    let path = tmp_state_path("star");
    let mut saver_cfg = cfg.clone();
    saver_cfg.rounds = 2;
    saver_cfg.ckpt.save_every = 2;
    saver_cfg.ckpt.path = Some(path.clone());
    let saver = Coordinator::new(saver_cfg, rt.clone()).unwrap().run().unwrap();
    // Saving must not perturb the first R rounds.
    assert_eq!(
        &saver.metrics.loss_curve[..],
        &straight.metrics.loss_curve[..saver.metrics.loss_curve.len()]
    );

    let mut resume_cfg = cfg.clone();
    resume_cfg.ckpt.resume = Some(path.clone());
    let resumed = Coordinator::new(resume_cfg, rt.clone()).unwrap().run().unwrap();
    assert_bitwise_tail(&straight, &resumed, 2, "star");
    // The resumed run re-ran exactly rounds 2..4: its billing rows must
    // equal the straight run's tail rows.
    assert_eq!(resumed.comm_per_round.len(), 2);
    assert_eq!(resumed.comm_per_round[..], straight.comm_per_round[2..]);
    // Loss curve covers only the resumed rounds (no pretrain, no replay).
    assert_eq!(
        resumed.metrics.loss_curve[..],
        straight.metrics.loss_curve[straight.metrics.loss_curve.len() - 2 * 10..]
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn resume_matches_straight_run_bitwise_ring() {
    // Same contract on the decentralized loop: per-replica models and
    // per-replica outer momentum cross the checkpoint boundary.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    cfg.topology = TopologyConfig::Ring;

    let straight = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();

    let path = tmp_state_path("ring");
    let mut saver_cfg = cfg.clone();
    saver_cfg.rounds = 2;
    saver_cfg.ckpt.save_every = 2;
    saver_cfg.ckpt.path = Some(path.clone());
    Coordinator::new(saver_cfg, rt.clone()).unwrap().run().unwrap();

    let mut resume_cfg = cfg.clone();
    resume_cfg.ckpt.resume = Some(path.clone());
    let resumed = Coordinator::new(resume_cfg, rt.clone()).unwrap().run().unwrap();
    assert_bitwise_tail(&straight, &resumed, 2, "ring");
    assert_eq!(resumed.replica_params.len(), straight.replica_params.len());
    for (r, (a, b)) in resumed
        .replica_params
        .iter()
        .zip(&straight.replica_params)
        .enumerate()
    {
        assert_eq!(a, b, "replica {r} diverged across resume");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn resume_rejects_mismatched_topology_and_rounds() {
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 2;
    let path = tmp_state_path("reject");
    cfg.ckpt.save_every = 2;
    cfg.ckpt.path = Some(path.clone());
    Coordinator::new(cfg.clone(), rt.clone()).unwrap().run().unwrap();

    // Decentralized config refuses a centralized state.
    let mut ring_cfg = cfg.clone();
    ring_cfg.ckpt = Default::default();
    ring_cfg.ckpt.resume = Some(path.clone());
    ring_cfg.topology = TopologyConfig::Ring;
    let err = Coordinator::new(ring_cfg, rt.clone())
        .unwrap()
        .run()
        .unwrap_err();
    assert!(format!("{err:#}").contains("topology"), "{err:#}");

    // A checkpoint beyond the configured rounds is an error.
    let mut short_cfg = cfg.clone();
    short_cfg.ckpt = Default::default();
    short_cfg.ckpt.resume = Some(path.clone());
    short_cfg.rounds = 1;
    let err = Coordinator::new(short_cfg, rt)
        .unwrap()
        .run()
        .unwrap_err();
    assert!(format!("{err:#}").contains("round"), "{err:#}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn churn_roster_is_deterministic_across_engines_and_bills_active_only() {
    // Elastic membership acceptance: the same (seed, churn schedule)
    // yields identical eval curves under the sequential and parallel
    // engines, and a departed worker bills nothing — every round's
    // traffic is exactly the active roster's flows.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    // w1 leaves after round 0, rejoins for round 3; w4 (beyond the
    // static pool of 4) joins at round 2.
    cfg.churn =
        Some(ChurnConfig::parse("leave:w1@r1,join:w1@r3,join:w4@r2").unwrap());
    let init = rt.init_params().unwrap();
    let run = |engine: EngineConfig| {
        let mut cfg = cfg.clone();
        cfg.engine = engine;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let seq = run(EngineConfig::Sequential);
    let par = run(EngineConfig::Parallel { threads: 0 });
    assert_eq!(par.final_params, seq.final_params);
    assert_eq!(par.metrics.loss_curve, seq.metrics.loss_curve);
    assert_eq!(par.metrics.eval_curve.len(), seq.metrics.eval_curve.len());
    for (a, b) in par.metrics.eval_curve.iter().zip(&seq.metrics.eval_curve) {
        assert_eq!(a.mean_nll, b.mean_nll, "churn eval curves diverged");
    }
    assert_eq!(par.metrics.comm_bytes, seq.metrics.comm_bytes);

    // Billing: per-round bytes == k_t·B each way (P=1, f32, no drops).
    let payload = rt.manifest.param_bytes() as u64;
    let rosters: Vec<Vec<usize>> = (0..4).map(|t| cfg.active_ids(t)).collect();
    assert_eq!(rosters[0], vec![0, 1, 2, 3]);
    assert_eq!(rosters[1], vec![0, 2, 3]);
    assert_eq!(rosters[2], vec![0, 2, 3, 4]);
    assert_eq!(rosters[3], vec![0, 1, 2, 3, 4]);
    for (t, row) in seq.comm_per_round.iter().enumerate() {
        let k_t = rosters[t].len() as u64;
        assert_eq!(row.bytes_up, k_t * payload, "round {t} up bytes");
        assert_eq!(row.bytes_down, k_t * payload, "round {t} down bytes");
        assert_eq!(row.messages, 2 * k_t, "round {t} messages");
    }
    for (t, rs) in seq.round_stats.iter().enumerate() {
        assert_eq!(rs.active_workers, rosters[t].len());
    }
    // The pool covers the late joiner.
    assert_eq!(seq.drops_per_worker.len(), 5);
    assert!(seq.metrics.final_ppl().is_finite());
}

#[test]
fn churn_leaver_rejoins_with_parked_state_and_run_resumes() {
    // Leave-then-rejoin composed with checkpoint/resume: the rejoin
    // event lands *inside the resumed segment*, so the roster derivation
    // and the parked worker state must both cross the save boundary.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    cfg.churn = Some(ChurnConfig::parse("leave:w1@r1,join:w1@r3").unwrap());

    let straight = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();
    // w1 really sat out rounds 1-2: those rounds bill 3 workers.
    let payload = rt.manifest.param_bytes() as u64;
    assert_eq!(straight.comm_per_round[1].bytes_up, 3 * payload);
    assert_eq!(straight.comm_per_round[2].bytes_up, 3 * payload);
    assert_eq!(straight.comm_per_round[3].bytes_up, 4 * payload);

    let path = tmp_state_path("churn");
    let mut saver_cfg = cfg.clone();
    saver_cfg.ckpt.save_every = 3; // one save, at the end of round 3
    saver_cfg.ckpt.path = Some(path.clone());
    let saver = Coordinator::new(saver_cfg, rt.clone()).unwrap().run().unwrap();
    // A full run that also saves must equal the plain run bitwise.
    assert_eq!(saver.final_params, straight.final_params);

    let mut resume_cfg = cfg.clone();
    resume_cfg.ckpt.resume = Some(path.clone());
    let resumed = Coordinator::new(resume_cfg, rt).unwrap().run().unwrap();
    assert_bitwise_tail(&straight, &resumed, 1, "churn+resume");
    std::fs::remove_file(&path).ok();
}

#[test]
fn async_delay0_and_uniform_speed_match_default_loop_bitwise() {
    // The async acceptance criterion (DESIGN.md §11): explicitly
    // configuring the synchronous homogeneous point of the async layer
    // — delay_rounds = 0, discount set, an empty speed model — must
    // reproduce the default (PR-4) loop bitwise, on the star loop with
    // drops + fragments and on the decentralized ring loop.
    let Some(rt) = runtime() else { return };
    let mut star_cfg = small_cfg();
    star_cfg.comm.drop_prob = 0.3;
    star_cfg.stream.fragments = 2;
    star_cfg.seed = 5;
    let mut ring_cfg = small_cfg();
    ring_cfg.topology = TopologyConfig::Ring;

    for (what, cfg) in [("star", star_cfg), ("ring", ring_cfg)] {
        let default_run = Coordinator::new(cfg.clone(), rt.clone())
            .unwrap()
            .run()
            .unwrap();
        let mut explicit = cfg.clone();
        explicit.speed = SpeedConfig::parse("").unwrap();
        explicit.sync = SyncConfig { delay_rounds: 0, discount: 0.5 };
        let async_run = Coordinator::new(explicit, rt.clone()).unwrap().run().unwrap();
        assert_eq!(
            async_run.final_params, default_run.final_params,
            "{what}: final params diverged"
        );
        assert_eq!(async_run.metrics.loss_curve, default_run.metrics.loss_curve);
        for (a, b) in async_run
            .metrics
            .eval_curve
            .iter()
            .zip(&default_run.metrics.eval_curve)
        {
            assert_eq!(a.mean_nll, b.mean_nll, "{what}: eval diverged");
        }
        assert_eq!(async_run.comm_per_round, default_run.comm_per_round);
        assert_eq!(async_run.drops_per_worker, default_run.drops_per_worker);
        assert_eq!(async_run.metrics.comm_messages, default_run.metrics.comm_messages);
        assert!(async_run.round_stats.iter().all(|rs| rs.staleness == 0));
    }
}

#[test]
fn async_delay_overlaps_transfers_and_drains_everything() {
    // Delayed application: every non-final compute round defers its
    // whole transfer behind the next inner phase (zero barrier rows),
    // the end-of-run drain closes one extra row per in-flight batch,
    // the same total bytes move as in the synchronous run, and recorded
    // staleness is min(D, T−1−r). The schedule genuinely changes
    // training (workers see a stale global), so params must differ from
    // the synchronous run while staying finite and deterministic.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.pretrain_steps = 0;
    let init = rt.init_params().unwrap();
    let sync_run = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    cfg.sync.delay_rounds = 2;
    let r1 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    let r2 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    assert_eq!(r1.final_params, r2.final_params, "delayed runs must be seeded");
    assert_eq!(r1.metrics.loss_curve, r2.metrics.loss_curve);
    assert_ne!(
        r1.final_params, sync_run.final_params,
        "a 2-round delay must change the trajectory"
    );
    assert!(r1.final_params.all_finite());
    assert!(r1.metrics.final_ppl().is_finite());
    // Billing shape: T compute rows + D drain rows; only the final
    // compute round and the drain close barriers.
    let rows = &r1.comm_per_round;
    assert_eq!(rows.len(), cfg.rounds + 2);
    assert!(rows[..cfg.rounds - 1].iter().all(|r| r.barrier_s == 0.0));
    assert!(rows[cfg.rounds - 1].barrier_s > 0.0);
    assert!(rows[cfg.rounds..].iter().all(|r| r.barrier_s > 0.0));
    assert!(r1.metrics.sim_comm_seconds < sync_run.metrics.sim_comm_seconds);
    assert_eq!(r1.metrics.comm_bytes, sync_run.metrics.comm_bytes);
    assert_eq!(r1.metrics.comm_messages, sync_run.metrics.comm_messages);
    // Staleness: steady-state D, tapering across the drained tail.
    assert_eq!(r1.round_stats.len(), cfg.rounds);
    for rs in &r1.round_stats {
        assert_eq!(rs.staleness, 2usize.min(cfg.rounds - 1 - rs.round));
    }
}

#[test]
fn async_jitter_speed_profile_replays_across_engines() {
    // Seeded-jitter speed heterogeneity + one-round delay: the jitter
    // draws are a pure function of (seed, worker, round), so the whole
    // training trace — params, losses, billing rows, staleness — must
    // replay bitwise under the sequential and parallel engines (only
    // real wall-clock timing may differ).
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.speed = SpeedConfig::parse("w0=2.0,jitter:0.3").unwrap();
    cfg.sync.delay_rounds = 1;
    cfg.comm.drop_prob = 0.3;
    cfg.seed = 13;
    let init = rt.init_params().unwrap();
    let run = |engine: EngineConfig| {
        let mut cfg = cfg.clone();
        cfg.engine = engine;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let seq = run(EngineConfig::Sequential);
    let par = run(EngineConfig::Parallel { threads: 0 });
    assert_eq!(par.final_params, seq.final_params);
    assert_eq!(par.metrics.loss_curve, seq.metrics.loss_curve);
    assert_eq!(par.comm_per_round, seq.comm_per_round);
    assert_eq!(par.drops_per_worker, seq.drops_per_worker);
    assert_eq!(
        par.round_stats
            .iter()
            .map(|rs| (rs.round, rs.staleness))
            .collect::<Vec<_>>(),
        seq.round_stats
            .iter()
            .map(|rs| (rs.round, rs.staleness))
            .collect::<Vec<_>>()
    );
    // The straggler really shows up in the idle accounting.
    assert!(seq.metrics.sim_idle_seconds > 0.0);
}

#[test]
fn async_churn_resume_composition_is_bitwise() {
    // The full composition: one-round delayed application + elastic
    // membership, checkpointed at a boundary where a delayed
    // contribution is still in flight (with D = 1 and no drops, every
    // non-final boundary is), then resumed. The queue crosses the
    // save/load boundary and the continuation must be bitwise
    // (DESIGN.md §11 determinism contract). Drops × delay is covered by
    // the jitter replay test above.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    cfg.sync.delay_rounds = 1;
    cfg.seed = 17;
    cfg.churn = Some(ChurnConfig::parse("leave:w1@r1,join:w1@r3").unwrap());

    let straight = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();

    // A full-length run that saves once at boundary 3 — a *non-final*
    // boundary, so the D = 1 queue still holds round 2's batch (a save
    // at the run's own final boundary would sit after the drain).
    let path = tmp_state_path("async_churn");
    let mut saver_cfg = cfg.clone();
    saver_cfg.ckpt.save_every = 3;
    saver_cfg.ckpt.path = Some(path.clone());
    let saver = Coordinator::new(saver_cfg, rt.clone()).unwrap().run().unwrap();
    assert_eq!(
        saver.final_params, straight.final_params,
        "saving must not perturb the run"
    );
    let st = checkpoint::load_state(&path, &rt.manifest).unwrap();
    assert_eq!(st.round, 3);
    assert_eq!(st.pending_sync.len(), 1, "D=1 leaves one batch in flight");
    assert_eq!(st.pending_sync[0].round, 2);

    let mut resume_cfg = cfg.clone();
    resume_cfg.ckpt.resume = Some(path.clone());
    let resumed = Coordinator::new(resume_cfg, rt.clone()).unwrap().run().unwrap();
    assert_bitwise_tail(&straight, &resumed, 1, "async+churn+resume");
    // The resumed run re-ran round 3 plus the drain: its billing rows
    // must equal the straight run's tail rows exactly.
    assert_eq!(
        resumed.comm_per_round[..],
        straight.comm_per_round[3..],
        "resumed billing rows diverged"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn aggregate_trim0_no_attackers_is_bitwise_mean_on_every_topology() {
    // The API-redesign acceptance criterion at integration scale:
    // `trimmed:0` with no adversary must reproduce the plain weighted
    // mean bit for bit — on the centralized star and the decentralized
    // gossip loop with drop injection live, and on the ring drop-free
    // (validate rejects ring × drops: the ring all-reduce is a reliable
    // collective, a dropped chunk would corrupt every replica).
    let Some(rt) = runtime() else { return };
    let init = rt.init_params().unwrap();
    for (what, topology, drop_prob) in [
        ("star", TopologyConfig::Star, 0.3),
        ("gossip", TopologyConfig::Gossip, 0.3),
        ("ring", TopologyConfig::Ring, 0.0),
    ] {
        let run = |aggregate: AggregateConfig| {
            let mut cfg = small_cfg();
            cfg.rounds = 3;
            cfg.pretrain_steps = 0;
            cfg.topology = topology;
            cfg.comm.drop_prob = drop_prob;
            cfg.aggregate = aggregate;
            cfg.seed = 7;
            cfg.validate().unwrap();
            Coordinator::new(cfg, rt.clone())
                .unwrap()
                .run_from(Some(init.clone()))
                .unwrap()
        };
        let mean = run(AggregateConfig::WeightedMean);
        let trim0 = run(AggregateConfig::TrimmedMean { trim: 0 });
        assert_eq!(
            trim0.final_params, mean.final_params,
            "{what}: trimmed:0 final params diverged from the mean"
        );
        assert_eq!(trim0.metrics.loss_curve, mean.metrics.loss_curve, "{what}");
        assert_eq!(trim0.round_stats, mean.round_stats, "{what}: stats diverged");
        assert_eq!(
            trim0.comm_per_round, mean.comm_per_round,
            "{what}: the byte bill must not depend on the aggregator"
        );
        for rs in &trim0.round_stats {
            assert_eq!(rs.rejected, 0, "{what}: honest run rejected a payload");
            assert_eq!(rs.trimmed_mass, 0.0, "{what}");
        }
    }
}

#[test]
fn adversary_noise_draws_replay_across_engines() {
    // The attacker set and every noise draw hang off their own RNG
    // stream as pure functions of (seed, round, worker), so a Byzantine
    // run must replay bitwise under the sequential and parallel engines
    // — corruption happens on the coordinator side of the inner phase,
    // after whichever engine produced the honest delta.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 3;
    cfg.adversary = Some(AdversaryConfig::parse("noise:0.25:4.0").unwrap());
    cfg.aggregate = AggregateConfig::TrimmedMean { trim: 1 };
    cfg.seed = 11;
    cfg.validate().unwrap();
    let init = rt.init_params().unwrap();
    let run = |engine: EngineConfig| {
        let mut cfg = cfg.clone();
        cfg.engine = engine;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let seq = run(EngineConfig::Sequential);
    let par = run(EngineConfig::Parallel { threads: 0 });
    assert_eq!(par.final_params, seq.final_params);
    assert_eq!(par.metrics.loss_curve, seq.metrics.loss_curve);
    assert_eq!(par.round_stats, seq.round_stats);
    assert_eq!(par.comm_per_round, seq.comm_per_round);
    // The estimator really worked: trimming discards mass every round.
    assert!(seq.round_stats.iter().all(|rs| rs.trimmed_mass > 0.0));
}

#[test]
fn resume_matches_straight_run_bitwise_stale_adversary() {
    // The stale-replay attacker parks its previous delta between rounds;
    // version-4 states carry the parked buffers, so save → resume must
    // be bitwise even when the boundary splits two attacked rounds —
    // a resume that lost the buffer would replay round 2 as the
    // attacker's honest first round.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    cfg.adversary = Some(AdversaryConfig::parse("stale:0.25").unwrap());
    cfg.aggregate = AggregateConfig::TrimmedMean { trim: 1 };
    cfg.seed = 23;
    cfg.validate().unwrap();

    let straight = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();

    let path = tmp_state_path("stale_adv");
    let mut saver_cfg = cfg.clone();
    saver_cfg.rounds = 2;
    saver_cfg.ckpt.save_every = 2;
    saver_cfg.ckpt.path = Some(path.clone());
    Coordinator::new(saver_cfg, rt.clone()).unwrap().run().unwrap();

    // The parked replay buffers are in the state, one per attacker.
    let st = checkpoint::load_state(&path, &rt.manifest).unwrap();
    let attackers = cfg.adversary.unwrap().attacker_ids(cfg.seed, cfg.workers);
    assert_eq!(
        st.stale.iter().map(|(w, _)| *w).collect::<Vec<_>>(),
        attackers,
        "checkpoint must park exactly the attackers' replay buffers"
    );

    let mut resume_cfg = cfg.clone();
    resume_cfg.ckpt.resume = Some(path.clone());
    let resumed = Coordinator::new(resume_cfg, rt.clone()).unwrap().run().unwrap();
    assert_bitwise_tail(&straight, &resumed, 2, "stale adversary");
    std::fs::remove_file(&path).ok();
}

#[test]
fn plain_train_matches_run_pretrain_phase() {
    // run() with pretrain_steps=N and rounds→0-equivalent must produce the
    // same pretrain loss prefix as plain_train with the same seed.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.pretrain_steps = 8;
    cfg.rounds = 1;
    cfg.inner_steps = 1;
    let coord = Coordinator::new(cfg.clone(), rt.clone()).unwrap();
    let report = coord.run().unwrap();

    let coord2 = Coordinator::new(cfg, rt.clone()).unwrap();
    let mut m = RunMetrics::new("plain");
    coord2
        .plain_train(rt.init_params().unwrap(), 0.0, 8, &mut m, 0)
        .unwrap();
    assert_eq!(&report.metrics.loss_curve[..8], &m.loss_curve[..]);
}

#[test]
fn pruning_composes_with_quantized_codecs() {
    // PR-7 lift #1: `prune_frac > 0` with a non-f32 codec used to be a
    // validate() hard error ("pruned payloads are f32-only"). The sparse
    // wire format ships bitmap + codec-encoded survivors, so the
    // composition now runs — and its upload bill sits strictly between
    // the bitmap floor and the dense q8 bill.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 2;
    cfg.pretrain_steps = 0;
    cfg.stream.codec = Codec::Q8;
    let init = rt.init_params().unwrap();
    let dense = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    cfg.prune_frac = 0.75;
    let pruned = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    assert!(pruned.metrics.final_ppl().is_finite());
    let n = (rt.manifest.param_bytes() / 4) as u64;
    let (k, rounds) = (4u64, cfg.rounds as u64);
    // Every upload carries at least its presence bitmap…
    assert!(
        pruned.metrics.comm_bytes_up >= rounds * k * n.div_ceil(8),
        "upload bill lost the bitmap: {}",
        pruned.metrics.comm_bytes_up
    );
    // …and 75% pruning must undercut the dense q8 bill.
    assert!(
        pruned.metrics.comm_bytes_up < dense.metrics.comm_bytes_up,
        "pruned q8 {} !< dense q8 {}",
        pruned.metrics.comm_bytes_up,
        dense.metrics.comm_bytes_up
    );
    // Downloads are the dense parameter broadcast either way.
    assert_eq!(
        pruned.metrics.comm_bytes - pruned.metrics.comm_bytes_up,
        dense.metrics.comm_bytes - dense.metrics.comm_bytes_up
    );
    // Determinism: the sparse path replays bitwise.
    let again = Coordinator::new(cfg, rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    assert_eq!(again.final_params, pruned.final_params);
    assert_eq!(again.metrics.comm_bytes, pruned.metrics.comm_bytes);
}

#[test]
fn ring_composes_with_pruning_and_bills_partial_sums() {
    // PR-7 lift #2: prune × ring used to be rejected because the
    // reduce-scatter re-densifies partial sums. Now each chunk hop bills
    // the union support of the contributions it actually carries: less
    // than dense, at least the bitmap floor, and growing with hop depth.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 2;
    cfg.pretrain_steps = 0;
    cfg.topology = TopologyConfig::Ring;
    cfg.prune_frac = 0.75;
    let init = rt.init_params().unwrap();
    let r1 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    let r2 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    assert_eq!(r1.final_params, r2.final_params);
    assert_eq!(r1.metrics.comm_bytes, r2.metrics.comm_bytes);
    // No drops + shared mixing row ⇒ every replica stays identical.
    assert_eq!(r1.replica_params.len(), 4);
    for p in &r1.replica_params {
        assert_eq!(p, &r1.replica_params[0]);
        assert!(p.all_finite());
    }
    let n = (rt.manifest.param_bytes() / 4) as u64;
    let payload = rt.manifest.param_bytes() as u64;
    let (k, rounds) = (4u64, cfg.rounds as u64);
    let dense_ring = rounds * 2 * (k - 1) * payload;
    assert!(
        r1.metrics.comm_bytes_up < dense_ring,
        "pruned ring {} !< dense ring {dense_ring}",
        r1.metrics.comm_bytes_up
    );
    // Each hop layer's k chunks tile the parameter space, so every one
    // of the 2(k−1) layers bills at least a full presence bitmap.
    assert!(
        r1.metrics.comm_bytes_up >= rounds * 2 * (k - 1) * (n / 8),
        "ring bill lost the chunk bitmaps: {}",
        r1.metrics.comm_bytes_up
    );
    assert_eq!(r1.metrics.comm_messages, rounds * 2 * (k - 1) * k);
}

#[test]
fn hierarchical_pruning_bills_union_density_and_keeps_star_math() {
    // PR-7 lift #3: prune × hierarchical used to be rejected because the
    // leader re-aggregates member payloads at a different density. The
    // leader hop now bills the union of its group's supports — routing
    // still changes billing only, so the trained model stays bitwise
    // equal to the pruned star run, while the WAN bill shrinks below
    // the star's (the bitmap is shared and overlapping supports merge).
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 2;
    cfg.pretrain_steps = 0;
    cfg.prune_frac = 0.5;
    let init = rt.init_params().unwrap();
    let run = |topology: TopologyConfig| {
        let mut cfg = cfg.clone();
        cfg.topology = topology;
        Coordinator::new(cfg, rt.clone())
            .unwrap()
            .run_from(Some(init.clone()))
            .unwrap()
    };
    let star = run(TopologyConfig::Star);
    let hier = run(TopologyConfig::Hierarchical { groups: 2 });
    assert_eq!(hier.final_params, star.final_params);
    assert_eq!(hier.metrics.loss_curve, star.metrics.loss_curve);
    assert!(
        hier.metrics.comm_bytes_up < star.metrics.comm_bytes_up,
        "union-billed leader hops {} !< per-worker sparse uploads {}",
        hier.metrics.comm_bytes_up,
        star.metrics.comm_bytes_up
    );
    let n = (rt.manifest.param_bytes() / 4) as u64;
    let (g, rounds) = (2u64, cfg.rounds as u64);
    assert!(hier.metrics.comm_bytes_up >= rounds * g * n.div_ceil(8));
    assert!(hier.metrics.final_ppl().is_finite());
}

#[test]
fn error_feedback_with_f32_is_a_no_op() {
    // With the exact codec and no pruning nothing is ever lost on the
    // wire, so the error-feedback residual is identically zero and the
    // knob must not move the trajectory (or the bill) at all.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 3;
    let init = rt.init_params().unwrap();
    let off = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    cfg.stream.error_feedback = true;
    let on = Coordinator::new(cfg, rt)
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    assert_eq!(on.final_params, off.final_params);
    assert_eq!(on.metrics.loss_curve, off.metrics.loss_curve);
    assert_eq!(on.metrics.comm_bytes, off.metrics.comm_bytes);
    assert_eq!(on.metrics.codec_err_l2, off.metrics.codec_err_l2);
}

#[test]
fn resume_matches_straight_run_bitwise_ef_q4() {
    // The EF residual is training state: q4 quantization leaves a real
    // residual every round, and the v3 TrainState must carry it across
    // the save/load boundary for the determinism contract to hold.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    cfg.stream.codec = Codec::Q4;
    cfg.stream.error_feedback = true;
    cfg.seed = 21;

    let straight = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run()
        .unwrap();
    assert!(straight.metrics.final_ppl().is_finite());
    // q4 really loses something each round — the residual is live.
    assert!(straight.metrics.codec_err_l2 > 0.0);

    let path = tmp_state_path("ef_q4");
    let mut saver_cfg = cfg.clone();
    saver_cfg.rounds = 2;
    saver_cfg.ckpt.save_every = 2;
    saver_cfg.ckpt.path = Some(path.clone());
    let saver = Coordinator::new(saver_cfg, rt.clone()).unwrap().run().unwrap();
    assert_eq!(
        &saver.metrics.loss_curve[..],
        &straight.metrics.loss_curve[..saver.metrics.loss_curve.len()]
    );
    let st = checkpoint::load_state(&path, &rt.manifest).unwrap();
    assert_eq!(st.residuals.len(), 4, "EF residuals must be checkpointed");

    let mut resume_cfg = cfg.clone();
    resume_cfg.ckpt.resume = Some(path.clone());
    let resumed = Coordinator::new(resume_cfg, rt.clone()).unwrap().run().unwrap();
    assert_bitwise_tail(&straight, &resumed, 2, "ef_q4");
    std::fs::remove_file(&path).ok();
}

#[test]
fn gossip_error_feedback_composes_with_prune_and_q4() {
    // The full MuLoCo-flavored stack on the decentralized loop: gossip
    // topology, 50% sign-pruning, q4 wire, error feedback on. Runs,
    // replays bitwise, and bills sparse bytes per exchanged payload.
    let Some(rt) = runtime() else { return };
    let mut cfg = small_cfg();
    cfg.rounds = 4;
    cfg.pretrain_steps = 0;
    cfg.topology = TopologyConfig::Gossip;
    cfg.prune_frac = 0.5;
    cfg.stream.codec = Codec::Q4;
    cfg.stream.error_feedback = true;
    let init = rt.init_params().unwrap();
    let r1 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init.clone()))
        .unwrap();
    let r2 = Coordinator::new(cfg.clone(), rt.clone())
        .unwrap()
        .run_from(Some(init))
        .unwrap();
    assert_eq!(r1.final_params, r2.final_params);
    assert_eq!(r1.metrics.comm_bytes, r2.metrics.comm_bytes);
    assert!(r1.final_params.all_finite());
    for p in &r1.replica_evals {
        assert!(p.ppl.is_finite());
    }
    // One sparse q4 payload per worker per round: bitmap floor below,
    // dense q4 above.
    let n = (rt.manifest.param_bytes() / 4) as u64;
    let (k, rounds) = (4u64, cfg.rounds as u64);
    assert!(r1.metrics.comm_bytes_up >= rounds * k * n.div_ceil(8));
    let dense_f32 = rounds * k * rt.manifest.param_bytes() as u64;
    assert!(
        r1.metrics.comm_bytes_up < dense_f32 / 2,
        "sparse q4 gossip {} should be far under the dense f32 bill {dense_f32}",
        r1.metrics.comm_bytes_up
    );
}
