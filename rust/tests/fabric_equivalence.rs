//! Cross-backend differential suite — the headline correctness artifact
//! of the fabric abstraction (DESIGN.md §14).
//!
//! For drop-free configs the TCP fabric must be *bitwise* equivalent to
//! the simulator: billing and drop decisions come from the same embedded
//! [`SimNet`] oracle, and the inner phases are exact f32/f64 LE state
//! round-trips through deterministic PJRT CPU compute — so per-round
//! losses, eval NLLs, byte bills, and the final parameters of a loopback
//! TCP run must equal the sim run bit for bit. Any divergence means a
//! fabric backend leaked into the algorithm.
//!
//! Needs the AOT artifacts (`make artifacts`), hence `#[ignore]`; CI
//! runs it via `cargo test --release --test fabric_equivalence -- --ignored`
//! (the fabric-equivalence job). The suite spawns real worker processes
//! (`env!("CARGO_BIN_EXE_diloco") worker ...`) on loopback.

use diloco::config::{ComputeSchedule, ExperimentConfig, FabricKind, TopologyConfig};
use diloco::coordinator::{Coordinator, DilocoReport};
use diloco::runtime::Runtime;
use std::sync::Arc;

fn artifacts_dir() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string()
}

fn runtime() -> Option<Arc<Runtime>> {
    let dir = artifacts_dir();
    std::path::Path::new(&dir)
        .join("nano.manifest.json")
        .exists()
        .then(|| Arc::new(Runtime::load(&dir, "nano").unwrap()))
}

/// The tiny differential preset — the golden-trace preset's shape
/// (2 workers × 3 rounds × 5 inner steps on nano), drop-free.
fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(&artifacts_dir(), "nano");
    cfg.seed = 0;
    cfg.workers = 2;
    cfg.schedule = ComputeSchedule::Constant(2);
    cfg.inner_steps = 5;
    cfg.rounds = 3;
    cfg.pretrain_steps = 0;
    cfg.eval_every_rounds = 1;
    cfg.eval_batches = 1;
    cfg.data.n_docs = 60;
    cfg.data.doc_len = 120;
    cfg
}

/// Switch a config onto the loopback TCP fabric: ephemeral port, workers
/// spawned from this build's own `diloco` binary.
fn tcp(mut cfg: ExperimentConfig) -> ExperimentConfig {
    cfg.fabric.kind = FabricKind::Tcp;
    cfg.fabric.host = "127.0.0.1".to_string();
    cfg.fabric.port = 0;
    cfg.fabric.spawn = true;
    cfg.fabric.worker_bin = Some(env!("CARGO_BIN_EXE_diloco").to_string());
    cfg
}

fn run(cfg: ExperimentConfig, rt: Arc<Runtime>) -> DilocoReport {
    Coordinator::new(cfg, rt).unwrap().run().unwrap()
}

/// Assert every deterministic field of two reports is bitwise equal.
/// Wall-clock-derived metrics (`sim_compute_seconds`, phase timers) are
/// real elapsed time on both backends and are deliberately excluded —
/// exactly as the golden trace excludes them.
fn assert_bitwise_equal(sim: &DilocoReport, tcp: &DilocoReport, what: &str) {
    let (a, b) = (&sim.metrics, &tcp.metrics);
    for (s, (x, y)) in a.loss_curve.iter().zip(&b.loss_curve).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: loss step {s}: {x} vs {y}");
    }
    assert_eq!(a.loss_curve.len(), b.loss_curve.len(), "{what}: loss points");
    assert_eq!(a.eval_curve.len(), b.eval_curve.len(), "{what}: eval points");
    for (p, q) in a.eval_curve.iter().zip(&b.eval_curve) {
        assert_eq!(p.step, q.step, "{what}: eval step");
        assert_eq!(
            p.mean_nll.to_bits(),
            q.mean_nll.to_bits(),
            "{what}: eval nll {} vs {}",
            p.mean_nll,
            q.mean_nll
        );
    }
    assert_eq!(a.comm_bytes, b.comm_bytes, "{what}: total bytes");
    assert_eq!(a.comm_bytes_up, b.comm_bytes_up, "{what}: up bytes");
    assert_eq!(a.comm_messages, b.comm_messages, "{what}: messages");
    assert_eq!(a.comm_dropped, b.comm_dropped, "{what}: drops");
    assert_eq!(sim.comm_per_round, tcp.comm_per_round, "{what}: billing rows");
    assert_eq!(sim.drops_per_worker, tcp.drops_per_worker, "{what}: drop book");
    assert_eq!(sim.final_params, tcp.final_params, "{what}: final params");
    assert_eq!(
        sim.replica_params, tcp.replica_params,
        "{what}: replica params"
    );
}

/// Star (classic DiLoCo): the default config under both backends.
#[test]
#[ignore]
fn star_loopback_tcp_reproduces_sim_trace_bitwise() {
    let Some(rt) = runtime() else {
        eprintln!("skipping fabric equivalence: run `make artifacts` first");
        return;
    };
    let sim = run(tiny_cfg(), rt.clone());
    let tcp = run(tcp(tiny_cfg()), rt);
    assert_bitwise_equal(&sim, &tcp, "star");
}

/// Ring (decentralized replicas): the structurally different round loop
/// must dispatch through the same fabric seam.
#[test]
#[ignore]
fn ring_loopback_tcp_reproduces_sim_trace_bitwise() {
    let Some(rt) = runtime() else {
        eprintln!("skipping fabric equivalence: run `make artifacts` first");
        return;
    };
    let mut cfg = tiny_cfg();
    cfg.workers = 3;
    cfg.schedule = ComputeSchedule::Constant(3);
    cfg.topology = TopologyConfig::parse("ring").unwrap();
    let sim = run(cfg.clone(), rt.clone());
    let tcp = run(tcp(cfg), rt);
    assert_bitwise_equal(&sim, &tcp, "ring");
}

/// Streaming + quantization ride the same seam: fragments × staggered
/// schedule × q8 codec, still drop-free, still bitwise.
#[test]
#[ignore]
fn streaming_codec_config_is_backend_independent() {
    let Some(rt) = runtime() else {
        eprintln!("skipping fabric equivalence: run `make artifacts` first");
        return;
    };
    let mut cfg = tiny_cfg();
    cfg.stream = diloco::config::StreamConfig::parse(
        "fragments=2,schedule=staggered,codec=q8",
    )
    .unwrap();
    let sim = run(cfg.clone(), rt.clone());
    let tcp = run(tcp(cfg), rt);
    assert_bitwise_equal(&sim, &tcp, "streaming");
}

/// Checkpoint resume dispatches through the fabric seam too: a TCP run
/// saved at round 1 and resumed (still on TCP) must finish bitwise
/// identical to the straight sim run.
#[test]
#[ignore]
fn tcp_resume_matches_straight_sim_run() {
    let Some(rt) = runtime() else {
        eprintln!("skipping fabric equivalence: run `make artifacts` first");
        return;
    };
    let dir = std::env::temp_dir().join(format!(
        "diloco-fabric-eq-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("state.ckpt").to_string_lossy().into_owned();

    let sim = run(tiny_cfg(), rt.clone());

    let mut save_cfg = tcp(tiny_cfg());
    save_cfg.ckpt.save_every = 1;
    save_cfg.ckpt.path = Some(ckpt.clone());
    save_cfg.rounds = 1;
    run(save_cfg, rt.clone());

    let mut resume_cfg = tcp(tiny_cfg());
    resume_cfg.ckpt.resume = Some(ckpt);
    let resumed = run(resume_cfg, rt);
    assert_eq!(
        sim.final_params, resumed.final_params,
        "resumed TCP run diverged from the straight sim run"
    );
    assert_eq!(sim.drops_per_worker, resumed.drops_per_worker);
    let _ = std::fs::remove_dir_all(&dir);
}
