//! Schema validation for the repo-root `BENCH_engine.json` perf ledger.
//!
//! The ledger's `schema` object documents the exact columns each bench
//! section carries; every run entry must conform. Historically nothing
//! checked this, so a malformed hand-pasted row (or a bench whose
//! printed JSON drifted from the schema) went unnoticed until a human
//! read the file. This suite needs no artifacts and runs everywhere —
//! the CI `bench-smoke` job invokes it by name.

use diloco::util::json::Json;
use std::collections::BTreeSet;

fn ledger() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_engine.json");
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("BENCH_engine.json is not JSON: {e:?}"))
}

/// Sections whose run rows are arrays of per-variant objects.
const ARRAY_SECTIONS: &[&str] = &[
    "stream_sync",
    "topology",
    "churn",
    "async_delay",
    "table6_sparse_wire",
    "byzantine",
];
/// Sections whose run entry is a single object of columns.
const OBJECT_SECTIONS: &[&str] = &["microbench_hotpath", "fig2_table2_main"];

fn schema_keys(schema: &Json, section: &str) -> BTreeSet<String> {
    schema
        .expect(section)
        .unwrap_or_else(|e| panic!("schema lacks {section}: {e}"))
        .as_obj()
        .unwrap_or_else(|e| panic!("schema.{section} is not an object: {e}"))
        .keys()
        .cloned()
        .collect()
}

#[test]
fn every_run_row_matches_its_schema_section() {
    let ledger = ledger();
    let schema = ledger.expect("schema").unwrap();
    let runs = ledger.expect("runs").unwrap().as_arr().unwrap();
    assert!(!runs.is_empty(), "the ledger must carry at least one PR entry");
    for (i, run) in runs.iter().enumerate() {
        let obj = run
            .as_obj()
            .unwrap_or_else(|e| panic!("runs[{i}] is not an object: {e}"));
        run.expect("pr")
            .and_then(|p| p.as_str().map(str::to_string))
            .unwrap_or_else(|e| panic!("runs[{i}] lacks a pr label: {e}"));
        run.expect("host")
            .and_then(|h| h.as_str().map(str::to_string))
            .unwrap_or_else(|e| panic!("runs[{i}] lacks a host note: {e}"));
        for (key, value) in obj {
            if key == "pr" || key == "host" || key.ends_with("_note") {
                continue;
            }
            let want = schema_keys(schema, key);
            let rows: Vec<&Json> = if ARRAY_SECTIONS.contains(&key.as_str()) {
                value
                    .as_arr()
                    .unwrap_or_else(|e| panic!("runs[{i}].{key} is not an array: {e}"))
                    .iter()
                    .collect()
            } else if OBJECT_SECTIONS.contains(&key.as_str()) {
                vec![value]
            } else {
                panic!("runs[{i}] carries unknown section {key:?} — add it to this test");
            };
            assert!(!rows.is_empty(), "runs[{i}].{key} is empty");
            for (j, row) in rows.iter().enumerate() {
                let got: BTreeSet<String> = row
                    .as_obj()
                    .unwrap_or_else(|e| {
                        panic!("runs[{i}].{key}[{j}] is not an object: {e}")
                    })
                    .keys()
                    .cloned()
                    .collect();
                assert_eq!(
                    got, want,
                    "runs[{i}].{key}[{j}] columns diverge from schema.{key}"
                );
            }
        }
    }
}

#[test]
fn schema_covers_every_known_section() {
    let ledger = ledger();
    let schema = ledger.expect("schema").unwrap().as_obj().unwrap();
    for section in ARRAY_SECTIONS.iter().chain(OBJECT_SECTIONS) {
        assert!(
            schema.contains_key(*section),
            "schema lacks the {section} section"
        );
    }
    // The description must tell a human how to regenerate each section.
    let desc = ledger.expect("description").unwrap().as_str().unwrap().to_string();
    for bench in ["microbench_hotpath", "stream_sync", "topology", "async_delay"] {
        assert!(
            desc.contains(bench),
            "description does not say how to fill the {bench} section"
        );
    }
}
