//! Paper Fig 10 + Fig 11 — cosine similarity between outer gradients.
//!
//! Fig 10: mean ± std of pairwise cosine similarity among the k=8
//! replicas' outer gradients per round, for H ∈ {250, 500, 1000} (scaled
//! {10, 20, 40}) in both data regimes. Paper shape: i.i.d. similarity has
//! near-zero variance; similarity is *inversely* related to communication
//! frequency; non-i.i.d. variance grows late in training.
//!
//! Fig 11: non-i.i.d. similarity for k=4 vs k=8 — more shards ⇒ more
//! distinct distributions ⇒ less correlated outer gradients; the averaged
//! outer-gradient norm shrinks ~1/√k.

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Scale, Table};
use diloco::config::ComputeSchedule;
use diloco::coordinator::Coordinator;
use diloco::util::math;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig10_11_cosine_sim");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);
    let budget = base.rounds * base.inner_steps;

    let hs: Vec<(usize, &str)> = match ctx.scale {
        Scale::Scaled => vec![(10, "250"), (20, "500"), (40, "1000")],
        Scale::Paper => vec![(250, "250"), (500, "500"), (1000, "1000")],
    };

    // Fig 10: H × regime grid.
    let mut fig10 = Table::new(
        "Fig 10 — outer-gradient cosine similarity (mean over rounds)",
        &["regime", "H(paper)", "cos_mean", "cos_std_mean"],
    );
    let mut curve = String::from("regime,H,round,cos_mean,cos_std,avg_norm\n");
    for non_iid in [false, true] {
        let regime = if non_iid { "non_iid" } else { "iid" };
        for &(h, label) in &hs {
            let mut cfg = base.clone();
            cfg.data.non_iid = non_iid;
            cfg.inner_steps = h;
            cfg.rounds = (budget / h).max(2);
            cfg.eval_every_rounds = 0; // stats only — skip eval cost
            let coord = Coordinator::new(cfg, rt.clone())?;
            let report = coord.run()?;
            let means: Vec<f64> =
                report.round_stats.iter().map(|s| s.cos_mean).collect();
            let stds: Vec<f64> =
                report.round_stats.iter().map(|s| s.cos_std).collect();
            for s in &report.round_stats {
                curve.push_str(&format!(
                    "{regime},{label},{},{:.5},{:.5},{:.5}\n",
                    s.round, s.cos_mean, s.cos_std, s.avg_delta_norm
                ));
            }
            fig10.row(vec![
                regime.to_string(),
                label.to_string(),
                fmt(math::mean(&means)),
                fmt(math::mean(&stds)),
            ]);
        }
    }
    ctx.emit(&fig10);
    ctx.emit_csv("fig10_curves", &curve);

    // Fig 11: k = 4 vs 8, non-i.i.d.; also check the 1/√k norm scaling.
    let mut fig11 = Table::new(
        "Fig 11 — similarity vs replicas (paper: k=8 less correlated than k=4)",
        &["k", "cos_mean", "avg_delta_norm", "worker_norm_mean"],
    );
    for k in [4usize, 8] {
        let mut cfg = base.clone();
        cfg.workers = k;
        cfg.schedule = ComputeSchedule::Constant(k);
        cfg.data.non_iid = true;
        cfg.eval_every_rounds = 0;
        let coord = Coordinator::new(cfg, rt.clone())?;
        let report = coord.run()?;
        let means: Vec<f64> =
            report.round_stats.iter().map(|s| s.cos_mean).collect();
        let norms: Vec<f64> =
            report.round_stats.iter().map(|s| s.avg_delta_norm).collect();
        let wnorms: Vec<f64> = report
            .round_stats
            .iter()
            .map(|s| s.per_worker_norm_mean)
            .collect();
        fig11.row(vec![
            k.to_string(),
            fmt(math::mean(&means)),
            fmt(math::mean(&norms)),
            fmt(math::mean(&wnorms)),
        ]);
    }
    print!("{}", fig11.render());
    ctx.emit_csv("fig11", &fig11.csv());
    ctx.finish();
    Ok(())
}
