//! Sync-topology sweep — star vs ring vs gossip vs hierarchical, with
//! and without outer-gradient quantization (NoLoCo, arXiv:2506.10911;
//! DiLoCoX, arXiv:2506.21263).
//!
//! Every variant runs the same scaled main setting from the same
//! pretrained checkpoint; the interesting columns are per-round WAN
//! bytes (gossip halves the star total, hierarchical cuts root-link
//! flows from k to G, ring pays ~2× bytes to remove the hub), the
//! simulated barrier, the consensus distance of the decentralized
//! modes, and the final (consensus) PPL. The f32 byte counts are
//! hard-asserted against the DESIGN.md §9 analytic formulas, so a
//! billing regression fails the bench rather than skewing the table.
//! Paste the printed JSON fragment into `BENCH_engine.json`.

use diloco::bench::scenarios::{base_config, fmt, load_runtime, rel_pct, topology_grid};
use diloco::bench::{BenchCtx, Table};
use diloco::config::TopologyConfig;
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("topology");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    // Shared pretrained start so variants differ only in sync topology.
    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let payload = rt.manifest.param_bytes() as u64;
    let (k, rounds) = (base.workers as u64, base.rounds as u64);

    let mut table = Table::new(
        "Sync topologies — WAN bytes, barrier, consensus (star pinned by golden trace)",
        &[
            "variant",
            "up_MB/round",
            "up_vs_star",
            "msgs/round",
            "sim_comm_s",
            "consensus_d",
            "final_ppl",
            "ppl_vs_star",
        ],
    );
    let mut rows: Vec<(String, f64, f64, f64, f64)> = Vec::new();
    let mut json_rows = String::new();
    for (label, topology, codec) in topology_grid() {
        let mut cfg = base.clone();
        cfg.topology = topology;
        cfg.stream.codec = codec;
        cfg.validate()?;
        let coord = Coordinator::new(cfg.clone(), rt.clone())?;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = &report.metrics;
        let up_per_round = m.comm_bytes_up as f64 / rounds as f64 / 1e6;
        let consensus_d = report
            .round_stats
            .last()
            .map(|rs| rs.consensus_dist)
            .unwrap_or(0.0);

        // Analytic f32 WAN-byte formulas (DESIGN.md §9) — exact.
        if codec == diloco::comm::codec::Codec::F32 {
            let expect_up = match topology {
                TopologyConfig::Star => rounds * k * payload,
                TopologyConfig::Ring => rounds * 2 * (k - 1) * payload,
                TopologyConfig::Gossip => rounds * k * payload,
                TopologyConfig::Hierarchical { groups } => {
                    rounds * groups as u64 * payload
                }
            };
            assert_eq!(
                m.comm_bytes_up, expect_up,
                "{label}: billed {} up bytes, formula says {expect_up}",
                m.comm_bytes_up
            );
        }

        json_rows.push_str(&format!(
            "      {{ \"variant\": \"{label}\", \"up_mb_per_round\": {up_per_round:.4}, \
             \"msgs_per_round\": {:.1}, \"sim_comm_s\": {:.4}, \"sim_wall_s\": {:.2}, \
             \"consensus_dist\": {consensus_d:.4e}, \"final_ppl\": {:.4} }},\n",
            m.comm_messages as f64 / rounds as f64,
            m.sim_comm_seconds,
            m.sim_wall_seconds(),
            m.final_ppl()
        ));
        rows.push((
            label.to_string(),
            up_per_round,
            m.comm_bytes_up as f64,
            m.sim_comm_seconds,
            m.final_ppl(),
        ));
        let last = rows.last().unwrap();
        table.row(vec![
            label.to_string(),
            format!("{:.3}", last.1),
            rel_pct(last.2, rows[0].2),
            format!("{:.1}", m.comm_messages as f64 / rounds as f64),
            format!("{:.2}", last.3),
            format!("{consensus_d:.2e}"),
            fmt(last.4),
            rel_pct(last.4, rows[0].4),
        ]);
    }
    ctx.emit(&table);
    println!(
        "\nBENCH_engine.json topology rows (paste into the current PR entry):\n{json_rows}"
    );

    // Cross-variant invariants: gossip halves star's total (no
    // broadcast), hierarchical cuts uploads k/G ×, ring pays ~2× uploads
    // but runs with no hub at all.
    let star_up = rows[0].2;
    let gossip = rows.iter().find(|r| r.0 == "gossip_f32").expect("grid row");
    assert!(
        gossip.2 == star_up,
        "gossip uploads equal star's uploads (but nothing comes back down)"
    );
    let hier = rows.iter().find(|r| r.0 == "hier2_f32").expect("grid row");
    assert!(
        hier.2 < 0.5 * star_up,
        "hierarchical(2) must cut WAN uploads vs star: {} vs {star_up}",
        hier.2
    );
    ctx.finish();
    Ok(())
}
