//! Paper Fig 4 — communication frequency H.
//!
//! H sweeps {50, 100, 250, 500, 1000, 2000} (scaled {2, 4, 10, 20, 40,
//! 80}) with T×H held fixed so every variant does the same number of
//! inner steps from the same pretrained checkpoint. Paper shape: more
//! frequent communication helps, but with strongly diminishing returns —
//! H=1000 (scaled 40) costs only ~2.9% PPL vs H=50 (scaled 2) while
//! communicating 20× less.

use diloco::bench::scenarios::{base_config, fmt, load_runtime, rel_pct};
use diloco::bench::{BenchCtx, Scale, Table};
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig4_comm_freq");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    let (hs, labels): (Vec<usize>, Vec<&str>) = match ctx.scale {
        Scale::Scaled => (
            vec![2, 4, 10, 20, 40, 80],
            vec!["50", "100", "250", "500", "1000", "2000"],
        ),
        Scale::Paper => (
            vec![50, 100, 250, 500, 1000, 2000],
            vec!["50", "100", "250", "500", "1000", "2000"],
        ),
    };
    let budget = base.rounds * base.inner_steps;

    // Shared pretrained start.
    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let mut table = Table::new(
        "Fig 4 — communication frequency (paper: mild degradation to H=1000)",
        &["H(paper)", "H", "T", "comm_MB", "final_ppl", "vs_smallest_H"],
    );
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    let mut curves = String::from("H,step,ppl\n");
    for (&h, &label) in hs.iter().zip(&labels) {
        let mut cfg = base.clone();
        cfg.inner_steps = h;
        cfg.rounds = (budget / h).max(1);
        let coord = Coordinator::new(cfg.clone(), rt.clone())?;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = report.metrics;
        for p in &m.eval_curve {
            curves.push_str(&format!("{label},{},{:.4}\n", p.step, p.ppl));
        }
        results.push((
            format!("{label},{h},{}", cfg.rounds),
            m.comm_bytes as f64 / 1e6,
            m.final_ppl(),
        ));
    }
    let best_ref = results[0].2;
    for (prefix, mb, ppl) in &results {
        let cells: Vec<&str> = prefix.split(',').collect();
        table.row(vec![
            cells[0].to_string(),
            cells[1].to_string(),
            cells[2].to_string(),
            format!("{mb:.1}"),
            fmt(*ppl),
            rel_pct(*ppl, best_ref),
        ]);
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
