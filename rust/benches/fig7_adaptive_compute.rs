//! Paper Fig 7 — adaptive compute pool.
//!
//! The number of active replicas varies over the run: constant-local (1),
//! constant-distributed (8), doubling (4→8), halving (8→4), ramping up
//! (1→8) and ramping down (8→1). Paper shape: final quality tracks the
//! *total* compute spent (worker-rounds), not the shape of the schedule —
//! doubling ≈ halving, ramp-up ≈ ramp-down, both ramps worse than the
//! constant-8 run that spends more compute.

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Table};
use diloco::config::ComputeSchedule;
use diloco::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig7_adaptive_compute");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    let schedules: Vec<(&str, ComputeSchedule)> = vec![
        ("constant_local(1)", ComputeSchedule::Constant(1)),
        ("constant_distributed(8)", ComputeSchedule::Constant(8)),
        ("doubling(4->8)", ComputeSchedule::Step { first: 4, second: 8 }),
        ("halving(8->4)", ComputeSchedule::Step { first: 8, second: 4 }),
        ("ramp_up(1->8)", ComputeSchedule::Ramp { from: 1, to: 8 }),
        ("ramp_down(8->1)", ComputeSchedule::Ramp { from: 8, to: 1 }),
    ];

    let mut table = Table::new(
        "Fig 7 — adaptive compute (paper: quality ~ total compute)",
        &["schedule", "worker_rounds", "final_ppl"],
    );
    let mut curves = String::from("schedule,step,ppl\n");
    for (label, schedule) in schedules {
        let mut cfg = base.clone();
        // i.i.d. regime, as in the paper's adaptive-compute study.
        cfg.data.non_iid = false;
        cfg.schedule = schedule.clone();
        let wr = schedule.total_worker_rounds(cfg.rounds);
        let coord = Coordinator::new(cfg, rt.clone())?;
        let report = coord.run()?;
        for p in &report.metrics.eval_curve {
            curves.push_str(&format!("{label},{},{:.4}\n", p.step, p.ppl));
        }
        table.row(vec![
            label.to_string(),
            wr.to_string(),
            fmt(report.metrics.final_ppl()),
        ]);
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
