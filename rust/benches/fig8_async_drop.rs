//! Paper Fig 8 — asynchronous communication (dropped outer gradients).
//!
//! Each replica's upload is dropped with probability {0%, 10%, 30%, 50%};
//! a dropped worker continues from its own parameters instead of the
//! fresh global copy. Paper shape: learning gets spikier with drop rate
//! but degrades gracefully — 50% drops in the non-i.i.d. regime cost only
//! ~2.1% PPL vs perfect communication. BENCH_FULL=1 adds the i.i.d. rows.

use diloco::bench::scenarios::{base_config, fmt, load_runtime, rel_pct};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig8_async_drop");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    let drops = [0.0, 0.1, 0.3, 0.5];
    let regimes: Vec<bool> = if std::env::var("BENCH_FULL").is_ok() {
        vec![true, false]
    } else {
        vec![true]
    };

    let mut table = Table::new(
        "Fig 8 — dropped communication (paper: ~2.1% PPL at 50% non-iid)",
        &["regime", "drop_prob", "drops_observed", "final_ppl", "vs_no_drop"],
    );
    let mut curves = String::from("regime,drop,step,ppl\n");
    for non_iid in regimes {
        let regime = if non_iid { "non_iid" } else { "iid" };
        let mut reference = f64::NAN;
        for &p_drop in &drops {
            let mut cfg = base.clone();
            cfg.data.non_iid = non_iid;
            cfg.comm.drop_prob = p_drop;
            let coord = Coordinator::new(cfg, rt.clone())?;
            let report = coord.run()?;
            let m = &report.metrics;
            if p_drop == 0.0 {
                reference = m.final_ppl();
            }
            for pt in &m.eval_curve {
                curves.push_str(&format!(
                    "{regime},{p_drop},{},{:.4}\n",
                    pt.step, pt.ppl
                ));
            }
            table.row(vec![
                regime.to_string(),
                format!("{:.0}%", p_drop * 100.0),
                report.drops_per_worker.iter().sum::<usize>().to_string(),
                fmt(m.final_ppl()),
                rel_pct(m.final_ppl(), reference),
            ]);
        }
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
