//! Elastic island membership — the paper's Fig-8-style robustness claim
//! extended from dropped messages to departed/joined *machines*.
//!
//! Sweeps `bench::scenarios::churn_grid`: a static-roster baseline, a
//! two-worker permanent departure, a leave-then-rejoin schedule (the
//! worker's parked state is restored), a 4→8 ramp-up, and late joiners
//! beyond the initial pool. Paper shape: quality degrades gracefully as
//! compute leaves and recovers as it returns, while communication bills
//! only the workers actually present each round.
//!
//! Hard asserts (deterministic billing model, P=1 f32 star): every
//! round's upload AND download bytes equal `k_t · B` for the round's
//! active count `k_t` (0 when `k_t = 1`) — a departed worker bills
//! nothing in either direction.

use diloco::bench::scenarios::{base_config, churn_grid, fmt, load_runtime, rel_pct};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("churn");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);
    let payload = rt.manifest.param_bytes() as u64;

    let mut table = Table::new(
        "Elastic membership — leave/join/ramp rosters (billing hard-asserted)",
        &[
            "schedule",
            "worker_rounds",
            "pool",
            "final_ppl",
            "vs_static",
            "up_mb",
            "sim_wall_s",
        ],
    );
    let mut curves = String::from("schedule,round,active_workers,ppl\n");
    let mut reference = f64::NAN;
    for (label, churn) in churn_grid() {
        let mut cfg = base.clone();
        cfg.eval_every_rounds = 1;
        cfg.churn = churn;
        let coord = Coordinator::new(cfg, rt.clone())?;
        let cfg = &coord.cfg;
        let report = coord.run()?;
        let m = &report.metrics;
        if label == "static" {
            reference = m.final_ppl();
        }

        // Per-round billing: exactly the active roster's flows, nothing
        // from departed workers (k_t = 1 syncs locally, free).
        let mut worker_rounds = 0usize;
        for (t, row) in report.comm_per_round.iter().enumerate() {
            let k_t = cfg.active_ids(t).len() as u64;
            worker_rounds += k_t as usize;
            let want = if k_t > 1 { k_t * payload } else { 0 };
            assert_eq!(
                row.bytes_up, want,
                "{label}: round {t} billed {} up bytes for {k_t} active workers",
                row.bytes_up
            );
            assert_eq!(
                row.bytes_down, want,
                "{label}: round {t} billed {} down bytes for {k_t} active workers",
                row.bytes_down
            );
        }
        for (t, rs) in report.round_stats.iter().enumerate() {
            assert_eq!(
                rs.active_workers,
                cfg.active_ids(t).len(),
                "{label}: round stats roster size"
            );
        }

        // Skip the pretrain-phase eval points: one curve row per round.
        let skip = m.eval_curve.len().saturating_sub(cfg.rounds);
        for (pt, rs) in m.eval_curve.iter().skip(skip).zip(&report.round_stats) {
            curves.push_str(&format!(
                "{label},{},{},{:.4}\n",
                rs.round, rs.active_workers, pt.ppl
            ));
        }
        table.row(vec![
            label.to_string(),
            worker_rounds.to_string(),
            cfg.pool_size().to_string(),
            fmt(m.final_ppl()),
            rel_pct(m.final_ppl(), reference),
            format!("{:.2}", m.comm_bytes_up as f64 / 1e6),
            format!("{:.1}", m.sim_wall_seconds()),
        ]);
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    println!(
        "paste into BENCH_engine.json churn rows: see the table above \
         (worker_rounds/up_mb are deterministic; ppl/wall need this machine)"
    );
    ctx.finish();
    Ok(())
}
