//! Paper Table 3 — number of replicas/clusters, both data regimes.
//!
//! k ∈ {1, 4, 8, 16} by default ({1, 4, 8, 16, 64} with BENCH_FULL=1 —
//! k=64 multiplies bench compute 8× over the k=8 row). Inner steps per
//! replica are fixed, so more replicas = more data + compute, exactly as
//! in the paper. Paper shape: PPL improves with k with diminishing
//! returns past k=8, in both regimes (unlike the ImageNet-scale local-SGD
//! results of Ortiz et al.).

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Table};
use diloco::config::ComputeSchedule;
use diloco::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("table3_replicas");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    let mut ks = vec![1usize, 4, 8, 16];
    if std::env::var("BENCH_FULL").is_ok() {
        ks.push(64);
    }

    let mut table = Table::new(
        "Table 3 — replicas (paper non-iid: 16.23/15.18/15.02/14.91/14.96)",
        &["k", "iid_ppl", "non_iid_ppl"],
    );
    for &k in &ks {
        let mut row = vec![k.to_string()];
        for non_iid in [false, true] {
            let mut cfg = base.clone();
            cfg.workers = k;
            cfg.schedule = ComputeSchedule::Constant(k);
            cfg.data.non_iid = non_iid;
            // Keep shard sizes usable at large k.
            cfg.data.n_docs = cfg.data.n_docs.max(40 * k);
            let coord = Coordinator::new(cfg, rt.clone())?;
            let report = coord.run()?;
            row.push(fmt(report.metrics.final_ppl()));
        }
        table.row(row);
    }
    ctx.emit(&table);
    ctx.finish();
    Ok(())
}
