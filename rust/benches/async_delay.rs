//! Straggler-aware asynchronous outer loop — speed heterogeneity ×
//! delayed application (DESIGN.md §11; DiLoCoX one-step-delayed
//! overlap, arXiv:2506.21263, generalized to D rounds, with Streaming
//! DiLoCo's staleness question, arXiv:2501.18512, made measurable).
//!
//! Sweeps `bench::scenarios::async_grid`: the synchronous homogeneous
//! baseline, a 2× straggler under the synchronous barrier, one- and
//! two-round delayed application, staleness discounting, and seeded
//! per-round jitter. Emits a PPL-vs-staleness table plus a per-variant
//! curve CSV (round, staleness, idle, ppl) for the
//! wall-clock-vs-heterogeneity plots.
//!
//! Hard asserts (deterministic billing model, paper-shape invariants):
//!
//! * every variant moves the same total bytes — delay shifts *when*
//!   transfers bill, never *what* ships, and the end-of-run drain loses
//!   nothing;
//! * delayed syncs bill overlapped: every non-final compute round of a
//!   D > 0 run records a zero barrier, no row (drain rows included)
//!   ever exceeds the synchronous per-round barrier for the same
//!   payloads, and the run's total barrier time is strictly below the
//!   D = 0 run's;
//! * recorded staleness is exactly `min(D, T−1−r)` per upload round `r`
//!   (steady state D, tapering only in the drained tail).

use diloco::bench::scenarios::{async_grid, base_config, fmt, load_runtime, rel_pct};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("async_delay");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    // Shared pretrained start so variants differ only in scheduling.
    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let mut table = Table::new(
        "Async outer loop — speed × delay (overlap billing hard-asserted)",
        &[
            "variant",
            "delay",
            "mean_staleness",
            "sim_comm_s",
            "sim_wall_s",
            "idle_s",
            "final_ppl",
            "ppl_vs_sync",
        ],
    );
    let mut curves = String::from("variant,round,staleness,idle_s,ppl\n");
    let mut json_rows = String::new();
    // (label, delay, comm_rows, sim_comm_s, total_bytes, final_ppl)
    let mut rows: Vec<(String, usize, Vec<f64>, f64, u64, f64)> = Vec::new();
    for (label, speed, sync) in async_grid() {
        let mut cfg = base.clone();
        cfg.eval_every_rounds = 1;
        cfg.speed = speed;
        cfg.sync = sync;
        cfg.validate()?;
        let coord = Coordinator::new(cfg.clone(), rt.clone())?;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = &report.metrics;

        // Staleness bookkeeping: one stats row per upload round (no
        // drops in this sweep), stamped min(D, T−1−r).
        assert_eq!(
            report.round_stats.len(),
            cfg.rounds,
            "{label}: every round's batch must eventually apply"
        );
        let d = sync.delay_rounds;
        for rs in &report.round_stats {
            let want = d.min(cfg.rounds - 1 - rs.round);
            assert_eq!(
                rs.staleness, want,
                "{label}: round {} applied with staleness {} (want {want})",
                rs.round, rs.staleness
            );
        }
        let mean_staleness = report
            .round_stats
            .iter()
            .map(|rs| rs.staleness as f64)
            .sum::<f64>()
            / report.round_stats.len().max(1) as f64;

        let barrier_rows: Vec<f64> =
            report.comm_per_round.iter().map(|r| r.barrier_s).collect();
        let total_bytes = m.comm_bytes;
        for (pt, rs) in m
            .eval_curve
            .iter()
            .skip(m.eval_curve.len().saturating_sub(cfg.rounds))
            .zip(&report.round_stats)
        {
            curves.push_str(&format!(
                "{label},{},{},{:.4},{:.4}\n",
                rs.round, rs.staleness, rs.idle_s, pt.ppl
            ));
        }
        json_rows.push_str(&format!(
            "      {{ \"variant\": \"{label}\", \"delay\": {d}, \
             \"mean_staleness\": {mean_staleness:.3}, \"sim_comm_s\": {:.4}, \
             \"sim_wall_s\": {:.2}, \"sim_idle_s\": {:.3}, \"final_ppl\": {:.4} }},\n",
            m.sim_comm_seconds,
            m.sim_wall_seconds(),
            m.sim_idle_seconds,
            m.final_ppl()
        ));
        let ppl = m.final_ppl();
        table.row(vec![
            label.to_string(),
            d.to_string(),
            format!("{mean_staleness:.2}"),
            format!("{:.2}", m.sim_comm_seconds),
            format!("{:.1}", m.sim_wall_seconds()),
            format!("{:.2}", m.sim_idle_seconds),
            fmt(ppl),
            rel_pct(ppl, rows.first().map(|r| r.5).unwrap_or(ppl)),
        ]);
        rows.push((
            label.to_string(),
            d,
            barrier_rows,
            m.sim_comm_seconds,
            total_bytes,
            ppl,
        ));
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    println!(
        "\nBENCH_engine.json async_delay rows (paste into the current PR entry):\n{json_rows}"
    );

    // Invariants (hard-fail: regressions in the overlap-billing model
    // must be caught by running the bench, not by eyeballing).
    let (sync_rows, sync_comm_s, sync_bytes) = {
        let r = &rows[0];
        assert_eq!(r.1, 0, "row 0 is the synchronous baseline");
        (r.2.clone(), r.3, r.4)
    };
    let sync_barrier_max = sync_rows.iter().cloned().fold(0.0f64, f64::max);
    for (label, d, barriers, comm_s, bytes, _) in &rows {
        // Same payloads under every schedule: delay shifts billing
        // rounds, never byte totals (speed never touches the fabric).
        assert_eq!(
            *bytes, sync_bytes,
            "{label}: moved {bytes} bytes, baseline moved {sync_bytes}"
        );
        if *d == 0 {
            continue;
        }
        // Delayed syncs bill overlapped: compute rounds before the last
        // defer their whole transfer behind the next inner phase...
        let t = base.rounds;
        assert!(
            barriers[..t - 1].iter().all(|&b| b == 0.0),
            "{label}: a non-final compute round billed a barrier"
        );
        // ...the drain tail exists (one row per in-flight batch)...
        assert_eq!(
            barriers.len(),
            t + d,
            "{label}: want {t} compute rows + {d} drain rows"
        );
        // ...no row ever exceeds a synchronous round's barrier for the
        // same payloads, and the total is strictly smaller.
        for (i, &b) in barriers.iter().enumerate() {
            assert!(
                b <= sync_barrier_max + 1e-9,
                "{label}: row {i} barrier {b} exceeds the synchronous {sync_barrier_max}"
            );
        }
        assert!(
            *comm_s < sync_comm_s,
            "{label}: delayed total barrier {comm_s} not below synchronous {sync_comm_s}"
        );
    }
    ctx.finish();
    Ok(())
}
