//! Paper Fig 9 — accelerating a single worker (Lookahead-like).
//!
//! DiLoCo with k=1 but H≫1: every H steps the single replica takes an
//! outer Nesterov step on its own trajectory delta — zero communication.
//! Paper shape: k=1 DiLoCo converges faster *and* ends better than plain
//! training with the identical step budget.

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Table};
use diloco::config::ComputeSchedule;
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig9_single_worker");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);
    let n_steps = base.rounds * base.inner_steps;

    // Shared pretrained start for a clean comparison.
    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    // Plain baseline: same budget, no outer steps.
    let mut baseline = RunMetrics::new("baseline");
    coord0.plain_train(
        pretrained.clone(),
        base.pretrain_steps as f64,
        n_steps,
        &mut baseline,
        base.eval_every_rounds,
    )?;

    // k=1 DiLoCo.
    let mut cfg = base.clone();
    cfg.workers = 1;
    cfg.schedule = ComputeSchedule::Constant(1);
    cfg.data.non_iid = false; // single worker sees the whole distribution
    let coord = Coordinator::new(cfg, rt)?;
    let report = coord.run_from(Some(pretrained))?;
    let diloco = report.metrics;

    let mut table = Table::new(
        "Fig 9 — single-worker DiLoCo (paper: faster + better than baseline)",
        &["variant", "comm_bytes", "final_ppl", "tail_loss"],
    );
    table.row(vec![
        "baseline".into(),
        baseline.comm_bytes.to_string(),
        fmt(baseline.final_ppl()),
        fmt(baseline.tail_loss(10)),
    ]);
    table.row(vec![
        "diloco_k1".into(),
        diloco.comm_bytes.to_string(),
        fmt(diloco.final_ppl()),
        fmt(diloco.tail_loss(10)),
    ]);
    ctx.emit(&table);
    assert_eq!(diloco.comm_bytes, 0, "k=1 must be communication-free");

    let mut curves = String::from("variant,step,ppl\n");
    for (name, m) in [("baseline", &baseline), ("diloco_k1", &diloco)] {
        for p in &m.eval_curve {
            curves.push_str(&format!("{name},{},{:.4}\n", p.step, p.ppl));
        }
    }
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
