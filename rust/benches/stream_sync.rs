//! Streaming partial-sync sweep — schedule × codec vs the monolithic
//! full-precision baseline (Streaming DiLoCo, arXiv:2501.18512 +
//! DiLoCoX quantization, arXiv:2506.21263).
//!
//! Every variant runs the same scaled main setting from the same
//! pretrained checkpoint; the interesting columns are per-round upload
//! bytes (staggered ships 1/P of the model per round, q8 ≈4× fewer
//! bytes), the simulated communication barrier (overlapped hides it
//! behind compute), the deterministic codec error, and the final PPL
//! cost of each regime. Paste the printed JSON fragment into
//! `BENCH_engine.json` at the repo root to extend the perf trajectory.

use diloco::bench::scenarios::{base_config, fmt, load_runtime, rel_pct, stream_grid};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("stream_sync");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    // Shared pretrained start so variants differ only in sync regime.
    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let mut table = Table::new(
        "Streaming sync — schedule × codec (baseline pinned by golden trace)",
        &[
            "variant",
            "up_MB/round",
            "up_vs_base",
            "sim_comm_s",
            "sim_wall_s",
            "codec_err",
            "final_ppl",
            "ppl_vs_base",
        ],
    );
    let mut rows: Vec<(String, f64, f64, f64, f64, f64)> = Vec::new();
    let mut json_rows = String::new();
    for (label, stream) in stream_grid() {
        let mut cfg = base.clone();
        cfg.stream = stream;
        cfg.validate()?;
        let coord = Coordinator::new(cfg.clone(), rt.clone())?;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = &report.metrics;
        let up_per_round = m.comm_bytes_up as f64 / cfg.rounds as f64 / 1e6;
        rows.push((
            label.to_string(),
            up_per_round,
            m.comm_bytes_up as f64,
            m.sim_comm_seconds,
            m.sim_wall_seconds(),
            m.final_ppl(),
        ));
        json_rows.push_str(&format!(
            "      {{ \"variant\": \"{label}\", \"up_mb_per_round\": {up_per_round:.4}, \
             \"sim_comm_s\": {:.4}, \"sim_wall_s\": {:.2}, \"codec_err_l2\": {:.4e}, \
             \"final_ppl\": {:.4} }},\n",
            m.sim_comm_seconds,
            m.sim_wall_seconds(),
            m.codec_err_l2,
            m.final_ppl()
        ));
        let last = rows.last().unwrap();
        table.row(vec![
            label.to_string(),
            format!("{:.3}", last.1),
            rel_pct(last.2, rows[0].2),
            format!("{:.2}", last.3),
            format!("{:.1}", last.4),
            format!("{:.2e}", report.metrics.codec_err_l2),
            fmt(last.5),
            rel_pct(last.5, rows[0].5),
        ]);
    }
    ctx.emit(&table);
    println!(
        "\nBENCH_engine.json stream_sync rows (paste into the current PR entry):\n{json_rows}"
    );

    // Invariants the sweep must exhibit (hard-fail so regressions in the
    // billing model are caught by running the bench, not by eyeballing).
    let base_up = rows[0].2;
    for (label, _, up, ..) in &rows[1..] {
        if label.starts_with("staggered") || label.contains("q8") || label.contains("f16")
        {
            assert!(
                *up < base_up,
                "{label}: expected fewer upload bytes than baseline ({up} vs {base_up})"
            );
        }
    }
    let overlapped = rows
        .iter()
        .find(|r| r.0.starts_with("overlapped"))
        .expect("grid has an overlapped row");
    assert!(
        overlapped.3 < rows[0].3,
        "overlapped schedule must shrink the communication barrier"
    );
    ctx.finish();
    Ok(())
}
