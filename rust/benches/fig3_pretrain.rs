//! Paper Fig 3 — impact of the number of pretraining steps.
//!
//! Total step budget is held fixed (paper: 88k) while the pretrain/DiLoCo
//! split varies: {0, 12k, 24k, 48k} pretrain steps ↔ scaled {0, 20, 60,
//! 100} of a 220-step budget. Paper shape: final PPL is nearly flat —
//! even from-scratch DiLoCo loses only ~0.1 PPL (contradicting post-local-
//! SGD folklore), with a transient warmup spike at the transition.

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Scale, Table};
use diloco::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig3_pretrain");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    // (pretrain, paper-equivalent) pairs; total budget fixed.
    let variants: Vec<(usize, &str)> = match ctx.scale {
        Scale::Scaled => vec![(0, "0"), (20, "12k"), (60, "24k"), (100, "48k")],
        Scale::Paper => {
            vec![(0, "0"), (12_000, "12k"), (24_000, "24k"), (48_000, "48k")]
        }
    };
    let total = base.pretrain_steps + base.rounds * base.inner_steps;

    let mut table = Table::new(
        "Fig 3 — pretraining steps (paper: ≤0.1 PPL spread)",
        &["pretrain(paper)", "pretrain", "diloco_rounds", "final_ppl"],
    );
    let mut curves = String::from("pretrain,step,ppl\n");
    for (pre, label) in variants {
        let mut cfg = base.clone();
        cfg.pretrain_steps = pre;
        // Saturating: a smoke-mode budget can be smaller than the sweep's
        // larger pretrain points; such variants just run their minimum.
        let after = total.saturating_sub(pre);
        cfg.rounds = (after / cfg.inner_steps).max(1);
        let coord = Coordinator::new(cfg.clone(), rt.clone())?;
        let report = coord.run()?;
        table.row(vec![
            label.to_string(),
            pre.to_string(),
            cfg.rounds.to_string(),
            fmt(report.metrics.final_ppl()),
        ]);
        for p in &report.metrics.eval_curve {
            curves.push_str(&format!("{label},{},{:.4}\n", p.step, p.ppl));
        }
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
