//! Hot-path microbenchmarks — the §Perf instrument (not a paper figure).
//!
//! Times each primitive on the training path in isolation:
//!   · train_step (1 fused inner AdamW step, PJRT execute + readback)
//!   · train_chunk_5 / train_chunk_25 (amortized per-step cost)
//!   · eval_step, grad_step, apply_update
//!   · outer optimizer step, averaging, pruning, delta (pure rust)
//!   · batch sampling + corpus/tokenizer build (data substrate)
//! The per-step amortization of the chunk path vs the single-step path is
//! the headline number recorded in EXPERIMENTS.md §Perf.

use diloco::bench::scenarios::load_runtime;
use diloco::bench::{time_median, BenchCtx, Table};
use diloco::config::{DataConfig, OuterOptConfig};
use diloco::coordinator::{average, opt::OuterOpt, prune};
use diloco::data::batch::BatchIter;
use diloco::data::Dataset;
use diloco::engine::{self, InnerPhaseExecutor, ParallelIslands, Sequential};
use diloco::runtime::{Tensors, Value};
use diloco::util::rng::Rng;
use diloco::worker::Worker;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("microbench_hotpath");
    let rt = load_runtime("nano");
    let mcfg = rt.manifest.config.clone();
    let params = rt.init_params()?;
    let zeros = Tensors::zeros(&rt.manifest);

    let mut table = Table::new(
        "hot-path microbench (nano)",
        &["op", "median_ms", "per_step_ms", "notes"],
    );

    // Data pipeline.
    let data_cfg = DataConfig { n_docs: 120, doc_len: 120, ..DataConfig::default() };
    let t_dataset = time_median(3, || {
        let _ = Dataset::build(&data_cfg, 8, mcfg.vocab_size, 0).unwrap();
    });
    table.row(vec![
        "dataset_build".into(),
        format!("{:.2}", t_dataset * 1e3),
        "-".into(),
        "corpus+BPE+shard (once per run)".into(),
    ]);

    let ds = Dataset::build(&data_cfg, 8, mcfg.vocab_size, 0).unwrap();
    let mut iter = BatchIter::new(
        ds.shards[0].clone(),
        mcfg.batch_size,
        mcfg.seq_len,
        Rng::new(0),
    );
    let t_batch = time_median(20, || {
        let _ = iter.next_batch();
    });
    table.row(vec![
        "next_batch".into(),
        format!("{:.3}", t_batch * 1e3),
        format!("{:.3}", t_batch * 1e3),
        "per inner step".into(),
    ]);

    // PJRT execution paths.
    let run_steps = |key: &str, steps: usize| -> anyhow::Result<f64> {
        let mut iter = BatchIter::new(
            ds.shards[0].clone(),
            mcfg.batch_size,
            mcfg.seq_len,
            Rng::new(1),
        );
        let mut inputs = Vec::new();
        inputs.extend(params.to_values());
        inputs.extend(zeros.to_values());
        inputs.extend(zeros.to_values());
        inputs.push(Value::F32(vec![0.0]));
        let per = mcfg.batch_size * mcfg.seq_len;
        let mut tokens = Vec::with_capacity(steps * per);
        let mut targets = Vec::with_capacity(steps * per);
        for _ in 0..steps {
            let b = iter.next_batch();
            tokens.extend(b.tokens);
            targets.extend(b.targets);
        }
        inputs.push(Value::I32(tokens));
        inputs.push(Value::I32(targets));
        rt.execute(key, &inputs)?; // warm the compile cache
        Ok(time_median(5, || {
            rt.execute(key, &inputs).unwrap();
        }))
    };
    for (key, steps) in [("train_step", 1usize), ("train_chunk_5", 5), ("train_chunk_25", 25)] {
        let t = run_steps(key, steps)?;
        table.row(vec![
            key.into(),
            format!("{:.2}", t * 1e3),
            format!("{:.2}", t * 1e3 / steps as f64),
            format!("{steps} fused steps"),
        ]);
    }

    let eval_batch: Vec<i32> =
        (0..mcfg.batch_size * mcfg.seq_len).map(|i| (i % mcfg.vocab_size) as i32).collect();
    let t_eval = {
        rt.eval_batch(&params, &eval_batch, &eval_batch)?;
        time_median(5, || {
            rt.eval_batch(&params, &eval_batch, &eval_batch).unwrap();
        })
    };
    table.row(vec![
        "eval_step".into(),
        format!("{:.2}", t_eval * 1e3),
        "-".into(),
        "per eval batch".into(),
    ]);

    // Pure-rust outer loop ops over the full parameter set.
    let delta = {
        let mut d = params.clone();
        d.scale(1e-3);
        d
    };
    let deltas: Vec<Tensors> = (0..8).map(|_| delta.clone()).collect();
    let t_avg = time_median(20, || {
        let _ = average::weighted_average(&deltas, &[1.0; 8]);
    });
    table.row(vec![
        "average_k8".into(),
        format!("{:.3}", t_avg * 1e3),
        "-".into(),
        "per round".into(),
    ]);

    let mut outer = OuterOpt::new(&OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 }, &zeros);
    let mut g = params.clone();
    let t_outer = time_median(20, || {
        outer.step(&mut g, &delta);
    });
    table.row(vec![
        "outer_nesterov".into(),
        format!("{:.3}", t_outer * 1e3),
        "-".into(),
        "per round".into(),
    ]);

    let t_prune = time_median(10, || {
        let mut d = delta.clone();
        let _ = prune::prune_sign(&mut d, 0.5);
    });
    table.row(vec![
        "prune_sign_50%".into(),
        format!("{:.3}", t_prune * 1e3),
        "-".into(),
        "per worker per round (opt-in)".into(),
    ]);

    let t_delta = time_median(20, || {
        let _ = params.delta(&g);
    });
    table.row(vec![
        "delta".into(),
        format!("{:.3}", t_delta * 1e3),
        "-".into(),
        "per worker per round".into(),
    ]);

    // Engine comparison: the same k=4 × H=25 inner phase through the
    // sequential reference executor and the parallel-islands executor —
    // the island-parallelism speedup is measured here, not asserted.
    let k = 4usize;
    let h = 25usize;
    let make_workers = || -> Vec<Worker> {
        (0..k)
            .map(|i| {
                Worker::new(
                    i,
                    params.clone(),
                    zeros.clone(),
                    BatchIter::new(
                        ds.shards[i % ds.shards.len()].clone(),
                        mcfg.batch_size,
                        mcfg.seq_len,
                        Rng::new(42 + i as u64),
                    ),
                )
            })
            .collect()
    };
    // Warm every chunk artifact once so compile time skews neither side;
    // workers are built OUTSIDE the timed closures so the serial setup
    // cost (param clones, shard clones) doesn't dilute the measured
    // speedup — reps keep training the same workers, which repeats the
    // identical k×h-step workload.
    engine::run_inner_phase(&Sequential, &rt, &mut make_workers(), h)?;
    let mut ws_seq = make_workers();
    let t_seq = time_median(3, || {
        engine::run_inner_phase(&Sequential, &rt, &mut ws_seq, h).unwrap();
    });
    let parallel = ParallelIslands::new(0);
    let mut ws_par = make_workers();
    let t_par = time_median(3, || {
        engine::run_inner_phase(&parallel, &rt, &mut ws_par, h).unwrap();
    });
    let par_threads = parallel.resolved_threads(k);
    table.row(vec![
        "inner_phase_seq_k4".into(),
        format!("{:.2}", t_seq * 1e3),
        format!("{:.2}", t_seq * 1e3 / (k * h) as f64),
        format!("{k} islands × {h} steps, 1 thread"),
    ]);
    table.row(vec![
        "inner_phase_par_k4".into(),
        format!("{:.2}", t_par * 1e3),
        format!("{:.2}", t_par * 1e3 / (k * h) as f64),
        format!("{k} islands × {h} steps, {} engine", parallel.name()),
    ]);
    ctx.emit(&table);

    println!(
        "\nengine: sequential {:.1} ms vs parallel {:.1} ms at k={k} on {par_threads} threads \
         → {:.2}x inner-phase speedup",
        t_seq * 1e3,
        t_par * 1e3,
        t_seq / t_par
    );
    ctx.emit_csv(
        "engine",
        &format!(
            "engine,threads,k,h,median_s,speedup\nsequential,1,{k},{h},{t_seq:.6},1.00\n\
             parallel,{par_threads},{k},{h},{t_par:.6},{:.3}\n",
            t_seq / t_par
        ),
    );

    // Headline §Perf ratio: chunked vs stepwise per-step cost.
    let t1 = run_steps("train_step", 1)?;
    let t25 = run_steps("train_chunk_25", 25)? / 25.0;
    println!(
        "\nper-step: train_step {:.2} ms vs train_chunk_25 {:.2} ms → {:.2}x speedup",
        t1 * 1e3,
        t25 * 1e3,
        t1 / t25
    );
    ctx.finish();
    Ok(())
}
