//! Hot-path microbenchmarks — the §Perf instrument (not a paper figure).
//!
//! Two suites:
//!
//! **Artifact-free kernel suite** (runs first, on any machine — no AOT
//! artifacts needed, so the CI bench-smoke job always measures it):
//! before/after ns-per-element for the round loop's pure-rust hot paths —
//! fragment averaging (legacy scalar multi-pass vs the fused chunked
//! kernel fanned across the work-stealing pool), the outer optimizer
//! step (legacy indexed scalar loop vs pooled `step_fragments`), codec
//! round-trips (two-pass extract+transcode vs the fused single pass),
//! extract/scatter with and without allocation, and a k=256 pool smoke
//! whose outputs must be bitwise-identical to sequential. The average
//! and outer-step fast paths are HARD-ASSERTED ≥ 2× over scalar at k=64
//! whenever the host has ≥ 2 cores (skipped with a message otherwise),
//! and the fast paths are bitwise cross-checked against scalar inline.
//!
//! **Artifact suite** (needs `make artifacts`): per-primitive timings of
//!   · train_step (1 fused inner AdamW step, PJRT execute + readback)
//!   · train_chunk_5 / train_chunk_25 (amortized per-step cost)
//!   · eval_step, outer step, averaging, pruning, delta (pure rust)
//!   · batch sampling + corpus/tokenizer build (data substrate)
//! The per-step amortization of the chunk path vs the single-step path is
//! the headline number recorded in EXPERIMENTS.md §Perf.

use diloco::bench::scenarios::load_runtime;
use diloco::bench::{smoke, time_median, BenchCtx, Table};
use diloco::comm::codec::{extract_transcode, Codec};
use diloco::comm::fragment::{FragmentPlan, LeafSlice};
use diloco::config::{DataConfig, OuterOptConfig};
use diloco::coordinator::aggregate::WeightedMean;
use diloco::coordinator::{average, opt::OuterOpt, prune, scratch::RoundScratch};
use diloco::data::batch::BatchIter;
use diloco::data::Dataset;
use diloco::engine::{self, InnerPhaseExecutor, ParallelIslands, Sequential};
use diloco::runtime::{Tensors, Value};
use diloco::util::math;
use diloco::util::rng::Rng;
use diloco::worker::Worker;
use std::hint::black_box;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("microbench_hotpath");
    // The kernel suite needs no artifacts — run it before load_runtime,
    // which exits the process when the AOT artifacts are missing.
    hotpath_suite(&ctx);
    let rt = load_runtime("nano");
    let mcfg = rt.manifest.config.clone();
    let params = rt.init_params()?;
    let zeros = Tensors::zeros(&rt.manifest);

    let mut table = Table::new(
        "hot-path microbench (nano)",
        &["op", "median_ms", "per_step_ms", "notes"],
    );

    // Data pipeline.
    let data_cfg = DataConfig { n_docs: 120, doc_len: 120, ..DataConfig::default() };
    let t_dataset = time_median(3, || {
        let _ = Dataset::build(&data_cfg, 8, mcfg.vocab_size, 0).unwrap();
    });
    table.row(vec![
        "dataset_build".into(),
        format!("{:.2}", t_dataset * 1e3),
        "-".into(),
        "corpus+BPE+shard (once per run)".into(),
    ]);

    let ds = Dataset::build(&data_cfg, 8, mcfg.vocab_size, 0).unwrap();
    let mut iter = BatchIter::new(
        ds.shards[0].clone(),
        mcfg.batch_size,
        mcfg.seq_len,
        Rng::new(0),
    );
    let t_batch = time_median(20, || {
        let _ = iter.next_batch();
    });
    table.row(vec![
        "next_batch".into(),
        format!("{:.3}", t_batch * 1e3),
        format!("{:.3}", t_batch * 1e3),
        "per inner step".into(),
    ]);

    // PJRT execution paths.
    let run_steps = |key: &str, steps: usize| -> anyhow::Result<f64> {
        let mut iter = BatchIter::new(
            ds.shards[0].clone(),
            mcfg.batch_size,
            mcfg.seq_len,
            Rng::new(1),
        );
        let mut inputs = Vec::new();
        inputs.extend(params.to_values());
        inputs.extend(zeros.to_values());
        inputs.extend(zeros.to_values());
        inputs.push(Value::F32(vec![0.0]));
        let per = mcfg.batch_size * mcfg.seq_len;
        let mut tokens = Vec::with_capacity(steps * per);
        let mut targets = Vec::with_capacity(steps * per);
        for _ in 0..steps {
            let b = iter.next_batch();
            tokens.extend(b.tokens);
            targets.extend(b.targets);
        }
        inputs.push(Value::I32(tokens));
        inputs.push(Value::I32(targets));
        rt.execute(key, &inputs)?; // warm the compile cache
        Ok(time_median(5, || {
            rt.execute(key, &inputs).unwrap();
        }))
    };
    for (key, steps) in [("train_step", 1usize), ("train_chunk_5", 5), ("train_chunk_25", 25)] {
        let t = run_steps(key, steps)?;
        table.row(vec![
            key.into(),
            format!("{:.2}", t * 1e3),
            format!("{:.2}", t * 1e3 / steps as f64),
            format!("{steps} fused steps"),
        ]);
    }

    let eval_batch: Vec<i32> =
        (0..mcfg.batch_size * mcfg.seq_len).map(|i| (i % mcfg.vocab_size) as i32).collect();
    let t_eval = {
        rt.eval_batch(&params, &eval_batch, &eval_batch)?;
        time_median(5, || {
            rt.eval_batch(&params, &eval_batch, &eval_batch).unwrap();
        })
    };
    table.row(vec![
        "eval_step".into(),
        format!("{:.2}", t_eval * 1e3),
        "-".into(),
        "per eval batch".into(),
    ]);

    // Pure-rust outer loop ops over the full parameter set.
    let delta = {
        let mut d = params.clone();
        d.scale(1e-3);
        d
    };
    let deltas: Vec<Tensors> = (0..8).map(|_| delta.clone()).collect();
    let t_avg = time_median(20, || {
        let _ = average::weighted_average(&deltas, &[1.0; 8]);
    });
    table.row(vec![
        "average_k8".into(),
        format!("{:.3}", t_avg * 1e3),
        "-".into(),
        "per round".into(),
    ]);

    let mut outer = OuterOpt::new(&OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 }, &zeros);
    let mut g = params.clone();
    let t_outer = time_median(20, || {
        outer.step(&mut g, &delta);
    });
    table.row(vec![
        "outer_nesterov".into(),
        format!("{:.3}", t_outer * 1e3),
        "-".into(),
        "per round".into(),
    ]);

    let t_prune = time_median(10, || {
        let mut d = delta.clone();
        let _ = prune::prune_sign(&mut d, 0.5);
    });
    table.row(vec![
        "prune_sign_50%".into(),
        format!("{:.3}", t_prune * 1e3),
        "-".into(),
        "per worker per round (opt-in)".into(),
    ]);

    let t_delta = time_median(20, || {
        let _ = params.delta(&g);
    });
    table.row(vec![
        "delta".into(),
        format!("{:.3}", t_delta * 1e3),
        "-".into(),
        "per worker per round".into(),
    ]);

    // Engine comparison: the same k=4 × H=25 inner phase through the
    // sequential reference executor and the parallel-islands executor —
    // the island-parallelism speedup is measured here, not asserted.
    let k = 4usize;
    let h = 25usize;
    let make_workers = || -> Vec<Worker> {
        (0..k)
            .map(|i| {
                Worker::new(
                    i,
                    params.clone(),
                    zeros.clone(),
                    BatchIter::new(
                        ds.shards[i % ds.shards.len()].clone(),
                        mcfg.batch_size,
                        mcfg.seq_len,
                        Rng::new(42 + i as u64),
                    ),
                )
            })
            .collect()
    };
    // Warm every chunk artifact once so compile time skews neither side;
    // workers are built OUTSIDE the timed closures so the serial setup
    // cost (param clones, shard clones) doesn't dilute the measured
    // speedup — reps keep training the same workers, which repeats the
    // identical k×h-step workload.
    engine::run_inner_phase(&Sequential, &rt, &mut make_workers(), h)?;
    let mut ws_seq = make_workers();
    let t_seq = time_median(3, || {
        engine::run_inner_phase(&Sequential, &rt, &mut ws_seq, h).unwrap();
    });
    let parallel = ParallelIslands::new(0);
    let mut ws_par = make_workers();
    let t_par = time_median(3, || {
        engine::run_inner_phase(&parallel, &rt, &mut ws_par, h).unwrap();
    });
    let par_threads = parallel.resolved_threads(k);
    table.row(vec![
        "inner_phase_seq_k4".into(),
        format!("{:.2}", t_seq * 1e3),
        format!("{:.2}", t_seq * 1e3 / (k * h) as f64),
        format!("{k} islands × {h} steps, 1 thread"),
    ]);
    table.row(vec![
        "inner_phase_par_k4".into(),
        format!("{:.2}", t_par * 1e3),
        format!("{:.2}", t_par * 1e3 / (k * h) as f64),
        format!("{k} islands × {h} steps, {} engine", parallel.name()),
    ]);
    ctx.emit(&table);

    println!(
        "\nengine: sequential {:.1} ms vs parallel {:.1} ms at k={k} on {par_threads} threads \
         → {:.2}x inner-phase speedup",
        t_seq * 1e3,
        t_par * 1e3,
        t_seq / t_par
    );
    ctx.emit_csv(
        "engine",
        &format!(
            "engine,threads,k,h,median_s,speedup\nsequential,1,{k},{h},{t_seq:.6},1.00\n\
             parallel,{par_threads},{k},{h},{t_par:.6},{:.3}\n",
            t_seq / t_par
        ),
    );

    // Headline §Perf ratio: chunked vs stepwise per-step cost.
    let t1 = run_steps("train_step", 1)?;
    let t25 = run_steps("train_chunk_25", 25)? / 25.0;
    println!(
        "\nper-step: train_step {:.2} ms vs train_chunk_25 {:.2} ms → {:.2}x speedup",
        t1 * 1e3,
        t25 * 1e3,
        t1 / t25
    );
    ctx.finish();
    Ok(())
}

// ---- artifact-free kernel suite ---------------------------------------

/// Legacy PR-5 fragment average, element at a time: normalize, clone the
/// first payload, scale, then one full axpy pass per remaining payload.
/// Kept as the scalar baseline the fused kernel must beat (and match
/// bitwise — same per-element op order, different traversal).
#[inline(never)]
fn average_scalar_multipass(payloads: &[Vec<f32>], weights: &[f64]) -> Vec<f32> {
    let total: f64 = weights.iter().sum();
    let mut out = payloads[0].clone();
    math::scale_scalar(&mut out, (weights[0] / total) as f32);
    for (p, &w) in payloads[1..].iter().zip(&weights[1..]) {
        math::axpy_scalar(&mut out, (w / total) as f32, p);
    }
    out
}

/// Legacy indexed Nesterov fragment step (the historical `for_slices2`
/// body, redundant `1.0 *` included): bounds-checked element-at-a-time
/// indexing, one fragment at a time on one thread.
#[inline(never)]
#[allow(clippy::identity_op)]
fn nesterov_scalar(
    params: &mut Tensors,
    mom: &mut Tensors,
    avg: &[f32],
    slices: &[LeafSlice],
    mu: f32,
    c1: f32,
    c2: f32,
) {
    let mut off = 0usize;
    for s in slices {
        let p = &mut params.leaves_mut()[s.leaf];
        let m = &mut mom.leaves_mut()[s.leaf];
        for i in s.start..s.end {
            let d = avg[off + i - s.start];
            m[i] *= mu;
            m[i] += 1.0 * d;
            p[i] += c1 * d;
            p[i] += c2 * m[i];
        }
        off += s.len();
    }
}

fn zeros_like(t: &Tensors) -> Tensors {
    let mut z = t.clone();
    z.scale(0.0);
    z
}

/// Before/after ns-per-element for the round loop's pure-rust hot paths.
/// See the module docs for what is asserted vs merely reported.
fn hotpath_suite(ctx: &BenchCtx) {
    let smoke = smoke();
    let n_frag = 8usize;
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let threads = cores.min(n_frag);
    let reps = if smoke { 5 } else { 15 };
    let mut rng = Rng::new(0xD11_0C0);
    let mut table = Table::new(
        "hot-path kernels (pure rust, artifact-free)",
        &["op", "k", "ns_per_elem", "vs_scalar", "notes"],
    );
    let mut csv = String::from("op,k,ns_per_elem,speedup\n");
    let ns = |t: f64, elems: usize| t * 1e9 / elems as f64;

    // Fragment average: scalar multi-pass vs fused kernel on the pool.
    for &k in &[8usize, 64, 256] {
        // Constant total work across k so every row times a comparable
        // volume: P fragments × k payloads × n elements.
        let n = (if smoke { 1 << 15 } else { 1 << 19 }) / k;
        let payloads: Vec<Vec<Vec<f32>>> = (0..n_frag)
            .map(|_| {
                (0..k)
                    .map(|_| (0..n).map(|_| rng.f32() - 0.5).collect())
                    .collect()
            })
            .collect();
        let weights: Vec<f64> = (0..k).map(|_| 1.0 + rng.f64()).collect();
        let elems = n_frag * k * n;

        // Bitwise cross-check before timing: fused == scalar per element.
        {
            let mut scratch = RoundScratch::new();
            let (mut norm, mut out) = (scratch.lease(), scratch.lease());
            WeightedMean.mean_into(&payloads[0], &weights, &mut norm, &mut out);
            let want = average_scalar_multipass(&payloads[0], &weights);
            assert_eq!(out.len(), want.len());
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "fused average diverged: {a} != {b}");
            }
        }

        let t_scalar = time_median(reps, || {
            for pl in &payloads {
                black_box(average_scalar_multipass(pl, &weights));
            }
        });
        let mut scratch = RoundScratch::new();
        let t_fused = time_median(reps, || {
            let mut tasks: Vec<Box<dyn FnOnce() -> (Vec<f32>, Vec<f32>) + Send + '_>> =
                Vec::with_capacity(n_frag);
            for pl in &payloads {
                let (mut norm, mut out) = (scratch.lease(), scratch.lease());
                let wt = &weights;
                tasks.push(Box::new(move || {
                    WeightedMean.mean_into(pl, wt, &mut norm, &mut out);
                    (norm, out)
                }));
            }
            for (norm, out) in engine::run_tasks(threads, tasks) {
                scratch.recycle(norm);
                scratch.recycle(out);
            }
        });
        let speedup = t_scalar / t_fused;
        table.row(vec![
            "average_scalar".into(),
            format!("{k}"),
            format!("{:.3}", ns(t_scalar, elems)),
            "1.00".into(),
            format!("{n_frag} frags × {n} elems, 1 thread"),
        ]);
        table.row(vec![
            "average_fused_pool".into(),
            format!("{k}"),
            format!("{:.3}", ns(t_fused, elems)),
            format!("{speedup:.2}x"),
            format!("fused kernel on {threads} pooled threads"),
        ]);
        csv.push_str(&format!(
            "average_scalar,{k},{:.4},1.00\naverage_fused_pool,{k},{:.4},{speedup:.3}\n",
            ns(t_scalar, elems),
            ns(t_fused, elems),
        ));
        if k == 64 {
            if cores >= 2 {
                assert!(
                    speedup >= 2.0,
                    "fragment average fast path must be ≥2x over scalar at k=64 \
                     on a {cores}-core host, measured {speedup:.2}x"
                );
            } else {
                println!(
                    "[hotpath] single-core host: k=64 average ≥2x assert skipped \
                     (measured {speedup:.2}x)"
                );
            }
        }
    }

    // Outer optimizer step (Nesterov): legacy indexed scalar loop, one
    // fragment at a time, vs the pooled batch step over P=8 fragments.
    {
        let n_total = if smoke { 1 << 15 } else { 1 << 20 };
        let init: Vec<f32> = (0..n_total).map(|_| rng.f32() - 0.5).collect();
        let dvals: Vec<f32> = (0..n_total).map(|_| 0.01 * (rng.f32() - 0.5)).collect();
        let make = |v: &[f32]| {
            Tensors::from_raw(vec![v[..v.len() / 2].to_vec(), v[v.len() / 2..].to_vec()])
        };
        let template = make(&init);
        let plan = FragmentPlan::for_tensors(&template, n_frag);
        let delta = make(&dvals);
        let payloads: Vec<Vec<f32>> =
            (0..n_frag).map(|f| plan.extract(&delta, f)).collect();
        let (lr, mu) = (0.7f32, 0.9f32);

        let mut p_scalar = make(&init);
        let mut m_scalar = zeros_like(&p_scalar);
        let t_scalar = time_median(reps, || {
            for (f, payload) in payloads.iter().enumerate() {
                nesterov_scalar(
                    &mut p_scalar,
                    &mut m_scalar,
                    payload,
                    plan.slices(f),
                    mu,
                    -lr,
                    -lr * mu,
                );
            }
        });

        let mut outer =
            OuterOpt::new(&OuterOptConfig::Nesterov { lr, mu }, &zeros_like(&template));
        let mut p_pool = make(&init);
        let batch: Vec<(usize, &[f32])> = payloads
            .iter()
            .enumerate()
            .map(|(f, p)| (f, p.as_slice()))
            .collect();
        let t_pool = time_median(reps, || {
            outer.step_fragments(&mut p_pool, &batch, &plan, threads);
        });
        // Both sides applied exactly `reps` identical rounds from the
        // same start — the trajectories must agree bit for bit.
        for (a, b) in p_scalar.iter_flat().zip(p_pool.iter_flat()) {
            assert_eq!(a.to_bits(), b.to_bits(), "pooled outer step diverged: {a} != {b}");
        }
        let speedup = t_scalar / t_pool;
        table.row(vec![
            "outer_nesterov_scalar".into(),
            "-".into(),
            format!("{:.3}", ns(t_scalar, n_total)),
            "1.00".into(),
            format!("{n_frag} frags × {} elems, 1 thread", n_total / n_frag),
        ]);
        table.row(vec![
            "outer_nesterov_pool".into(),
            "-".into(),
            format!("{:.3}", ns(t_pool, n_total)),
            format!("{speedup:.2}x"),
            format!("step_fragments on {threads} pooled threads, bitwise == scalar"),
        ]);
        csv.push_str(&format!(
            "outer_nesterov_scalar,-,{:.4},1.00\nouter_nesterov_pool,-,{:.4},{speedup:.3}\n",
            ns(t_scalar, n_total),
            ns(t_pool, n_total),
        ));
        if cores >= 2 {
            assert!(
                speedup >= 2.0,
                "outer-step fast path must be ≥2x over scalar on a {cores}-core \
                 host, measured {speedup:.2}x"
            );
        } else {
            println!(
                "[hotpath] single-core host: outer-step ≥2x assert skipped \
                 (measured {speedup:.2}x)"
            );
        }
    }

    // Codec round-trip: two-pass extract-then-transcode (allocating) vs
    // the fused single pass into leased scratch. Report-only.
    {
        let n_total = if smoke { 1 << 14 } else { 1 << 18 };
        let vals: Vec<f32> = (0..n_total).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let t = Tensors::from_raw(vec![
            vals[..n_total / 2].to_vec(),
            vals[n_total / 2..].to_vec(),
        ]);
        let plan = FragmentPlan::for_tensors(&t, n_frag);
        let mut scratch = RoundScratch::new();
        for codec in [Codec::F16, Codec::Q8] {
            let t_twopass = time_median(reps, || {
                for f in 0..n_frag {
                    let mut v = plan.extract(&t, f);
                    black_box(codec.transcode(&mut v, plan.slices(f)));
                }
            });
            let t_fused = time_median(reps, || {
                for f in 0..n_frag {
                    let mut v = scratch.lease();
                    black_box(extract_transcode(codec, &plan, &t, f, &mut v));
                    scratch.recycle(v);
                }
            });
            let name = format!("{codec:?}").to_lowercase();
            table.row(vec![
                format!("codec_{name}_twopass"),
                "-".into(),
                format!("{:.3}", ns(t_twopass, n_total)),
                "1.00".into(),
                "extract alloc + transcode pass".into(),
            ]);
            table.row(vec![
                format!("codec_{name}_fused"),
                "-".into(),
                format!("{:.3}", ns(t_fused, n_total)),
                format!("{:.2}x", t_twopass / t_fused),
                "fused extract+transcode, leased scratch".into(),
            ]);
            csv.push_str(&format!(
                "codec_{name}_twopass,-,{:.4},1.00\ncodec_{name}_fused,-,{:.4},{:.3}\n",
                ns(t_twopass, n_total),
                ns(t_fused, n_total),
                t_twopass / t_fused,
            ));
        }

        // Extract + scatter with and without allocation. Report-only.
        let t_extract_alloc = time_median(reps, || {
            for f in 0..n_frag {
                black_box(plan.extract(&t, f));
            }
        });
        let t_extract_into = time_median(reps, || {
            for f in 0..n_frag {
                let mut v = scratch.lease();
                plan.extract_into(&t, f, &mut v);
                black_box(&v);
                scratch.recycle(v);
            }
        });
        let frags: Vec<Vec<f32>> = (0..n_frag).map(|f| plan.extract(&t, f)).collect();
        let mut dst = zeros_like(&t);
        let t_scatter = time_median(reps, || {
            for (f, v) in frags.iter().enumerate() {
                plan.scatter(v, f, &mut dst);
            }
        });
        table.row(vec![
            "extract_alloc".into(),
            "-".into(),
            format!("{:.3}", ns(t_extract_alloc, n_total)),
            "1.00".into(),
            "fresh Vec per fragment".into(),
        ]);
        table.row(vec![
            "extract_into_leased".into(),
            "-".into(),
            format!("{:.3}", ns(t_extract_into, n_total)),
            format!("{:.2}x", t_extract_alloc / t_extract_into),
            "reused scratch buffer".into(),
        ]);
        table.row(vec![
            "scatter".into(),
            "-".into(),
            format!("{:.3}", ns(t_scatter, n_total)),
            "-".into(),
            "fragment → tensor write-back".into(),
        ]);
        csv.push_str(&format!(
            "extract_alloc,-,{:.4},1.00\nextract_into_leased,-,{:.4},{:.3}\nscatter,-,{:.4},\n",
            ns(t_extract_alloc, n_total),
            ns(t_extract_into, n_total),
            t_extract_alloc / t_extract_into,
            ns(t_scatter, n_total),
        ));
    }

    // k=256 pool smoke: 256 reduction tasks scheduled onto ~cores
    // workers; outputs must be bitwise-identical to the sequential run
    // and arrive in task order (the pool determinism contract).
    {
        let k = 256usize;
        let m = if smoke { 1 << 10 } else { 1 << 14 };
        let data: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..m).map(|_| rng.f32()).collect())
            .collect();
        let run = |threads: usize| -> Vec<f32> {
            let tasks: Vec<Box<dyn FnOnce() -> f32 + Send + '_>> = data
                .iter()
                .map(|d| {
                    Box::new(move || {
                        d.iter().fold(0.0f32, |acc, &x| acc.mul_add(1.000_001, x))
                    }) as Box<dyn FnOnce() -> f32 + Send + '_>
                })
                .collect();
            engine::run_tasks(threads, tasks)
        };
        let seq = run(1);
        let mut par = Vec::new();
        let t_pool = time_median(reps, || {
            par = run(threads);
        });
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "pool output diverged from sequential: {a} != {b}"
            );
        }
        table.row(vec![
            "pool_k256_round".into(),
            format!("{k}"),
            format!("{:.3}", ns(t_pool, k * m)),
            "-".into(),
            format!("256 tasks on {threads} threads, bitwise == sequential"),
        ]);
        csv.push_str(&format!("pool_k256_round,{k},{:.4},\n", ns(t_pool, k * m)));
    }

    print!("{}", table.render());
    ctx.emit_csv("hotpath", &csv);
    println!(
        "[hotpath] kernel suite done on {cores} cores ({threads} pool threads), \
         asserts {}",
        if cores >= 2 { "live" } else { "skipped (1 core)" }
    );
}
