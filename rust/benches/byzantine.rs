//! Byzantine robustness sweep — robust outer aggregation vs scripted
//! attacks (ROADMAP item 4; Blanchard et al., NeurIPS 2017 for Krum).
//!
//! Sweeps `bench::scenarios::byzantine_grid`: an honest plain-mean
//! baseline, the `trimmed:0` honest run that must be *bitwise* equal to
//! it, a PPL-vs-f curve (f = 1, 2, 3 sign-flipping attackers of 8 under
//! `trimmed:2`), each robust estimator against the attack it is shaped
//! for (median vs NaN-bomb, Krum vs scaled noise, trimmed vs stale
//! replay), and adversarial rows composed with gossip mixing, a mid-run
//! departure, and one round of delayed application.
//!
//! Hard asserts (all deterministic, live in CI smoke):
//! - the byte bill is aggregator- and adversary-blind: every
//!   synchronous row bills exactly `k_t · B` uploads per round, the
//!   same as an honest mean run over the same roster — corruption
//!   happens before the wire and robust estimation after it;
//! - `trimmed:0` with zero attackers is bitwise identical to the plain
//!   weighted mean (final PPL bits and every per-round stat record);
//! - the rejection columns match the attack script: the median rejects
//!   exactly the NaN-bombers each round, Krum keeps exactly one row.
//!
//! Paste the printed JSON fragment into `BENCH_engine.json`.

use diloco::bench::scenarios::{base_config, byzantine_grid, fmt, load_runtime, rel_pct};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("byzantine");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    // Shared pretrained start so rows differ only in the adversary /
    // aggregation / composition axes.
    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let payload = rt.manifest.param_bytes() as u64;

    let mut table = Table::new(
        "Byzantine grid — robust aggregation vs attacks (bills aggregator-blind)",
        &[
            "variant",
            "agg",
            "attack",
            "f",
            "up_MB/round",
            "rej/round",
            "trim_mass",
            "final_ppl",
            "vs_honest",
        ],
    );
    let mut json_rows = String::new();
    let mut honest_ppl = f64::NAN;
    let mut honest_bits: Option<(u64, Vec<diloco::coordinator::stats::RoundStats>)> = None;
    let mut honest_up = 0u64;
    for r in byzantine_grid() {
        let mut cfg = base.clone();
        cfg.aggregate = r.aggregate;
        cfg.adversary = r.adversary;
        cfg.topology = r.topology;
        cfg.churn = r.churn.clone();
        cfg.sync = r.sync;
        cfg.validate()?;
        let coord = Coordinator::new(cfg, rt.clone())?;
        let cfg = &coord.cfg;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = &report.metrics;
        let n_attackers = r.adversary.map(|a| a.n_attackers(cfg.pool_size())).unwrap_or(0);
        let rounds = cfg.rounds as f64;

        // Byte-bill invariance (the API-redesign acceptance criterion):
        // on the synchronous path every round uploads exactly the active
        // roster's payloads, no matter which estimator reduces them or
        // how many contributions it rejects. (The one-round-delayed row
        // reshuffles *when* flows bill, so it is asserted separately
        // against the honest total below.)
        if r.sync.delay_rounds == 0 {
            for (t, row) in report.comm_per_round.iter().enumerate() {
                let k_t = cfg.active_ids(t).len() as u64;
                let want = if k_t > 1 { k_t * payload } else { 0 };
                assert_eq!(
                    row.bytes_up, want,
                    "{}: round {t} billed {} up bytes for {k_t} active workers — \
                     the bill must not depend on the aggregator or the adversary",
                    r.label, row.bytes_up
                );
            }
        }

        let rejected: usize = report.round_stats.iter().map(|rs| rs.rejected).sum();
        let trim_mass = report.round_stats.iter().map(|rs| rs.trimmed_mass).sum::<f64>()
            / report.round_stats.len().max(1) as f64;
        match r.label {
            // The attack script is deterministic, so the rejection
            // columns are too: the median drops exactly the NaN payloads,
            // Krum keeps exactly one contribution per round.
            "median_nan_f2" => {
                for rs in &report.round_stats {
                    assert_eq!(rs.rejected, n_attackers, "median rejects the bombers");
                }
            }
            "krum2_noise_f2" => {
                for rs in &report.round_stats {
                    assert_eq!(rs.rejected, cfg.workers - 1, "krum keeps one row");
                }
            }
            "mean_honest" | "mean_flip_f2" => {
                assert_eq!(rejected, 0, "the plain mean filters nothing");
                assert_eq!(trim_mass, 0.0);
            }
            _ => {}
        }

        if r.label == "mean_honest" {
            honest_ppl = m.final_ppl();
            honest_bits = Some((m.final_ppl().to_bits(), report.round_stats.clone()));
            honest_up = m.comm_bytes_up;
        }
        if r.label == "trimmed0_honest" {
            let (bits, stats) = honest_bits.as_ref().expect("honest row runs first");
            assert_eq!(
                m.final_ppl().to_bits(),
                *bits,
                "trimmed:0 with zero attackers must be bitwise the plain mean"
            );
            assert_eq!(
                &report.round_stats, stats,
                "trimmed:0 honest round stats must match the mean run exactly"
            );
        }
        if r.label == "delay1_median_noise_f2" {
            // Delay changes when flows bill, never how much: same total
            // uploads as the honest synchronous star run.
            assert_eq!(
                m.comm_bytes_up, honest_up,
                "delayed application must not change the total byte bill"
            );
        }

        json_rows.push_str(&format!(
            "      {{ \"variant\": \"{}\", \"aggregate\": \"{}\", \"attack\": \"{}\", \
             \"n_attackers\": {n_attackers}, \"up_mb_per_round\": {:.4}, \
             \"rejected_per_round\": {:.2}, \"trimmed_mass\": {:.4}, \
             \"final_ppl\": {:.4} }},\n",
            r.label,
            r.aggregate.label(),
            r.adversary.map(|a| a.label()).unwrap_or_else(|| "none".into()),
            m.comm_bytes_up as f64 / rounds / 1e6,
            rejected as f64 / rounds,
            trim_mass,
            m.final_ppl()
        ));
        table.row(vec![
            r.label.to_string(),
            r.aggregate.label(),
            r.adversary.map(|a| a.attack.name().to_string()).unwrap_or_else(|| "-".into()),
            n_attackers.to_string(),
            format!("{:.3}", m.comm_bytes_up as f64 / rounds / 1e6),
            format!("{:.2}", rejected as f64 / rounds),
            format!("{trim_mass:.3}"),
            fmt(m.final_ppl()),
            rel_pct(m.final_ppl(), honest_ppl),
        ]);
    }
    ctx.emit(&table);
    println!(
        "\nBENCH_engine.json byzantine rows (paste into the current PR entry):\n{json_rows}"
    );
    ctx.finish();
    Ok(())
}
