//! Paper Fig 6 — outer optimizer comparison.
//!
//! SGD (≡ FedAvg), SGD-momentum, Nesterov (the paper's choice), and Adam
//! (≡ FedOpt, with ε raised to 0.1 for stability — the paper found Adam
//! unstable otherwise), each at its best Table-5 hyperparameters, from a
//! shared pretrained checkpoint. Paper shape: Nesterov wins; SGD and Adam
//! clearly behind.

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Table};
use diloco::config::OuterOptConfig;
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig6_outer_opt");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    // Table 5 bold values per optimizer.
    let variants: Vec<(&str, OuterOptConfig)> = vec![
        ("sgd(lr=0.5)", OuterOptConfig::Sgd { lr: 0.5 }),
        ("sgdm(lr=0.3,mu=0.9)", OuterOptConfig::SgdM { lr: 0.3, mu: 0.9 }),
        (
            "nesterov(lr=0.7,mu=0.9)",
            OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
        ),
        (
            "adam(lr=0.3,eps=0.1)",
            OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
        ),
    ];

    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let mut table = Table::new(
        "Fig 6 — outer optimizers (paper: Nesterov best)",
        &["outer_opt", "final_ppl", "tail_loss"],
    );
    let mut curves = String::from("opt,step,ppl\n");
    for (label, opt) in variants {
        let mut cfg = base.clone();
        cfg.outer_opt = opt;
        let coord = Coordinator::new(cfg, rt.clone())?;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = report.metrics;
        for p in &m.eval_curve {
            curves.push_str(&format!("{label},{},{:.4}\n", p.step, p.ppl));
        }
        table.row(vec![
            label.to_string(),
            fmt(m.final_ppl()),
            fmt(m.tail_loss(10)),
        ]);
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
