//! Paper Fig 5 — i.i.d. vs non-i.i.d. data regimes.
//!
//! Same DiLoCo setting, shards drawn randomly (i.i.d.) vs by latent topic
//! (non-i.i.d., the analogue of the paper's k-means clusters). Paper
//! shape: i.i.d. converges faster early, but both regimes end at
//! comparable PPL — DiLoCo is robust to shard heterogeneity.

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig5_data_regimes");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    let mut table = Table::new(
        "Fig 5 — data regimes (paper: comparable final PPL)",
        &["regime", "final_ppl", "mid_ppl"],
    );
    let mut curves = String::from("regime,step,ppl\n");
    for non_iid in [true, false] {
        let mut cfg = base.clone();
        cfg.data.non_iid = non_iid;
        cfg.eval_every_rounds = 1; // fine-grained curve for the crossover
        let label = if non_iid { "non_iid" } else { "iid" };
        let coord = Coordinator::new(cfg, rt.clone())?;
        let report = coord.run()?;
        let m = report.metrics;
        for p in &m.eval_curve {
            curves.push_str(&format!("{label},{},{:.4}\n", p.step, p.ppl));
        }
        let mid = m
            .eval_curve
            .get(m.eval_curve.len() / 2)
            .map(|p| p.ppl)
            .unwrap_or(f64::NAN);
        table.row(vec![label.to_string(), fmt(m.final_ppl()), fmt(mid)]);
    }
    ctx.emit(&table);
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
