//! Paper Table 6 — pruning outer gradients.
//!
//! Sign-based pruning (Yadav et al.) of {0%, 25%, 50%, 75%} of each
//! replica's outer gradient before averaging. Paper shape: up to 50% is
//! almost free (+0.39% PPL), 75% costs +1.66% — communication drops
//! proportionally (we bill non-zeros + bitmap).
//!
//! The second table sweeps the sparse wire format across
//! codec × prune × topology — the compositions the config layer used to
//! hard-reject — and hard-asserts every round's billed upload bytes
//! against the closed forms at the exactly-solvable corners:
//!
//! * `prune = 1.0` zeroes every element, so each payload is exactly its
//!   presence bitmap plus a zero-element codec body (`nnz = 0`), per
//!   fragment / per ring chunk / per leader aggregate.
//! * `prune = 0.0` is the dense format: `codec.encoded_bytes` per
//!   payload, chunked on the ring.
//! * `prune = 0.5` has data-dependent density, so its bill is bracketed
//!   (bitmap floor ≤ billed ≤ bitmap + dense body) and pinned strictly
//!   monotone in the prune fraction.

use diloco::bench::scenarios::{base_config, fmt, load_runtime, rel_pct};
use diloco::bench::{BenchCtx, Table};
use diloco::comm::codec::Codec;
use diloco::comm::fragment::FragmentPlan;
use diloco::comm::{topology, wire};
use diloco::config::TopologyConfig;
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("table6_pruning");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let mut table = Table::new(
        "Table 6 — pruned outer gradients (paper: 0/-0.06/+0.39/+1.66 %)",
        &["pruned", "comm_MB", "final_ppl", "relative_change"],
    );
    let mut reference = f64::NAN;
    for frac in [0.0, 0.25, 0.5, 0.75] {
        let mut cfg = base.clone();
        cfg.prune_frac = frac;
        let coord = Coordinator::new(cfg, rt.clone())?;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = report.metrics;
        if frac == 0.0 {
            reference = m.final_ppl();
        }
        table.row(vec![
            format!("{:.0}%", frac * 100.0),
            format!("{:.2}", m.comm_bytes as f64 / 1e6),
            fmt(m.final_ppl()),
            rel_pct(m.final_ppl(), reference),
        ]);
    }
    ctx.emit(&table);

    // ---- sparse wire format: codec × prune × topology ----
    //
    // The monolithic plan (P = 1) over the real parameter tree gives the
    // fragment geometry the coordinator bills with: n elements spread
    // over s contiguous leaf slices.
    let plan = FragmentPlan::for_tensors(&pretrained, 1);
    let n = plan.total_elements();
    let s = plan.slices(0).len();
    let k = base.workers as u64;
    // One sparse payload of the whole delta at density `nnz`.
    let payload = |codec: Codec, nnz: usize| wire::sparse_payload_bytes(codec, n, nnz, s);
    // Per-round upload closed form at an exactly-known density
    // (`None` = dense wire format).
    let up_per_round = |topo: &TopologyConfig, codec: Codec, nnz: Option<usize>| -> u64 {
        let one = match nnz {
            Some(z) => payload(codec, z),
            None => codec.encoded_bytes(n, s),
        };
        match topo {
            // k sparse uploads to the hub / k pairwise exchanges.
            TopologyConfig::Star | TopologyConfig::Gossip => k * one,
            // G leader aggregates; at nnz = 0 the union support is
            // empty, at dense it is the full fragment.
            TopologyConfig::Hierarchical { groups } => *groups as u64 * one,
            // 2(k−1) hop layers of k chunks, each billed at the chunk's
            // own geometry (1 slice, chunk_elems elements).
            TopologyConfig::Ring => {
                let layer: u64 = (0..k as usize)
                    .map(|c| {
                        let cn = topology::chunk_elems(n, c, k as usize);
                        match nnz {
                            Some(0) => wire::sparse_payload_bytes(codec, cn, 0, 1),
                            Some(_) => unreachable!("only nnz=0 is closed-form"),
                            None => codec.encoded_bytes(cn, 1),
                        }
                    })
                    .sum();
                2 * (k - 1) * layer
            }
        }
    };

    let mut sweep = Table::new(
        "Sparse wire format — codec × prune × topology (billed bytes hard-asserted)",
        &["topology", "codec", "pruned", "up_MB_per_round", "final_ppl"],
    );
    let topologies = [
        ("star", TopologyConfig::Star),
        ("ring", TopologyConfig::Ring),
        ("hier/2", TopologyConfig::Hierarchical { groups: 2 }),
        ("gossip", TopologyConfig::Gossip),
    ];
    for (tname, topo) in &topologies {
        for codec in [Codec::F32, Codec::Q8, Codec::Q4] {
            let mut by_frac = Vec::new();
            for frac in [0.0, 0.5, 1.0] {
                let mut cfg = base.clone();
                cfg.rounds = 2;
                cfg.topology = topo.clone();
                cfg.stream.codec = codec;
                cfg.prune_frac = frac;
                let report = Coordinator::new(cfg, rt.clone())?
                    .run_from(Some(pretrained.clone()))?;
                // Hard-assert every round's billed upload against the
                // wire-format formulas.
                for (r, row) in report.comm_per_round.iter().enumerate() {
                    let tag = format!("{tname}/{codec:?}/prune={frac}/round {r}");
                    if frac == 0.0 {
                        assert_eq!(
                            row.bytes_up,
                            up_per_round(topo, codec, None),
                            "dense bill diverged: {tag}"
                        );
                    } else if frac == 1.0 {
                        assert_eq!(
                            row.bytes_up,
                            up_per_round(topo, codec, Some(0)),
                            "all-pruned bill diverged: {tag}"
                        );
                    } else {
                        // Bitmap floor ≤ billed ≤ bitmap + dense body.
                        let lo = up_per_round(topo, codec, Some(0));
                        let hi = match topo {
                            TopologyConfig::Ring => {
                                lo + up_per_round(topo, codec, None)
                            }
                            _ => up_per_round(topo, codec, Some(n)),
                        };
                        assert!(
                            (lo..=hi).contains(&row.bytes_up),
                            "bill outside sparse bracket: {tag}: \
                             {lo} ≤ {} ≤ {hi}",
                            row.bytes_up
                        );
                    }
                }
                let per_round = report.metrics.comm_bytes_up
                    / report.comm_per_round.len() as u64;
                by_frac.push(per_round);
                sweep.row(vec![
                    tname.to_string(),
                    format!("{codec:?}").to_lowercase(),
                    format!("{:.0}%", frac * 100.0),
                    format!("{:.3}", per_round as f64 / 1e6),
                    fmt(report.metrics.final_ppl()),
                ]);
            }
            // The bitmap-only bill always undercuts any live density.
            assert!(
                by_frac[2] < by_frac[1],
                "{tname}/{codec:?}: all-pruned not cheapest: {by_frac:?}"
            );
            // Per-worker payloads (star uploads, gossip exchanges) are
            // guaranteed cheaper than dense at 50% pruning: nnz ≤ ⌈n/2⌉
            // per payload. Aggregated hops (ring partial sums, leader
            // unions) can legitimately re-densify past break-even, so
            // for them the bracket assert above is the whole contract.
            if matches!(topo, TopologyConfig::Star | TopologyConfig::Gossip) {
                assert!(
                    by_frac[1] < by_frac[0],
                    "{tname}/{codec:?}: 50% prune not cheaper than dense: {by_frac:?}"
                );
            }
        }
    }
    ctx.emit(&sweep);
    ctx.finish();
    Ok(())
}
