//! Paper Table 6 — pruning outer gradients.
//!
//! Sign-based pruning (Yadav et al.) of {0%, 25%, 50%, 75%} of each
//! replica's outer gradient before averaging. Paper shape: up to 50% is
//! almost free (+0.39% PPL), 75% costs +1.66% — communication drops
//! proportionally (we bill non-zeros + bitmap).

use diloco::bench::scenarios::{base_config, fmt, load_runtime, rel_pct};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("table6_pruning");
    let base = base_config(ctx.scale);
    let rt = load_runtime(&base.model);

    let coord0 = Coordinator::new(base.clone(), rt.clone())?;
    let mut pre = RunMetrics::new("pretrain");
    let pretrained =
        coord0.plain_train(rt.init_params()?, 0.0, base.pretrain_steps, &mut pre, 0)?;

    let mut table = Table::new(
        "Table 6 — pruned outer gradients (paper: 0/-0.06/+0.39/+1.66 %)",
        &["pruned", "comm_MB", "final_ppl", "relative_change"],
    );
    let mut reference = f64::NAN;
    for frac in [0.0, 0.25, 0.5, 0.75] {
        let mut cfg = base.clone();
        cfg.prune_frac = frac;
        let coord = Coordinator::new(cfg, rt.clone())?;
        let report = coord.run_from(Some(pretrained.clone()))?;
        let m = report.metrics;
        if frac == 0.0 {
            reference = m.final_ppl();
        }
        table.row(vec![
            format!("{:.0}%", frac * 100.0),
            format!("{:.2}", m.comm_bytes as f64 / 1e6),
            fmt(m.final_ppl()),
            rel_pct(m.final_ppl(), reference),
        ]);
    }
    ctx.emit(&table);
    ctx.finish();
    Ok(())
}
