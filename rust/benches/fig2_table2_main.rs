//! Paper Fig 2 + Table 2 — the main result.
//!
//! Compares, from a shared pretrained checkpoint (paper: 24k steps):
//!   1. baseline            — 1 worker, batch B, N more steps
//!   2. baseline, 8× batch via data parallelism   (comm 8×N, time 1×)
//!   3. baseline, 8× batch via microbatching      (comm 0,   time 8×)
//!   4. baseline, 8× updates                      (comm 0,   time 8×)
//!   5. DiLoCo, k=8 non-i.i.d.                    (comm 8×N/H, time 1×)
//! plus a from-scratch baseline for the Fig-2 curve. Rows report measured
//! communication, simulated time, compute×, and final validation PPL.
//! Paper shape to reproduce: DiLoCo beats rows 1–3 in PPL, ~matches the
//! 8×-batch rows' compute, and communicates H× less than DP; 8× updates
//! (row 4) still wins PPL at 8× the wall-clock.

use diloco::bench::scenarios::{base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Table};
use diloco::coordinator::baselines::{run_big_batch, BigBatchMode};
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("fig2_table2_main");
    let cfg = base_config(ctx.scale);
    let rt = load_runtime(&cfg.model);
    let coord = Coordinator::new(cfg.clone(), rt.clone())?;

    let n_steps = cfg.rounds * cfg.inner_steps; // N after pretraining
    let k = cfg.workers;
    let payload = rt.manifest.param_bytes() as f64;

    // Shared pretrained checkpoint θ(0).
    let mut pre_metrics = RunMetrics::new("pretrain");
    let pretrained = coord.plain_train(
        rt.init_params()?,
        0.0,
        cfg.pretrain_steps,
        &mut pre_metrics,
        0,
    )?;
    println!(
        "pretrained {} steps: ppl {}",
        cfg.pretrain_steps,
        fmt(pre_metrics.final_ppl())
    );

    // 0. From-scratch baseline (Fig 2 red curve): same *total* step count.
    let mut scratch = RunMetrics::new("from_scratch");
    coord.plain_train(
        rt.init_params()?,
        0.0,
        cfg.pretrain_steps + n_steps,
        &mut scratch,
        cfg.eval_every_rounds,
    )?;

    // 1. Baseline: finetune N more steps at batch B.
    let mut baseline = RunMetrics::new("baseline");
    coord.plain_train(
        pretrained.clone(),
        cfg.pretrain_steps as f64,
        n_steps,
        &mut baseline,
        cfg.eval_every_rounds,
    )?;

    // 2+3. 8× batch (DP billing and microbatch billing).
    let dp = run_big_batch(
        &coord,
        k,
        n_steps,
        BigBatchMode::DataParallel,
        pretrained.clone(),
        cfg.pretrain_steps as f64,
    )?;
    let micro = run_big_batch(
        &coord,
        k,
        n_steps,
        BigBatchMode::Microbatch,
        pretrained.clone(),
        cfg.pretrain_steps as f64,
    )?;

    // 4. 8× updates at batch B.
    let mut upd8 = RunMetrics::new("8x_updates");
    coord.plain_train(
        pretrained.clone(),
        cfg.pretrain_steps as f64,
        k * n_steps,
        &mut upd8,
        cfg.eval_every_rounds,
    )?;

    // 5. DiLoCo k=8, non-i.i.d., from the same checkpoint.
    let report = coord.run_from(Some(pretrained))?;
    let diloco = report.metrics;

    // Two time columns: `time_dc` assumes the paper's co-located
    // datacenter fabric (communication fully overlapped ⇒ compute only);
    // `time_wan` bills the simulated cross-island WAN. The paper's Table 2
    // reports the datacenter column; the WAN column is the scenario
    // DiLoCo exists for (DP's per-step barrier is ruinous there).
    let mut table = Table::new(
        "Table 2 — trade-offs (paper PPL: 16.23 / 15.30 / 15.30 / 14.72 / 15.02)",
        &["model", "comm_msgs", "comm_MB", "time_dc", "time_wan", "compute_x", "ppl"],
    );
    let base_time = baseline.sim_compute_seconds.max(1e-9);
    let mut row = |label: &str, m: &RunMetrics, compute_x: f64| {
        table.row(vec![
            label.to_string(),
            m.comm_messages.to_string(),
            format!("{:.1}", m.comm_bytes as f64 / 1e6),
            format!("{:.2}", m.sim_compute_seconds / base_time),
            format!("{:.2}", m.sim_wall_seconds() / base_time),
            format!("{compute_x:.0}x"),
            fmt(m.final_ppl()),
        ]);
    };
    row("baseline", &baseline, 1.0);
    row("dp_8x_batch", &dp, k as f64);
    row("microbatch_8x", &micro, k as f64);
    row("8x_updates", &upd8, k as f64);
    row("diloco_k8", &diloco, k as f64);
    ctx.emit(&table);

    println!(
        "\nouter-gradient upload reduction vs DP: {:.0}x (paper: H = {}x); \
         total incl. broadcast: {:.1}x",
        dp.comm_bytes_up as f64 / diloco.comm_bytes_up.max(1) as f64,
        cfg.inner_steps,
        dp.comm_bytes as f64 / diloco.comm_bytes.max(1) as f64,
    );
    assert!(
        (dp.comm_bytes as f64) > payload, // sanity: DP actually communicated
        "DP baseline communicated nothing"
    );

    // Fig 2 curves: eval PPL vs step for every variant.
    let mut curves = String::from("variant,step,ppl\n");
    for (name, m) in [
        ("from_scratch", &scratch),
        ("baseline_finetune", &baseline),
        ("8x_batch", &micro),
        ("8x_updates", &upd8),
        ("diloco_k8", &diloco),
    ] {
        for p in &m.eval_curve {
            curves.push_str(&format!("{name},{},{:.4}\n", p.step, p.ppl));
        }
    }
    ctx.emit_csv("curves", &curves);
    ctx.finish();
    Ok(())
}
