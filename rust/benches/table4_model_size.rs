//! Paper Table 4 — model-size sweep.
//!
//! For each size tier, compares the single-worker baseline against
//! DiLoCo k=8 (non-i.i.d.) on the same step budget and reports the
//! relative + absolute PPL improvement. Paper shape: DiLoCo's advantage
//! holds (indeed grows) with model size — 4.33% / 7.45% / 7.49% for
//! 60M / 150M / 400M. Scaled tiers: nano + micro by default (micro ≈ 7×
//! nano compute); BENCH_FULL=1 adds tiny.
//!
//! Requires artifacts for each tier: `make artifacts` builds nano+micro.

use diloco::bench::scenarios::{artifacts_dir, base_config, fmt, load_runtime};
use diloco::bench::{BenchCtx, Scale, Table};
use diloco::coordinator::Coordinator;
use diloco::metrics::RunMetrics;

fn main() -> anyhow::Result<()> {
    let ctx = BenchCtx::new("table4_model_size");
    let mut tiers: Vec<&str> = match ctx.scale {
        Scale::Scaled => vec!["nano", "micro"],
        Scale::Paper => vec!["60m", "150m", "400m"],
    };
    if ctx.scale == Scale::Scaled && std::env::var("BENCH_FULL").is_ok() {
        tiers.push("tiny");
    }

    let mut table = Table::new(
        "Table 4 — model size (paper improvement: 4.33% / 7.45% / 7.49%)",
        &["model", "params", "baseline_ppl", "diloco_ppl", "rel_improve", "abs_improve"],
    );
    for model in tiers {
        if !std::path::Path::new(&artifacts_dir())
            .join(format!("{model}.manifest.json"))
            .exists()
        {
            println!("skipping {model}: artifacts not built");
            continue;
        }
        let rt = load_runtime(model);
        let mut cfg = base_config(ctx.scale);
        cfg.model = model.to_string();
        // Bigger tiers get shorter rounds to keep the bench bounded, but
        // baseline/DiLoCo stay compute-matched within a tier.
        if model == "micro" {
            cfg.rounds = 6;
            cfg.pretrain_steps = 40;
        }
        if model == "tiny" {
            cfg.rounds = 4;
            cfg.inner_steps = 10;
            cfg.pretrain_steps = 20;
        }
        let coord = Coordinator::new(cfg.clone(), rt.clone())?;
        let n_steps = cfg.rounds * cfg.inner_steps;

        let mut pre = RunMetrics::new("pretrain");
        let pretrained =
            coord.plain_train(rt.init_params()?, 0.0, cfg.pretrain_steps, &mut pre, 0)?;

        let mut baseline = RunMetrics::new("baseline");
        coord.plain_train(
            pretrained.clone(),
            cfg.pretrain_steps as f64,
            n_steps,
            &mut baseline,
            0,
        )?;
        let report = coord.run_from(Some(pretrained))?;
        let (b, d) = (baseline.final_ppl(), report.metrics.final_ppl());
        table.row(vec![
            model.to_string(),
            rt.manifest.config.param_count.to_string(),
            fmt(b),
            fmt(d),
            format!("{:.2}%", 100.0 * (b - d) / b),
            fmt(b - d),
        ]);
    }
    ctx.emit(&table);
    ctx.finish();
    Ok(())
}
