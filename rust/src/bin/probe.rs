fn main() -> anyhow::Result<()> {
    let client = xla::PjRtClient::cpu()?;
    for rt in ["True", "False"] {
        let path = format!("/tmp/probe_{rt}.hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let exe = client.compile(&xla::XlaComputation::from_proto(&proto))?;
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]);
        let y = xla::Literal::vec1(&[10f32, 20., 30., 40.]);
        let out = exe.execute::<xla::Literal>(&[x, y])?;
        println!("return_tuple={rt}: replicas={} bufs={}", out.len(), out[0].len());
        for (i, b) in out[0].iter().enumerate() {
            let lit = b.to_literal_sync()?;
            println!("  buf{i}: shape={:?}", lit.shape()?);
        }
    }
    Ok(())
}
