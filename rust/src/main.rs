//! `diloco` — the launcher CLI.
//!
//! Subcommands:
//!   train    Run a full DiLoCo experiment from a TOML config.
//!   eval     Evaluate a checkpoint on the validation split.
//!   data     Synthesize the corpus and print shard statistics.
//!   inspect  Dump the AOT artifact manifest for a model preset.
//!   worker   Serve one island as a TCP fabric worker process
//!            (normally spawned by `train --fabric tcp`, not by hand).
//!
//! Examples:
//!   diloco train --config experiments/diloco_nano.toml --out runs/
//!   diloco train --config exp.toml --fabric tcp
//!   diloco inspect --artifacts artifacts --model nano
//!   diloco data --topics 8 --docs 400 --workers 8 --non-iid

use diloco::config::toml::TomlDoc;
use diloco::config::{
    AdversaryConfig, AggregateConfig, ChurnConfig, EngineConfig, ExperimentConfig,
    SpeedConfig, StreamConfig, TopologyConfig,
};
use diloco::coordinator::Coordinator;
use diloco::data::Dataset;
use diloco::engine::InnerPhaseExecutor as _;
use diloco::runtime::Runtime;
use std::sync::Arc;

/// Minimal flag parser: `--key value` and `--flag` booleans.
struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "data" => cmd_data(&args),
        "inspect" => cmd_inspect(&args),
        "worker" => cmd_worker(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "diloco — Distributed Low-Communication training (DiLoCo)\n\n\
         USAGE: diloco <train|eval|data|inspect> [--flags]\n\n\
         train   --config <exp.toml> [--out runs/] [--ckpt out.ckpt]\n\
         \x20       [--engine auto|sequential|parallel] [--threads N]\n\
         \x20       [--stream fragments=4,schedule=staggered,codec=q8,error_feedback=true]\n\
         \x20       (schedules: every-round|staggered|overlapped; codecs: f32|f16|q8|q4|q2)\n\
         \x20       [--topology star|ring|gossip|hierarchical[:G]]\n\
         \x20       [--churn leave:w3@r10,join:w8@r20,ramp:4..8]\n\
         \x20       [--speed w3=2.0,w7=1.5..3.0,jitter:0.2] [--delay D] [--discount G]\n\
         \x20       (speed: per-worker compute-time factors; delay: apply outer\n\
         \x20        contributions D rounds late; discount: stale weight gamma^s)\n\
         \x20       [--aggregate mean|trimmed:N|median|krum:F]\n\
         \x20       [--adversary flip:0.25|noise:0.25:3.0|nan:0.25|stale:0.25]\n\
         \x20       (aggregate: robust outer estimator; adversary: kind:fraction[:scale]\n\
         \x20        — floor(fraction*pool) seeded workers corrupt their outer delta)\n\
         \x20       [--save-every N --save-path state.ckpt] [--resume state.ckpt]\n\
         \x20       [--fabric sim|tcp] (tcp: islands run as real worker processes;\n\
         \x20        sim — the default — is the bitwise golden path)\n\
         eval    --ckpt <file> [--artifacts artifacts] [--model nano]\n\
         data    [--topics 8] [--docs 400] [--workers 8] [--non-iid] [--seed 0]\n\
         inspect [--artifacts artifacts] [--model nano]\n\
         worker  --host H --port P --run-id ID [--artifacts artifacts] [--model nano]\n\
         \x20       (serve one island for a `train --fabric tcp` coordinator)"
    );
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_toml(&TomlDoc::load(path)?)?,
        None => {
            eprintln!("no --config given; using built-in nano defaults");
            ExperimentConfig::paper_default(&args.get_or("artifacts", "artifacts"), "nano")
        }
    };
    if let Some(engine) = args.get("engine") {
        cfg.engine = EngineConfig::parse(engine)?;
    }
    if let Some(threads) = args.get("threads") {
        let threads: usize = threads
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --threads {threads:?}: {e}"))?;
        cfg.engine = match cfg.engine {
            EngineConfig::Sequential => {
                anyhow::bail!("--threads conflicts with --engine sequential")
            }
            EngineConfig::Parallel { threads: t } if t != 0 && t != threads => {
                anyhow::bail!("--threads {threads} conflicts with --engine parallel:{t}")
            }
            _ => EngineConfig::Parallel { threads },
        };
    }
    if let Some(stream) = args.get("stream") {
        cfg.stream = StreamConfig::parse(stream)?;
    }
    if let Some(topology) = args.get("topology") {
        cfg.topology = TopologyConfig::parse(topology)?;
    }
    if let Some(churn) = args.get("churn") {
        cfg.churn = Some(ChurnConfig::parse(churn)?);
    }
    if let Some(speed) = args.get("speed") {
        cfg.speed = SpeedConfig::parse(speed)?;
    }
    if let Some(delay) = args.get("delay") {
        cfg.sync.delay_rounds = delay
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --delay {delay:?}: {e}"))?;
    }
    if let Some(discount) = args.get("discount") {
        cfg.sync.discount = discount
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --discount {discount:?}: {e}"))?;
    }
    if let Some(aggregate) = args.get("aggregate") {
        cfg.aggregate = AggregateConfig::parse(aggregate)?;
    }
    if let Some(adversary) = args.get("adversary") {
        cfg.adversary = Some(AdversaryConfig::parse(adversary)?);
    }
    if let Some(every) = args.get("save-every") {
        cfg.ckpt.save_every = every
            .parse()
            .map_err(|e| anyhow::anyhow!("bad --save-every {every:?}: {e}"))?;
    }
    if let Some(path) = args.get("save-path") {
        cfg.ckpt.path = Some(path.to_string());
    }
    if let Some(resume) = args.get("resume") {
        cfg.ckpt.resume = Some(resume.to_string());
    }
    if let Some(fabric) = args.get("fabric") {
        cfg.fabric.kind = diloco::config::FabricKind::parse(fabric)?;
    }
    // Self-spawned TCP workers default to this very binary.
    if cfg.fabric.kind == diloco::config::FabricKind::Tcp
        && cfg.fabric.spawn
        && cfg.fabric.worker_bin.is_none()
    {
        cfg.fabric.worker_bin =
            Some(std::env::current_exe()?.to_string_lossy().into_owned());
    }
    cfg.validate()?;
    println!(
        "DiLoCo: model={} k={} H={} T={} pretrain={} outer={} non_iid={} engine={:?} \
         topology={}",
        cfg.model,
        cfg.workers,
        cfg.inner_steps,
        cfg.rounds,
        cfg.pretrain_steps,
        cfg.outer_opt.name(),
        cfg.data.non_iid,
        cfg.engine,
        cfg.topology.name()
    );
    if !cfg.stream.is_monolithic() {
        println!(
            "stream: fragments={} schedule={} codec={}",
            cfg.stream.fragments,
            cfg.stream.schedule.name(),
            cfg.stream.codec.name()
        );
    }
    if let Some(churn) = &cfg.churn {
        println!(
            "churn: {} events{} over a pool of {} workers",
            churn.events.len(),
            churn
                .ramp
                .map(|(a, b)| format!(" + ramp {a}..{b}"))
                .unwrap_or_default(),
            cfg.pool_size()
        );
    }
    if !cfg.speed.is_uniform() {
        println!(
            "speed: {} worker profiles, jitter {:.0}%",
            cfg.speed.profiles.len(),
            100.0 * cfg.speed.jitter
        );
    }
    if !cfg.sync.is_synchronous() {
        println!(
            "async: outer contributions applied {} rounds late, discount {:.2}^s",
            cfg.sync.delay_rounds, cfg.sync.discount
        );
    }
    if !cfg.aggregate.is_default() {
        println!("aggregate: robust outer estimator {}", cfg.aggregate.label());
    }
    if let Some(adv) = &cfg.adversary {
        println!(
            "adversary: {} — {} of {} workers compromised (ids drawn from the seed)",
            adv.label(),
            adv.n_attackers(cfg.pool_size()),
            cfg.pool_size()
        );
    }
    if cfg.ckpt.save_every > 0 {
        println!(
            "ckpt: saving TrainState every {} rounds to {}",
            cfg.ckpt.save_every,
            cfg.ckpt.path.as_deref().unwrap_or("?")
        );
    }
    if let Some(resume) = &cfg.ckpt.resume {
        println!("ckpt: resuming from {resume}");
    }
    if cfg.fabric.kind == diloco::config::FabricKind::Tcp {
        println!(
            "fabric: tcp on {}:{} ({}), billing via the embedded simulator",
            cfg.fabric.host,
            cfg.fabric.port,
            if cfg.fabric.spawn { "spawning workers" } else { "awaiting workers" }
        );
    }
    let rt = Arc::new(Runtime::load(&cfg.artifacts_dir, &cfg.model)?);
    println!(
        "artifacts: {} params, kernels={}, {} artifacts compiled lazily",
        rt.manifest.config.param_count,
        rt.manifest.config.kernels,
        rt.manifest.artifacts.len()
    );
    let coord = Coordinator::new(cfg, rt)?;
    println!("engine: {}", coord.engine().name());
    let report = coord.run()?;

    let m = &report.metrics;
    println!("\n-- run summary --");
    println!("{}", m.summary_json());
    for p in &m.eval_curve {
        println!("step {:>6}  nll {:.4}  ppl {:.3}", p.step, p.mean_nll, p.ppl);
    }
    println!(
        "comm: {} msgs, {:.2} MB total, {} dropped; sim wall {:.1}s \
         (compute {:.1}s + comm {:.1}s, {:.1}s idle at barriers); \
         coordinator overhead {:.1}%",
        m.comm_messages,
        m.comm_bytes as f64 / 1e6,
        m.comm_dropped,
        m.sim_wall_seconds(),
        m.sim_compute_seconds,
        m.sim_comm_seconds,
        m.sim_idle_seconds,
        100.0 * m.phases.overhead_fraction()
    );
    if !coord.cfg.stream.is_monolithic() {
        println!(
            "stream: {:.2} MB up vs {:.2} MB monolithic baseline \
             ({:.1}x less); codec err L2 {:.3e}",
            m.comm_bytes_up as f64 / 1e6,
            m.comm_bytes_up_baseline as f64 / 1e6,
            m.up_savings_factor(),
            m.codec_err_l2
        );
    }
    if coord.cfg.topology.is_decentralized() {
        let dist = report
            .round_stats
            .last()
            .map(|rs| rs.consensus_dist)
            .unwrap_or(0.0);
        println!(
            "topology {}: {} replicas, consensus dist {:.3e} (eval curve = consensus model)",
            coord.cfg.topology.name(),
            report.replica_evals.len(),
            dist
        );
        for (r, p) in report.replica_evals.iter().enumerate() {
            println!("  replica {r}: nll {:.4}  ppl {:.3}", p.mean_nll, p.ppl);
        }
    }

    if let Some(out) = args.get("out") {
        m.write_curves(out)?;
        println!("curves written under {out}/");
    }
    if let Some(ckpt) = args.get("ckpt") {
        diloco::checkpoint::save(ckpt, &coord.runtime().manifest, &report.final_params)?;
        println!("checkpoint written to {ckpt}");
    }
    Ok(())
}

/// Serve one island as a TCP fabric worker: connect to the
/// coordinator's rendezvous endpoint, then run inner phases on demand
/// until a SHUTDOWN frame (or the coordinator vanishing) ends the
/// process. The `--die-*`/`--hang-*` flags are fault-injection hooks
/// for the test suite — they make the worker fail on cue so the
/// coordinator's reconnect-as-churn path can be exercised.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let port: u16 = args
        .get("port")
        .ok_or_else(|| anyhow::anyhow!("--port required"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("bad --port: {e}"))?;
    let run_id = args
        .get("run-id")
        .ok_or_else(|| anyhow::anyhow!("--run-id required"))?
        .to_string();
    let parse_phase = |key: &str| -> anyhow::Result<Option<u64>> {
        args.get(key)
            .map(|v| {
                v.parse()
                    .map_err(|e| anyhow::anyhow!("bad --{key} {v:?}: {e}"))
            })
            .transpose()
    };
    let opts = diloco::comm::tcp::WorkerOpts {
        host: args.get_or("host", "127.0.0.1"),
        port,
        run_id,
        artifacts_dir: args.get_or("artifacts", "artifacts"),
        model: args.get_or("model", "nano"),
        connect_timeout_s: args.get_or("connect-timeout-s", "30").parse()?,
        die_after_phases: parse_phase("die-after-phases")?,
        die_mid_phase: parse_phase("die-mid-phase")?,
        hang_mid_phase: parse_phase("hang-mid-phase")?,
    };
    diloco::comm::tcp::serve_worker(opts)
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let model = args.get_or("model", "nano");
    let ckpt = args
        .get("ckpt")
        .ok_or_else(|| anyhow::anyhow!("--ckpt required"))?;
    let rt = Arc::new(Runtime::load(&dir, &model)?);
    let params = diloco::checkpoint::load(ckpt, &rt.manifest)?;
    let mut cfg = ExperimentConfig::paper_default(&dir, &model);
    cfg.seed = args.get_or("seed", "0").parse()?;
    let coord = Coordinator::new(cfg, rt)?;
    let p = coord.evaluate(&params)?;
    println!("ckpt {ckpt}: mean nll {:.4}, ppl {:.3}", p.mean_nll, p.ppl);
    Ok(())
}

fn cmd_data(args: &Args) -> anyhow::Result<()> {
    let mut cfg = diloco::config::DataConfig {
        n_topics: args.get_or("topics", "8").parse()?,
        n_docs: args.get_or("docs", "400").parse()?,
        doc_len: args.get_or("doc-len", "220").parse()?,
        non_iid: args.get("non-iid").is_some(),
        mix: args.get_or("mix", "0.0").parse()?,
        holdout: 0.1,
    };
    if args.get("iid").is_some() {
        cfg.non_iid = false;
    }
    let k: usize = args.get_or("workers", "8").parse()?;
    let vocab: usize = args.get_or("vocab", "256").parse()?;
    let seed: u64 = args.get_or("seed", "0").parse()?;
    let ds = Dataset::build(&cfg, k, vocab, seed)?;
    println!(
        "corpus: {} docs × ~{} words, {} topics, non_iid={}",
        cfg.n_docs, cfg.doc_len, cfg.n_topics, cfg.non_iid
    );
    println!("tokenizer: {} pieces (target {vocab})", ds.tokenizer.pieces());
    for (i, (shard, docs)) in
        ds.shards.iter().zip(&ds.shard_doc_counts).enumerate()
    {
        println!("shard {i}: {docs} docs, {} tokens", shard.len());
    }
    println!("holdout: {} tokens", ds.holdout.len());
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let model = args.get_or("model", "nano");
    let rt = Runtime::load(&dir, &model)?;
    let c = &rt.manifest.config;
    println!(
        "model {} (kernels={}): {} layers, d_model {}, {} heads × d_head {}, \
         vocab {}, seq {}, batch {} — {} params",
        c.name, c.kernels, c.n_layers, c.d_model, c.n_heads, c.d_head,
        c.vocab_size, c.seq_len, c.batch_size, c.param_count
    );
    println!("{} parameter leaves; artifacts:", rt.manifest.params.len());
    for (key, art) in &rt.manifest.artifacts {
        println!(
            "  {key:<16} {} inputs, {} outputs  ({})",
            art.inputs.len(),
            art.outputs.len(),
            art.file
        );
    }
    println!("train chunk sizes: {:?}", rt.chunk_sizes());
    Ok(())
}
