//! # diloco — Distributed Low-Communication training (DiLoCo)
//!
//! A rust + JAX + Pallas reproduction of *DiLoCo: Distributed
//! Low-Communication Training of Language Models* (Douillard et al., 2023).
//!
//! Architecture (see `DESIGN.md`):
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution:
//!   round orchestration ([`coordinator`]), the island execution engine
//!   ([`engine`] — sequential reference path or truly parallel OS
//!   threads, bitwise-identical), outer optimizers ([`coordinator::opt`]),
//!   the pluggable communication fabric ([`comm::Fabric`] — the
//!   simulated wide-area network [`comm::SimNet`] by default, or real
//!   worker OS processes over TCP via [`comm::TcpFabric`]) with its
//!   streaming fragment/codec layers ([`comm::fragment`],
//!   [`comm::codec`]) and pluggable sync topologies
//!   ([`comm::topology`] — star, ring all-reduce, NoLoCo-style gossip,
//!   DiLoCoX-style hierarchical), data sharding ([`data`]), metrics,
//!   checkpoints, config and CLI.
//! * **Layer 2/1 (build-time python, never on the training path)** — the
//!   transformer fwd/bwd + fused AdamW and the Pallas kernels, lowered
//!   once by `python/compile/aot.py` into `artifacts/*.hlo.txt` which
//!   [`runtime`] loads through the PJRT C API (`xla` crate).
//!
//! The hot path is rust-only: device-resident parameter/optimizer buffers
//! stepped by `execute_b`, with host round-trips only at the H-step round
//! boundaries — exactly the communication pattern the paper exploits.
//!
//! # Configuring a run
//!
//! Every experiment is one [`ExperimentConfig`] — built programmatically,
//! or parsed from the TOML subset ([`config::toml`]) by the CLI. The
//! communication axes compose: `[stream]` picks fragments × schedule ×
//! codec, `[topology]` picks who exchanges outer gradients with whom,
//! `[speed]` + `[sync]` pick the async scheduling layer (per-worker
//! compute-speed heterogeneity and DiLoCoX-style delayed application of
//! outer contributions — [`config::SpeedConfig`], [`config::SyncConfig`]).
//!
//! ```
//! use diloco::config::{ExperimentConfig, TopologyConfig};
//!
//! let mut cfg = ExperimentConfig::paper_default("artifacts", "nano");
//! assert_eq!(cfg.topology, TopologyConfig::Star); // classic DiLoCo
//! cfg.topology = TopologyConfig::parse("gossip").unwrap();
//! cfg.validate().unwrap();
//! ```

pub mod bench;
pub mod checkpoint;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod metrics;
pub mod runtime;
pub mod util;
pub mod worker;

pub use config::ExperimentConfig;
pub use coordinator::{Coordinator, DilocoReport};
pub use runtime::Runtime;
