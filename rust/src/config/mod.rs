//! Typed experiment configuration + TOML loading + presets.
//!
//! One [`ExperimentConfig`] fully describes a run: which AOT artifact set
//! to load, how to synthesize and shard data, the DiLoCo schedule
//! (k, H, T, outer optimizer), failure injection, and metric sinks.
//! Benches and examples construct it programmatically; the CLI loads it
//! from a TOML file (`config::toml` subset parser).

pub mod presets;
pub mod toml;

use crate::comm::codec::Codec;
use crate::util::rng::Rng;
use toml::TomlDoc;

/// Which outer optimizer updates the global parameters (paper Fig. 6).
#[derive(Clone, Debug, PartialEq)]
pub enum OuterOptConfig {
    /// Plain SGD — equivalent to classical FedAvg (McMahan et al., 2017).
    Sgd { lr: f32 },
    /// SGD with (heavy-ball) momentum.
    SgdM { lr: f32, mu: f32 },
    /// Nesterov momentum — the paper's choice (lr 0.7, μ 0.9).
    Nesterov { lr: f32, mu: f32 },
    /// Adam — equivalent to FedOpt (Reddi et al., 2021). The paper found
    /// ε must be raised to ~0.1 for stability.
    Adam { lr: f32, b1: f32, b2: f32, eps: f32 },
}

impl OuterOptConfig {
    pub fn paper_default() -> Self {
        OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OuterOptConfig::Sgd { .. } => "sgd",
            OuterOptConfig::SgdM { .. } => "sgdm",
            OuterOptConfig::Nesterov { .. } => "nesterov",
            OuterOptConfig::Adam { .. } => "adam",
        }
    }
}

/// Which [`crate::engine::InnerPhaseExecutor`] runs the islands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineConfig {
    /// Parallel when the run can have ≥ 2 concurrent workers, sequential
    /// otherwise (the default).
    Auto,
    /// Islands run back-to-back on one thread (reference path).
    Sequential,
    /// Islands run on real OS threads; `threads` caps the pool
    /// (0 = one per available core).
    Parallel { threads: usize },
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig::Auto
    }
}

impl EngineConfig {
    /// Build the executor for a run whose worker pool peaks at `max_k`.
    pub fn build(&self, max_k: usize) -> Box<dyn crate::engine::InnerPhaseExecutor> {
        match self {
            EngineConfig::Sequential => Box::new(crate::engine::Sequential),
            EngineConfig::Parallel { threads } => {
                Box::new(crate::engine::ParallelIslands::new(*threads))
            }
            EngineConfig::Auto => {
                if max_k >= 2 {
                    Box::new(crate::engine::ParallelIslands::new(0))
                } else {
                    Box::new(crate::engine::Sequential)
                }
            }
        }
    }

    /// Parse `auto` / `sequential` / `parallel` / `parallel:N`.
    pub fn parse(s: &str) -> anyhow::Result<EngineConfig> {
        match s {
            "auto" => Ok(EngineConfig::Auto),
            "sequential" | "seq" => Ok(EngineConfig::Sequential),
            "parallel" => Ok(EngineConfig::Parallel { threads: 0 }),
            other => {
                if let Some(n) = other.strip_prefix("parallel:") {
                    let threads = n
                        .trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad engine thread count {n:?}: {e}"))?;
                    Ok(EngineConfig::Parallel { threads })
                } else {
                    anyhow::bail!(
                        "unknown engine {other:?} (want auto|sequential|parallel[:N])"
                    )
                }
            }
        }
    }

    /// Injectable env override (`ENGINE=sequential` etc.) — pure function
    /// of its argument so tests never mutate process env.
    pub fn from_env_var(v: Option<&str>) -> anyhow::Result<EngineConfig> {
        match v {
            None => Ok(EngineConfig::Auto),
            Some(s) => EngineConfig::parse(s),
        }
    }
}

/// Which synchronization topology moves outer gradients between islands
/// (`[topology]` in TOML, `--topology` on the CLI) — see
/// [`crate::comm::topology`] for the schedules themselves.
///
/// The default, [`TopologyConfig::Star`], is DiLoCo's all-to-coordinator
/// reduction and reproduces the pre-topology loop bitwise. `Ring` and
/// `Gossip` are decentralized: every worker keeps its own model replica
/// and outer-optimizer state, and the run reports per-replica and
/// consensus perplexity plus a consensus-distance metric.
///
/// ```
/// use diloco::config::TopologyConfig;
///
/// assert_eq!(TopologyConfig::parse("star").unwrap(), TopologyConfig::default());
/// assert_eq!(
///     TopologyConfig::parse("hierarchical:4").unwrap(),
///     TopologyConfig::Hierarchical { groups: 4 },
/// );
/// assert!(TopologyConfig::parse("mesh").is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyConfig {
    /// All-to-coordinator star (Algorithm 1; the default).
    Star,
    /// Ring all-reduce: `2(k−1)` lane-overlapped hops of `1/k` chunks,
    /// one model + outer state per worker (all replicas stay equal).
    Ring,
    /// Seeded random pairwise gossip averaging (NoLoCo,
    /// arXiv:2506.10911); one model + outer state per worker.
    Gossip,
    /// Two-level star: intra-group aggregation onto a leader over free
    /// local links, then leader ↔ root over the billed WAN (DiLoCoX,
    /// arXiv:2506.21263).
    Hierarchical {
        /// Number of groups `G` (clamped to the active worker count).
        groups: usize,
    },
}

impl Default for TopologyConfig {
    fn default() -> Self {
        TopologyConfig::Star
    }
}

impl TopologyConfig {
    /// Parse `star` / `ring` / `gossip` / `hierarchical[:G]`.
    pub fn parse(s: &str) -> anyhow::Result<TopologyConfig> {
        match s {
            "star" => Ok(TopologyConfig::Star),
            "ring" => Ok(TopologyConfig::Ring),
            "gossip" => Ok(TopologyConfig::Gossip),
            "hierarchical" | "hier" => Ok(TopologyConfig::Hierarchical { groups: 2 }),
            other => {
                if let Some(g) = other
                    .strip_prefix("hierarchical:")
                    .or_else(|| other.strip_prefix("hier:"))
                {
                    let groups: usize = g.trim().parse().map_err(|e| {
                        anyhow::anyhow!("bad hierarchical group count {g:?}: {e}")
                    })?;
                    anyhow::ensure!(groups >= 1, "hierarchical needs >= 1 group");
                    Ok(TopologyConfig::Hierarchical { groups })
                } else {
                    anyhow::bail!(
                        "unknown topology {other:?} \
                         (want star|ring|gossip|hierarchical[:G])"
                    )
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TopologyConfig::Star => "star",
            TopologyConfig::Ring => "ring",
            TopologyConfig::Gossip => "gossip",
            TopologyConfig::Hierarchical { .. } => "hierarchical",
        }
    }

    /// Decentralized topologies keep one model replica + outer state per
    /// worker; centralized ones keep a single global replica.
    pub fn is_decentralized(&self) -> bool {
        matches!(self, TopologyConfig::Ring | TopologyConfig::Gossip)
    }

    /// Build the runtime schedule; `seed` feeds gossip's per-round
    /// pairing stream.
    pub fn build(&self, seed: u64) -> Box<dyn crate::comm::topology::Topology> {
        use crate::comm::topology as topo;
        match *self {
            TopologyConfig::Star => Box::new(topo::Star),
            TopologyConfig::Ring => Box::new(topo::Ring),
            TopologyConfig::Gossip => Box::new(topo::Gossip { seed }),
            TopologyConfig::Hierarchical { groups } => {
                Box::new(topo::Hierarchical { groups })
            }
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if let TopologyConfig::Hierarchical { groups } = self {
            anyhow::ensure!(
                *groups >= 1,
                "topology.groups must be >= 1 (got {groups})"
            );
        }
        Ok(())
    }
}

/// Which fragments synchronize each round, and how the transfer cost is
/// charged (Streaming DiLoCo, arXiv:2501.18512).
///
/// ```
/// use diloco::config::SyncSchedule;
///
/// let stag = SyncSchedule::parse("staggered").unwrap();
/// assert_eq!(stag.fragments_due(5, 4), vec![1]); // fragment (round mod P)
/// assert!(!stag.defers_barrier());
/// assert!(SyncSchedule::parse("overlapped").unwrap().defers_barrier());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncSchedule {
    /// All fragments every round, transfer billed as a sync barrier —
    /// with one fragment this is classic DiLoCo, bitwise identical to
    /// the pre-streaming fabric.
    EveryRound,
    /// Fragment `round mod P` each round: each round ships 1/P of the
    /// model, every fragment still syncs every P rounds.
    Staggered,
    /// All fragments every round, but the transfer overlaps the *next*
    /// round's inner compute instead of blocking at a barrier.
    Overlapped,
}

impl SyncSchedule {
    pub fn parse(s: &str) -> anyhow::Result<SyncSchedule> {
        match s {
            "every-round" | "every_round" | "every" => Ok(SyncSchedule::EveryRound),
            "staggered" => Ok(SyncSchedule::Staggered),
            "overlapped" => Ok(SyncSchedule::Overlapped),
            other => anyhow::bail!(
                "unknown sync schedule {other:?} (want every-round|staggered|overlapped)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncSchedule::EveryRound => "every-round",
            SyncSchedule::Staggered => "staggered",
            SyncSchedule::Overlapped => "overlapped",
        }
    }

    /// Fragments (out of `p`) that synchronize in round `round`.
    pub fn fragments_due(&self, round: usize, p: usize) -> Vec<usize> {
        match self {
            SyncSchedule::EveryRound | SyncSchedule::Overlapped => (0..p).collect(),
            SyncSchedule::Staggered => vec![round % p.max(1)],
        }
    }

    /// Whether the round's transfer time is deferred into the next
    /// inner phase instead of billed as a barrier.
    pub fn defers_barrier(&self) -> bool {
        matches!(self, SyncSchedule::Overlapped)
    }
}

/// Streaming partial-sync fabric configuration (`[stream]` in TOML,
/// `--stream fragments=4,schedule=staggered,codec=q8` on the CLI).
///
/// The default — one fragment, every-round schedule, f32 codec — is the
/// monolithic full-precision sync and reproduces pre-streaming traces
/// bitwise (the golden-trace suite pins this).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StreamConfig {
    /// Number of parameter fragments P (≥ 1; clamped to the parameter
    /// count at plan time).
    pub fragments: usize,
    pub schedule: SyncSchedule,
    /// Outer-gradient wire codec.
    pub codec: Codec,
    /// Per-worker error feedback (MuLoCo, arXiv 2505.23725): each worker
    /// keeps residual = intended − sent after compression and folds it
    /// into its next outer delta. Lossy compression becomes unbiased
    /// over rounds; under the f32 codec with no pruning the residual is
    /// exactly zero, and fragments lost to drops stay lost (their
    /// residual is cleared, preserving the drop semantics).
    pub error_feedback: bool,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            fragments: 1,
            schedule: SyncSchedule::EveryRound,
            codec: Codec::F32,
            error_feedback: false,
        }
    }
}

impl StreamConfig {
    /// Parse the CLI mini-language:
    /// `fragments=4,schedule=staggered,codec=q8,error_feedback=true`
    /// (keys optional, any order; omitted keys keep their defaults).
    pub fn parse(s: &str) -> anyhow::Result<StreamConfig> {
        let mut cfg = StreamConfig::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad --stream item {part:?} (want key=value)"))?;
            match key.trim() {
                "fragments" => {
                    cfg.fragments = value.trim().parse().map_err(|e| {
                        anyhow::anyhow!("bad fragment count {value:?}: {e}")
                    })?
                }
                "schedule" => cfg.schedule = SyncSchedule::parse(value.trim())?,
                "codec" => cfg.codec = Codec::parse(value.trim())?,
                "error_feedback" => {
                    cfg.error_feedback = value.trim().parse().map_err(|e| {
                        anyhow::anyhow!("bad error_feedback flag {value:?}: {e}")
                    })?
                }
                other => anyhow::bail!(
                    "unknown --stream key {other:?} \
                     (want fragments|schedule|codec|error_feedback)"
                ),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.fragments >= 1,
            "stream.fragments must be >= 1 (got {})",
            self.fragments
        );
        Ok(())
    }

    /// True for the monolithic full-precision default.
    pub fn is_monolithic(&self) -> bool {
        *self == StreamConfig::default()
    }
}

/// Child-stream tag of the seeded speed-jitter draws (distinct from the
/// fabric's `child(7)`, the workers' `child(100 + i)`, and the data
/// pipeline's streams).
const SPEED_JITTER_STREAM: u64 = 424_242;

/// One worker's base compute-speed profile across the run. Factors are
/// *time multipliers* on the worker's simulated per-round compute: 1.0
/// is nominal, 2.0 runs half as fast (twice the time), 0.5 twice as
/// fast.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SpeedProfile {
    /// The same factor every round.
    Constant(f64),
    /// Linear ramp from the first factor to the second across the run
    /// (a machine degrading — or recovering — over time).
    Ramp(f64, f64),
}

/// Per-worker compute-speed model (`[speed]` in TOML, `--speed` on the
/// CLI) — the heterogeneity axis of the async scheduling layer
/// (DESIGN.md §11). Real federated clusters are speed-heterogeneous;
/// under the synchronous loop every round costs the straggler's time
/// while fast islands idle at the barrier. The speed model makes that
/// measurable (per-round critical path + idle seconds) and, combined
/// with `sync.delay_rounds`, recoverable.
///
/// DSL: comma-separated items, each one of
///
/// * `wW=F`     — worker `W` runs at constant factor `F`,
/// * `wW=A..B`  — worker `W`'s factor ramps linearly from `A` to `B`,
/// * `jitter:S` — every `(worker, round)` multiplies its base factor by
///   a seeded draw from `U[1-S, 1+S]` (at most one `jitter:` item).
///
/// Unlisted workers run at the nominal factor 1.0; per worker the last
/// listed profile wins. The empty model (no items) is *uniform* and
/// keeps runs bitwise on the legacy trace.
///
/// ```
/// use diloco::config::SpeedConfig;
///
/// let s = SpeedConfig::parse("w3=2.0,w1=1.0..3.0,jitter:0.2").unwrap();
/// assert!(!s.is_uniform());
/// assert_eq!(SpeedConfig::default(), SpeedConfig::parse("").unwrap());
/// assert!(SpeedConfig::parse("w3=0").is_err());
/// // Jitter draws are a pure function of (seed, worker, round).
/// let a = s.factor(3, 5, 10, 42);
/// assert_eq!(a, s.factor(3, 5, 10, 42));
/// assert!(a > 0.0);
/// ```
#[derive(Clone, Debug, PartialEq, Default)]
pub struct SpeedConfig {
    /// `(worker id, profile)` pairs; per worker the last entry wins.
    pub profiles: Vec<(usize, SpeedProfile)>,
    /// Seeded multiplicative jitter amplitude in `[0, 1)`; 0.0 = none.
    pub jitter: f64,
}

impl SpeedConfig {
    /// Parse the `--speed` DSL (see the type-level docs for the
    /// grammar). The empty string parses to the uniform model.
    pub fn parse(s: &str) -> anyhow::Result<SpeedConfig> {
        let mut cfg = SpeedConfig::default();
        let mut saw_jitter = false;
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(amp) = part.strip_prefix("jitter:") {
                anyhow::ensure!(!saw_jitter, "speed allows one jitter: item");
                saw_jitter = true;
                cfg.jitter = amp
                    .trim()
                    .parse()
                    .map_err(|e| anyhow::anyhow!("bad speed jitter {amp:?}: {e}"))?;
                continue;
            }
            let (w, spec) = part.split_once('=').ok_or_else(|| {
                anyhow::anyhow!("bad --speed item {part:?} (want wW=F|wW=A..B|jitter:S)")
            })?;
            let worker: usize = w
                .trim()
                .strip_prefix('w')
                .ok_or_else(|| anyhow::anyhow!("bad speed worker {w:?} (want wN)"))?
                .parse()
                .map_err(|e| anyhow::anyhow!("bad speed worker {w:?}: {e}"))?;
            let profile = match spec.split_once("..") {
                Some((a, b)) => SpeedProfile::Ramp(
                    a.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad speed ramp start {a:?}: {e}"))?,
                    b.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad speed ramp end {b:?}: {e}"))?,
                ),
                None => SpeedProfile::Constant(
                    spec.trim()
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad speed factor {spec:?}: {e}"))?,
                ),
            };
            cfg.profiles.push((worker, profile));
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// The uniform model: every worker at factor 1.0 every round — the
    /// legacy timing path, guaranteed bitwise.
    pub fn is_uniform(&self) -> bool {
        self.profiles.is_empty() && self.jitter == 0.0
    }

    /// Largest worker id any profile names, plus one (0 when none).
    pub fn max_profiled_worker(&self) -> usize {
        self.profiles.iter().map(|&(w, _)| w + 1).max().unwrap_or(0)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        for &(w, p) in &self.profiles {
            let ok = match p {
                SpeedProfile::Constant(f) => f > 0.0 && f.is_finite(),
                SpeedProfile::Ramp(a, b) => {
                    a > 0.0 && b > 0.0 && a.is_finite() && b.is_finite()
                }
            };
            anyhow::ensure!(
                ok,
                "speed factors must be positive and finite (worker {w}: {p:?})"
            );
        }
        anyhow::ensure!(
            (0.0..1.0).contains(&self.jitter),
            "speed jitter must be in [0, 1) (got {})",
            self.jitter
        );
        Ok(())
    }

    /// Compute-time factor of `worker` in round `round` of a
    /// `total`-round run — a pure function of `(self, seed, worker,
    /// round)`, so the same profile replays identically under any
    /// engine, execution order, or resume point.
    pub fn factor(&self, worker: usize, round: usize, total: usize, seed: u64) -> f64 {
        let mut f = 1.0;
        for &(w, p) in &self.profiles {
            if w == worker {
                f = match p {
                    SpeedProfile::Constant(c) => c,
                    SpeedProfile::Ramp(a, b) => {
                        if total <= 1 {
                            b
                        } else {
                            a + (round as f64 / (total - 1) as f64) * (b - a)
                        }
                    }
                };
            }
        }
        if self.jitter > 0.0 {
            let u = Rng::new(seed)
                .child(SPEED_JITTER_STREAM)
                .child(worker as u64)
                .child(round as u64)
                .f64();
            f *= 1.0 - self.jitter + 2.0 * self.jitter * u;
        }
        f
    }
}

/// Asynchronous outer-loop schedule (`[sync]` in TOML; `--delay` /
/// `--discount` on the CLI) — DiLoCoX-style delayed application of
/// outer contributions (arXiv:2506.21263), generalized from one round
/// to `D` (DESIGN.md §11).
///
/// With `delay_rounds = D > 0`, the contribution a worker uploads after
/// round `t`'s inner phase is folded into the global model at the end
/// of round `t + D`: workers train round `t + 1` against the global
/// model of round `t − D`, and the upload's transfer time hides behind
/// the next `D` inner phases instead of blocking at a barrier. `D = 0`
/// is the synchronous legacy loop, bitwise.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SyncConfig {
    /// Rounds between a contribution's compute and its application
    /// (0 = synchronous).
    pub delay_rounds: usize,
    /// Per-round staleness discount γ ∈ (0, 1]: a contribution applied
    /// `s` rounds late is scaled by `γ^s` before the outer step. 1.0
    /// (the default) applies stale contributions at full weight; the
    /// scaling is skipped entirely when `γ^s == 1.0`, so the legacy
    /// path performs the identical arithmetic.
    pub discount: f64,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig { delay_rounds: 0, discount: 1.0 }
    }
}

impl SyncConfig {
    /// True for the synchronous default (the legacy round loop).
    pub fn is_synchronous(&self) -> bool {
        self.delay_rounds == 0
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.discount > 0.0 && self.discount <= 1.0,
            "sync.discount must be in (0, 1] (got {})",
            self.discount
        );
        Ok(())
    }
}

/// One elastic-membership event: a specific worker leaving or joining
/// the active roster at a specific round boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnEvent {
    /// `worker` is inactive from round `round` on (until a later join).
    Leave { worker: usize, round: usize },
    /// `worker` is active from round `round` on (until a later leave).
    /// A joiner warm-starts by adopting the current global (star,
    /// hierarchical) or consensus (ring, gossip) model at the start of
    /// its first active round.
    Join { worker: usize, round: usize },
}

impl ChurnEvent {
    pub fn worker(&self) -> usize {
        match *self {
            ChurnEvent::Leave { worker, .. } | ChurnEvent::Join { worker, .. } => worker,
        }
    }

    pub fn round(&self) -> usize {
        match *self {
            ChurnEvent::Leave { round, .. } | ChurnEvent::Join { round, .. } => round,
        }
    }
}

/// Elastic island membership (`[churn]` in TOML, `--churn` on the CLI) —
/// the paper's robustness claim ("resources becoming unavailable over
/// time, and vice versa") made concrete: a per-round roster of active
/// worker ids driven by a small schedule DSL.
///
/// DSL: comma-separated items, each one of
///
/// * `leave:wW@rR` — worker `W` leaves the roster at round `R`,
/// * `join:wW@rR`  — worker `W` joins (or rejoins) at round `R`,
/// * `ramp:A..B`   — the *base* roster (workers `0..k`) ramps linearly
///   from `A` to `B` workers across the run (at most one `ramp:` item).
///
/// Without a `ramp:` the base roster is all `diloco.workers` workers.
/// Events apply in round order; for one worker the latest event at or
/// before round `t` wins, so `leave:w3@r2,join:w3@r5` parks worker 3 for
/// rounds 2–4 and restores it from round 5 on. A departed worker bills
/// nothing on the fabric and holds no compute; its per-fragment sync
/// state and (decentralized) outer-momentum are parked and restored on
/// rejoin.
///
/// ```
/// use diloco::config::ChurnConfig;
///
/// let c = ChurnConfig::parse("leave:w3@r10,join:w8@r20,ramp:4..8").unwrap();
/// assert_eq!(c.events.len(), 2);
/// assert_eq!(c.ramp, Some((4, 8)));
/// // Round 0 of 40: base ramp says 4 workers, no events fired yet.
/// assert_eq!(c.active_ids(0, 40, 4), vec![0, 1, 2, 3]);
/// assert!(ChurnConfig::parse("leave:3@r10").is_err());
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct ChurnConfig {
    /// Membership events, sorted by round (stable, so listed order breaks
    /// same-round ties).
    pub events: Vec<ChurnEvent>,
    /// Base-roster linear ramp `(from, to)` across the run's rounds.
    pub ramp: Option<(usize, usize)>,
}

impl ChurnConfig {
    /// Parse the `--churn` DSL (see the type-level docs for the grammar).
    pub fn parse(s: &str) -> anyhow::Result<ChurnConfig> {
        let mut cfg = ChurnConfig::default();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (kind, spec) = part.split_once(':').ok_or_else(|| {
                anyhow::anyhow!("bad --churn item {part:?} (want leave:wW@rR|join:wW@rR|ramp:A..B)")
            })?;
            match kind.trim() {
                "leave" | "join" => {
                    let (w, r) = spec.split_once('@').ok_or_else(|| {
                        anyhow::anyhow!("bad churn event {part:?} (want {kind}:wW@rR)")
                    })?;
                    let worker: usize = w
                        .trim()
                        .strip_prefix('w')
                        .ok_or_else(|| anyhow::anyhow!("bad churn worker {w:?} (want wN)"))?
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad churn worker {w:?}: {e}"))?;
                    let round: usize = r
                        .trim()
                        .strip_prefix('r')
                        .ok_or_else(|| anyhow::anyhow!("bad churn round {r:?} (want rN)"))?
                        .parse()
                        .map_err(|e| anyhow::anyhow!("bad churn round {r:?}: {e}"))?;
                    cfg.events.push(if kind.trim() == "leave" {
                        ChurnEvent::Leave { worker, round }
                    } else {
                        ChurnEvent::Join { worker, round }
                    });
                }
                "ramp" => {
                    anyhow::ensure!(cfg.ramp.is_none(), "churn allows one ramp: item");
                    let (a, b) = spec.split_once("..").ok_or_else(|| {
                        anyhow::anyhow!("bad churn ramp {spec:?} (want ramp:A..B)")
                    })?;
                    let from: usize = a.trim().parse().map_err(|e| {
                        anyhow::anyhow!("bad churn ramp start {a:?}: {e}")
                    })?;
                    let to: usize = b.trim().parse().map_err(|e| {
                        anyhow::anyhow!("bad churn ramp end {b:?}: {e}")
                    })?;
                    anyhow::ensure!(from >= 1, "churn ramp must start >= 1 worker");
                    cfg.ramp = Some((from, to));
                }
                other => anyhow::bail!(
                    "unknown churn item {other:?} (want leave|join|ramp)"
                ),
            }
        }
        anyhow::ensure!(
            !cfg.events.is_empty() || cfg.ramp.is_some(),
            "empty churn schedule"
        );
        // Round order is authoritative (stable: listed order breaks ties).
        cfg.events.sort_by_key(|e| e.round());
        Ok(cfg)
    }

    /// Size of the base roster (workers `0..k`) at round `t` of `total`.
    fn base_workers(&self, t: usize, total: usize, workers: usize) -> usize {
        match self.ramp {
            None => workers,
            Some((from, to)) => {
                if total <= 1 {
                    return to.max(1);
                }
                let frac = t as f64 / (total - 1) as f64;
                let k = from as f64 + frac * (to as f64 - from as f64);
                k.round().max(1.0) as usize
            }
        }
    }

    /// Sorted ids of the workers active in round `t` (0-based) of a
    /// `total`-round run whose static worker count is `workers`.
    pub fn active_ids(&self, t: usize, total: usize, workers: usize) -> Vec<usize> {
        let base_k = self.base_workers(t, total, workers);
        let pool = self.pool_size(workers);
        (0..pool)
            .filter(|&id| {
                let mut active = id < base_k;
                for ev in &self.events {
                    if ev.worker() == id && ev.round() <= t {
                        active = matches!(ev, ChurnEvent::Join { .. });
                    }
                }
                active
            })
            .collect()
    }

    /// Worker-pool size the run must allocate: the largest id any base
    /// roster or *join* event can activate, plus one. Leave events never
    /// activate anyone, so they cannot grow the pool.
    pub fn pool_size(&self, workers: usize) -> usize {
        let mut pool = match self.ramp {
            None => workers,
            Some((from, to)) => from.max(to),
        };
        for ev in &self.events {
            if let ChurnEvent::Join { worker, .. } = ev {
                pool = pool.max(worker + 1);
            }
        }
        pool.max(1)
    }

    /// Cross-field invariants against the run shape.
    pub fn validate(&self, rounds: usize, workers: usize) -> anyhow::Result<()> {
        let pool = self.pool_size(workers);
        for ev in &self.events {
            anyhow::ensure!(
                ev.round() < rounds.max(1),
                "churn event at round {} but the run has {} rounds",
                ev.round(),
                rounds
            );
            anyhow::ensure!(
                ev.worker() < pool,
                "churn leave names worker {} but no base roster or join \
                 ever activates an id past {}",
                ev.worker(),
                pool - 1
            );
        }
        for t in 0..rounds {
            anyhow::ensure!(
                !self.active_ids(t, rounds, workers).is_empty(),
                "churn schedule leaves round {t} with no active workers"
            );
        }
        Ok(())
    }
}

/// Training-state checkpointing (`[ckpt]` in TOML; `--save-every` /
/// `--save-path` / `--resume` on the CLI). `save_every = 0` disables
/// periodic saves. The determinism contract is *bitwise*: training 2R
/// rounds straight equals training R rounds, saving, and resuming for R
/// more (see DESIGN.md §10 and the `resume_*` integration tests).
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub struct CkptConfig {
    /// Save the full [`crate::checkpoint::TrainState`] every N rounds
    /// (0 = never).
    pub save_every: usize,
    /// Where periodic saves land (required when `save_every > 0`).
    pub path: Option<String>,
    /// Resume a run from a TrainState checkpoint written by a previous
    /// run of the *same* configuration.
    pub resume: Option<String>,
}

impl CkptConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.save_every == 0 || self.path.is_some(),
            "ckpt.save_every = {} needs ckpt.path",
            self.save_every
        );
        Ok(())
    }
}

/// How many workers are active each round (paper Fig. 7 schedules).
#[derive(Clone, Debug, PartialEq)]
pub enum ComputeSchedule {
    /// k workers every round.
    Constant(usize),
    /// `first` workers for the first half of rounds, then `second`.
    Step { first: usize, second: usize },
    /// Linear ramp from `from` to `to` across rounds.
    Ramp { from: usize, to: usize },
    /// Explicit per-round worker counts.
    Explicit(Vec<usize>),
}

impl ComputeSchedule {
    /// Active worker count for round `t` of `total` (0-based).
    pub fn workers_at(&self, t: usize, total: usize) -> usize {
        match self {
            ComputeSchedule::Constant(k) => *k,
            ComputeSchedule::Step { first, second } => {
                if t < total / 2 {
                    *first
                } else {
                    *second
                }
            }
            ComputeSchedule::Ramp { from, to } => {
                if total <= 1 {
                    return *to;
                }
                let frac = t as f64 / (total - 1) as f64;
                let k = *from as f64 + frac * (*to as f64 - *from as f64);
                k.round().max(1.0) as usize
            }
            ComputeSchedule::Explicit(v) => v[t.min(v.len() - 1)],
        }
    }

    /// Maximum concurrent workers (sizing for state allocation).
    pub fn max_workers(&self, total: usize) -> usize {
        (0..total.max(1))
            .map(|t| self.workers_at(t, total))
            .max()
            .unwrap_or(1)
    }

    /// Total worker-rounds (∝ compute) across the run.
    pub fn total_worker_rounds(&self, total: usize) -> usize {
        (0..total).map(|t| self.workers_at(t, total)).sum()
    }
}

/// Synthetic-corpus + sharding parameters (DESIGN.md §2 substitution).
#[derive(Clone, Debug)]
pub struct DataConfig {
    /// Latent topics — these play the role of the paper's k-means clusters.
    pub n_topics: usize,
    pub n_docs: usize,
    pub doc_len: usize,
    /// i.i.d. = random split; non-i.i.d. = shard by topic.
    pub non_iid: bool,
    /// Non-i.i.d. softening: probability a document is re-assigned to a
    /// random shard (0.0 = fully clustered, 1.0 = i.i.d.).
    pub mix: f64,
    /// Held-out fraction for the validation split.
    pub holdout: f64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            n_topics: 8,
            n_docs: 400,
            doc_len: 220,
            non_iid: true,
            mix: 0.0,
            holdout: 0.1,
        }
    }
}

/// Simulated inter-island network (DESIGN.md §2).
#[derive(Clone, Debug)]
pub struct CommConfig {
    /// Bytes/second on each island's WAN link (paper: poorly connected).
    pub bandwidth_bps: f64,
    /// One-way latency per message, seconds.
    pub latency_s: f64,
    /// Probability a worker's outer gradient is dropped in a round (Fig 8).
    pub drop_prob: f64,
}

impl Default for CommConfig {
    fn default() -> Self {
        CommConfig {
            bandwidth_bps: 1e9 / 8.0, // 1 Gb/s WAN
            latency_s: 0.05,
            drop_prob: 0.0,
        }
    }
}

/// Which transport backend carries the run (DESIGN.md §14).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FabricKind {
    /// In-process simulator — the bitwise golden path (default).
    #[default]
    Sim,
    /// One OS process per island over loopback/LAN TCP; billing still
    /// comes from the embedded simulator, so bills and drop keys match
    /// the sim backend bitwise.
    Tcp,
}

impl FabricKind {
    pub fn parse(s: &str) -> anyhow::Result<FabricKind> {
        match s {
            "sim" => Ok(FabricKind::Sim),
            "tcp" => Ok(FabricKind::Tcp),
            other => anyhow::bail!("unknown fabric.kind {other:?} (sim | tcp)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FabricKind::Sim => "sim",
            FabricKind::Tcp => "tcp",
        }
    }
}

/// Transport backend selection + TCP process/rendezvous knobs
/// (`[fabric]`; DESIGN.md §14). All knobs besides `kind` only matter
/// for `kind = "tcp"`.
#[derive(Clone, Debug)]
pub struct FabricConfig {
    pub kind: FabricKind,
    /// Interface the coordinator listens on.
    pub host: String,
    /// Listen port; 0 picks an ephemeral port (the spawned workers are
    /// told the resolved one).
    pub port: u16,
    /// Spawn (and respawn) one worker process per slot. Turn off to
    /// rendezvous with externally launched `diloco worker` processes.
    pub spawn: bool,
    /// Worker binary to spawn; `main.rs` defaults this to the current
    /// executable.
    pub worker_bin: Option<String>,
    /// Extra per-slot argv for spawned workers — the fault-injection
    /// hook the `fabric_faults` suite uses (`--die-mid-phase`, …).
    pub spawn_extra: Vec<Vec<String>>,
    /// Rendezvous / reconnect budget, seconds.
    pub connect_timeout_s: f64,
    /// Bound on one inner-phase round-trip, seconds: a hung worker
    /// becomes a booked drop after this long, never a hang.
    pub phase_timeout_s: f64,
    /// Bound on one heartbeat round-trip, seconds.
    pub heartbeat_timeout_s: f64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            kind: FabricKind::Sim,
            host: "127.0.0.1".to_string(),
            port: 0,
            spawn: true,
            worker_bin: None,
            spawn_extra: Vec::new(),
            connect_timeout_s: 30.0,
            phase_timeout_s: 600.0,
            heartbeat_timeout_s: 5.0,
        }
    }
}

impl FabricConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, t) in [
            ("connect_timeout_s", self.connect_timeout_s),
            ("phase_timeout_s", self.phase_timeout_s),
            ("heartbeat_timeout_s", self.heartbeat_timeout_s),
        ] {
            anyhow::ensure!(
                t > 0.0 && t.is_finite(),
                "fabric.{name} must be positive and finite (got {t})"
            );
        }
        anyhow::ensure!(
            !self.host.is_empty(),
            "fabric.host must not be empty (use 127.0.0.1 for loopback)"
        );
        Ok(())
    }
}

/// Child-stream tag of the attacker-model draws (`[adversary]`): the
/// attacker set and every per-round noise draw hang off
/// `Rng::new(seed).child(ADVERSARY_STREAM)`, so they are independent of
/// the fabric (`child(7)`), worker (`child(100 + i)`), speed-jitter
/// ([`SPEED_JITTER_STREAM`]), and data streams — a pure function of
/// `(seed, round, worker)` like every other scenario axis.
pub const ADVERSARY_STREAM: u64 = 0x00BA_DAC7;

/// What a compromised worker does to its outer delta (after the inner
/// phase, before the wire — billing and routing see the normal payload
/// shape).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttackKind {
    /// Ship `-scale × delta`: the classic sign-flip / model-poisoning
    /// attack. `scale > 1` amplifies it.
    FlipSign,
    /// Replace the delta with i.i.d. `scale × N(0, 1)` draws keyed by
    /// `(seed, worker, round)`.
    ScaledNoise,
    /// Ship all-NaN — fatal to the plain mean in one round.
    NanBomb,
    /// Ship the delta from the attacker's *previous* synced round
    /// (first round ships honestly while parking a copy).
    StaleReplay,
}

impl AttackKind {
    /// Parse `flip` / `noise` / `nan` / `stale`.
    pub fn parse(s: &str) -> anyhow::Result<AttackKind> {
        match s {
            "flip" => Ok(AttackKind::FlipSign),
            "noise" => Ok(AttackKind::ScaledNoise),
            "nan" => Ok(AttackKind::NanBomb),
            "stale" => Ok(AttackKind::StaleReplay),
            other => anyhow::bail!(
                "unknown adversary.attack {other:?} (want flip|noise|nan|stale)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttackKind::FlipSign => "flip",
            AttackKind::ScaledNoise => "noise",
            AttackKind::NanBomb => "nan",
            AttackKind::StaleReplay => "stale",
        }
    }
}

/// The `[adversary]` section / `--adversary` DSL: a deterministic
/// Byzantine attacker model. ⌊`fraction`·pool⌋ workers (chosen once per
/// run from the seed) corrupt their outer delta every round they sync.
///
/// ```
/// use diloco::config::{AdversaryConfig, AttackKind};
///
/// let a = AdversaryConfig::parse("flip:0.25").unwrap();
/// assert_eq!(a.attack, AttackKind::FlipSign);
/// assert_eq!(a.n_attackers(8), 2);
/// let n = AdversaryConfig::parse("noise:0.125:3.0").unwrap();
/// assert_eq!(n.scale, 3.0);
/// assert!(AdversaryConfig::parse("flip:1.0").is_err()); // everyone evil
/// assert!(AdversaryConfig::parse("melt:0.25").is_err());
/// // The attacker set is a pure function of (seed, pool).
/// assert_eq!(a.attacker_ids(42, 8), a.attacker_ids(42, 8));
/// ```
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdversaryConfig {
    pub attack: AttackKind,
    /// Fraction of the worker pool that is compromised, in (0, 1).
    pub fraction: f64,
    /// Attack amplitude (flip multiplier / noise stddev; ignored by
    /// `nan` and `stale`).
    pub scale: f64,
}

impl AdversaryConfig {
    /// Parse `kind:fraction[:scale]`, e.g. `flip:0.25` or `noise:0.25:3`.
    pub fn parse(s: &str) -> anyhow::Result<AdversaryConfig> {
        let mut it = s.split(':');
        let attack = AttackKind::parse(it.next().unwrap_or(""))?;
        let frac = it.next().ok_or_else(|| {
            anyhow::anyhow!("bad --adversary {s:?} (want kind:fraction[:scale])")
        })?;
        let fraction: f64 = frac
            .trim()
            .parse()
            .map_err(|e| anyhow::anyhow!("bad adversary fraction {frac:?}: {e}"))?;
        let scale: f64 = match it.next() {
            Some(x) => x
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad adversary scale {x:?}: {e}"))?,
            None => 1.0,
        };
        anyhow::ensure!(
            it.next().is_none(),
            "bad --adversary {s:?} (want kind:fraction[:scale])"
        );
        let cfg = AdversaryConfig { attack, fraction, scale };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Field invariants (pool-dependent checks live in
    /// `ExperimentConfig::validate`).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.fraction > 0.0 && self.fraction < 1.0,
            "adversary.fraction must be in (0, 1) — a fraction of {} would \
             compromise the whole roster (got no honest majority to protect)",
            self.fraction
        );
        anyhow::ensure!(
            self.scale > 0.0 && self.scale.is_finite(),
            "adversary.scale must be positive and finite (got {})",
            self.scale
        );
        Ok(())
    }

    /// ⌊fraction · pool⌋ — how many workers are compromised.
    pub fn n_attackers(&self, pool: usize) -> usize {
        (self.fraction * pool as f64).floor() as usize
    }

    /// The run's compromised ids: `n_attackers` distinct workers drawn
    /// from `Rng::new(seed).child(ADVERSARY_STREAM)`, sorted. Static for
    /// the whole run and independent of every other stream.
    pub fn attacker_ids(&self, seed: u64, pool: usize) -> Vec<usize> {
        let n = self.n_attackers(pool).min(pool);
        let mut ids = Rng::new(seed).child(ADVERSARY_STREAM).choose(pool, n);
        ids.sort_unstable();
        ids
    }

    /// `kind:fraction[:scale]` round-trip label for logs and bench rows.
    pub fn label(&self) -> String {
        format!("{}:{}:{}", self.attack.name(), self.fraction, self.scale)
    }
}

/// The `[aggregate]` section / `--aggregate` DSL: which
/// [`crate::coordinator::aggregate::Aggregator`] reduces each fragment.
///
/// ```
/// use diloco::config::AggregateConfig;
///
/// assert_eq!(AggregateConfig::parse("mean").unwrap(), AggregateConfig::default());
/// assert_eq!(
///     AggregateConfig::parse("trimmed:1").unwrap(),
///     AggregateConfig::TrimmedMean { trim: 1 }
/// );
/// assert_eq!(
///     AggregateConfig::parse("krum:2").unwrap(),
///     AggregateConfig::Krum { f: 2 }
/// );
/// assert!(AggregateConfig::parse("trimmed").is_err()); // trim required
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum AggregateConfig {
    /// The legacy weighted mean — bitwise with every pre-existing trace.
    #[default]
    WeightedMean,
    /// Coordinate-wise trimmed weighted mean (`trimmed:N` drops N values
    /// from each end of every coordinate).
    TrimmedMean { trim: usize },
    /// Coordinate-wise median.
    CoordinateMedian,
    /// Krum selection tolerating `f` Byzantine workers (`krum:F`).
    Krum { f: usize },
}

impl AggregateConfig {
    /// Parse `mean` / `trimmed:N` / `median` / `krum:F`.
    pub fn parse(s: &str) -> anyhow::Result<AggregateConfig> {
        match s {
            "mean" => Ok(AggregateConfig::WeightedMean),
            "median" => Ok(AggregateConfig::CoordinateMedian),
            other => {
                if let Some(n) = other.strip_prefix("trimmed:") {
                    let trim = n.trim().parse().map_err(|e| {
                        anyhow::anyhow!("bad aggregate trim {n:?}: {e}")
                    })?;
                    Ok(AggregateConfig::TrimmedMean { trim })
                } else if let Some(n) = other.strip_prefix("krum:") {
                    let f = n.trim().parse().map_err(|e| {
                        anyhow::anyhow!("bad aggregate krum f {n:?}: {e}")
                    })?;
                    Ok(AggregateConfig::Krum { f })
                } else {
                    anyhow::bail!(
                        "unknown aggregate.kind {other:?} \
                         (want mean|trimmed:N|median|krum:F)"
                    )
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggregateConfig::WeightedMean => "mean",
            AggregateConfig::TrimmedMean { .. } => "trimmed",
            AggregateConfig::CoordinateMedian => "median",
            AggregateConfig::Krum { .. } => "krum",
        }
    }

    /// Round-trip DSL label (`trimmed:1`, `krum:2`, ...).
    pub fn label(&self) -> String {
        match self {
            AggregateConfig::TrimmedMean { trim } => format!("trimmed:{trim}"),
            AggregateConfig::Krum { f } => format!("krum:{f}"),
            other => other.name().to_string(),
        }
    }

    /// True for the bitwise-default mean path.
    pub fn is_default(&self) -> bool {
        matches!(self, AggregateConfig::WeightedMean)
    }
}

/// Uniform section-tagged validation error: every rejection out of
/// [`ExperimentConfig::validate`] renders as `[section] message`, so a
/// failing TOML/CLI combination names the section to fix.
#[derive(Debug)]
pub struct ConfigError {
    pub section: &'static str,
    pub message: String,
}

impl ConfigError {
    fn tag(section: &'static str, e: anyhow::Error) -> anyhow::Error {
        anyhow::Error::new(ConfigError { section, message: format!("{e:#}") })
    }
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.section, self.message)
    }
}

impl std::error::Error for ConfigError {}

/// The full description of one run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,
    /// Artifact directory (output of `make artifacts`).
    pub artifacts_dir: String,
    /// Model preset name — selects `<model>.manifest.json`.
    pub model: String,
    /// Replicas k (paper default 8).
    pub workers: usize,
    /// Inner steps per round (paper default 500).
    pub inner_steps: usize,
    /// Outer rounds T (paper: 128 at H=500).
    pub rounds: usize,
    /// Plain (non-DiLoCo) warm-start steps before round 0 (paper: 24k).
    pub pretrain_steps: usize,
    pub outer_opt: OuterOptConfig,
    pub schedule: ComputeSchedule,
    /// Weight outer gradients by shard example counts (paper §6.1,
    /// applied in the non-i.i.d. regime).
    pub weighted_average: bool,
    /// Sign-based outer-gradient pruning fraction (paper Table 6).
    pub prune_frac: f64,
    /// Synchronize inner AdamW state across workers at each round
    /// (paper appendix: costs 3× communication, no quality win — off).
    pub sync_inner_opt: bool,
    pub data: DataConfig,
    pub comm: CommConfig,
    /// Transport backend: in-process simulator (default, bitwise golden)
    /// or real TCP worker processes.
    pub fabric: FabricConfig,
    /// Streaming partial-sync fabric: fragments × schedule × codec.
    pub stream: StreamConfig,
    /// Per-worker compute-speed heterogeneity model.
    pub speed: SpeedConfig,
    /// Asynchronous outer loop: delayed application + staleness discount.
    pub sync: SyncConfig,
    /// Synchronization topology: star | ring | gossip | hierarchical.
    pub topology: TopologyConfig,
    /// Elastic island membership: per-round active-worker roster driven
    /// by leave/join/ramp events (None = the static `schedule` roster).
    pub churn: Option<ChurnConfig>,
    /// Byzantine attacker model (None = all workers honest, the legacy
    /// path).
    pub adversary: Option<AdversaryConfig>,
    /// Outer aggregation strategy (default: the bitwise weighted mean).
    pub aggregate: AggregateConfig,
    /// Training-state checkpointing (periodic saves + resume).
    pub ckpt: CkptConfig,
    /// Inner-phase executor (sequential reference vs parallel islands).
    pub engine: EngineConfig,
    /// Opt-in float-op-reordering fast paths (`[engine] fast_math`):
    /// the per-fragment reduction switches to a pairwise payload tree
    /// (tolerance-tested, NOT bitwise with the golden trace). `false`
    /// (default) keeps every path on the bitwise reference arithmetic.
    pub fast_math: bool,
    /// Evaluate every this many rounds (0 = only at end).
    pub eval_every_rounds: usize,
    /// Validation batches per evaluation.
    pub eval_batches: usize,
}

impl ExperimentConfig {
    /// The paper's default DiLoCo setting, scaled per DESIGN.md §6.
    pub fn paper_default(artifacts_dir: &str, model: &str) -> Self {
        ExperimentConfig {
            seed: 0,
            artifacts_dir: artifacts_dir.to_string(),
            model: model.to_string(),
            workers: 8,
            inner_steps: 25,
            rounds: 12,
            pretrain_steps: 100,
            outer_opt: OuterOptConfig::paper_default(),
            schedule: ComputeSchedule::Constant(8),
            weighted_average: true,
            prune_frac: 0.0,
            sync_inner_opt: false,
            data: DataConfig::default(),
            comm: CommConfig::default(),
            fabric: FabricConfig::default(),
            stream: StreamConfig::default(),
            speed: SpeedConfig::default(),
            sync: SyncConfig::default(),
            topology: TopologyConfig::Star,
            churn: None,
            adversary: None,
            aggregate: AggregateConfig::default(),
            ckpt: CkptConfig::default(),
            engine: EngineConfig::Auto,
            fast_math: false,
            eval_every_rounds: 1,
            eval_batches: 4,
        }
    }

    /// Derived: total inner steps per worker, N = T × H.
    pub fn total_inner_steps(&self) -> usize {
        self.rounds * self.inner_steps
    }

    pub fn rng(&self) -> Rng {
        Rng::new(self.seed)
    }

    /// Worker-pool size the run allocates: the schedule's peak, the
    /// static worker count, and every churn-activated id.
    pub fn pool_size(&self) -> usize {
        let mut k = self.schedule.max_workers(self.rounds).max(self.workers);
        if let Some(churn) = &self.churn {
            k = k.max(churn.pool_size(self.workers));
        }
        k.max(1)
    }

    /// Sorted ids of the workers active in round `t` — the churn roster
    /// when churn is configured, else the schedule's prefix `0..k_t`
    /// (the pre-churn behavior, bitwise).
    pub fn active_ids(&self, t: usize) -> Vec<usize> {
        match &self.churn {
            Some(churn) => churn.active_ids(t, self.rounds, self.workers),
            None => {
                let k_t = self
                    .schedule
                    .workers_at(t, self.rounds)
                    .min(self.pool_size())
                    .max(1);
                (0..k_t).collect()
            }
        }
    }

    /// Compute-time factors for a round's roster, in roster order — the
    /// per-island multipliers the engine's critical-path reduction
    /// consumes. All exactly 1.0 under the uniform model (the legacy
    /// timing path, bitwise).
    pub fn speed_factors(&self, roster: &[usize], t: usize) -> Vec<f64> {
        if self.speed.is_uniform() {
            return vec![1.0; roster.len()];
        }
        roster
            .iter()
            .map(|&id| self.speed.factor(id, t, self.rounds, self.seed))
            .collect()
    }

    /// Cross-field invariants. Every config entry point (TOML, CLI
    /// overrides) funnels through this, so malformed settings surface as
    /// proper `anyhow` errors instead of panics deep in the run.
    ///
    /// One dispatcher, one error shape: each section validator runs in
    /// order and any rejection is wrapped in [`ConfigError`], rendering
    /// as `[section] message` — no more per-call-site ad-hoc wrapping.
    pub fn validate(&self) -> anyhow::Result<()> {
        let sections: [(&'static str, fn(&Self) -> anyhow::Result<()>); 13] = [
            ("diloco", Self::validate_run),
            ("comm", Self::validate_comm),
            ("fabric", |c: &Self| c.fabric.validate()),
            ("stream", |c: &Self| c.stream.validate()),
            ("speed", |c: &Self| c.speed.validate()),
            ("sync", |c: &Self| c.sync.validate()),
            ("topology", |c: &Self| c.topology.validate()),
            ("churn", Self::validate_churn),
            ("adversary", Self::validate_adversary),
            ("aggregate", Self::validate_aggregate),
            ("ckpt", |c: &Self| c.ckpt.validate()),
            ("data", Self::validate_data),
            ("compose", Self::validate_composition),
        ];
        for (section, check) in sections {
            check(self).map_err(|e| ConfigError::tag(section, e))?;
        }
        Ok(())
    }

    fn validate_run(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 1, "diloco.workers must be >= 1");
        anyhow::ensure!(self.inner_steps >= 1, "diloco.inner_steps must be >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.prune_frac),
            "diloco.prune_frac must be in [0, 1] (got {})",
            self.prune_frac
        );
        Ok(())
    }

    fn validate_comm(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.comm.drop_prob),
            "comm.drop_prob must be in [0, 1] (got {})",
            self.comm.drop_prob
        );
        anyhow::ensure!(
            self.comm.bandwidth_bps > 0.0,
            "comm.bandwidth_bps must be positive"
        );
        Ok(())
    }

    fn validate_churn(&self) -> anyhow::Result<()> {
        if let Some(churn) = &self.churn {
            anyhow::ensure!(
                matches!(self.schedule, ComputeSchedule::Constant(_)),
                "churn composes with the constant compute schedule only \
                 (use the churn DSL's ramp:A..B instead of schedule ramps)"
            );
            churn.validate(self.rounds, self.workers)?;
        }
        Ok(())
    }

    fn validate_adversary(&self) -> anyhow::Result<()> {
        let Some(adv) = &self.adversary else { return Ok(()) };
        adv.validate()?;
        let pool = self.pool_size();
        let n = adv.n_attackers(pool);
        anyhow::ensure!(
            n >= 1,
            "adversary.fraction = {} names zero attackers of the {}-worker \
             pool (drop the [adversary] section for an honest run)",
            adv.fraction,
            pool
        );
        anyhow::ensure!(
            n < pool,
            "adversary.fraction = {} compromises all {} workers — no honest \
             contribution would ever reach the outer step",
            adv.fraction,
            pool
        );
        Ok(())
    }

    fn validate_aggregate(&self) -> anyhow::Result<()> {
        let k = self.pool_size();
        match self.aggregate {
            AggregateConfig::WeightedMean | AggregateConfig::CoordinateMedian => {}
            AggregateConfig::TrimmedMean { trim } => {
                anyhow::ensure!(
                    2 * trim < k,
                    "aggregate trimmed:{trim} discards 2×{trim} values per \
                     coordinate but the pool has only {k} workers — nothing \
                     would survive the trim"
                );
            }
            AggregateConfig::Krum { f } => {
                anyhow::ensure!(
                    k >= 2 * f + 3,
                    "aggregate krum:{f} needs at least 2f+3 = {} workers for \
                     its Byzantine guarantee; the pool has {k}",
                    2 * f + 3
                );
            }
        }
        Ok(())
    }

    fn validate_data(&self) -> anyhow::Result<()> {
        // Data invariants — previously hard `assert!` panics deep inside
        // `data::shard::shard_corpus`; surfaced here so every config
        // entry point reports them as proper errors before a run starts.
        anyhow::ensure!(
            (0.0..1.0).contains(&self.data.holdout),
            "data.holdout must be in [0, 1) (got {})",
            self.data.holdout
        );
        let max_k = self.pool_size();
        // Count the training documents through the same function
        // Dataset::build splits with (data::shard::holdout_split) — this
        // used to be a hand-maintained mirror of that arithmetic, which
        // could drift.
        let train_docs =
            crate::data::shard::train_doc_count(self.data.n_docs, self.data.holdout);
        anyhow::ensure!(
            train_docs >= max_k,
            "data.docs = {} leaves {} training documents after the {:.0}% holdout \
             — fewer than the {} worker shards the schedule needs",
            self.data.n_docs,
            train_docs,
            100.0 * self.data.holdout,
            max_k
        );
        Ok(())
    }

    /// Pairwise composition rules between sections.
    fn validate_composition(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !(self.sync.delay_rounds > 0 && self.topology.is_decentralized()),
            "delayed outer application (sync.delay_rounds > 0) composes with the \
             centralized topologies (star, hierarchical); the decentralized \
             mixing-matrix loops ({}) have no central queue to delay",
            self.topology.name()
        );
        anyhow::ensure!(
            self.sync.delay_rounds <= self.rounds,
            "sync.delay_rounds = {} exceeds the run's {} rounds (every \
             contribution would only land in the end-of-run flush)",
            self.sync.delay_rounds,
            self.rounds
        );
        anyhow::ensure!(
            self.speed.max_profiled_worker() <= self.pool_size(),
            "speed profile names worker {} but the pool has {} workers",
            self.speed.max_profiled_worker() - 1,
            self.pool_size()
        );
        // Sign-pruning now composes with every codec and every topology:
        // the sparse wire format (comm::wire) bills pruned payloads as
        // bitmap + codec-encoded non-zeros, quantizers fit their grid
        // over the non-zeros only, the ring bills each chunk by the
        // density of the partial sum it carries, and the hierarchical
        // leader hop bills the union of its group's supports. The three
        // dense-only rejections that used to live here are gone.
        anyhow::ensure!(
            !(self.topology == TopologyConfig::Ring && self.comm.drop_prob > 0.0),
            "the ring all-reduce is a reliable collective (a dropped chunk would \
             corrupt every replica); drop injection (comm.drop_prob > 0) composes \
             with star|gossip|hierarchical"
        );
        anyhow::ensure!(
            !(self.fast_math && !self.aggregate.is_default()),
            "engine.fast_math's pairwise reduction tree exists only for the \
             weighted-mean path; the robust aggregators ({}) already fix \
             their own scalar-op order",
            self.aggregate.label()
        );
        Ok(())
    }

    /// Load from the TOML subset; missing keys fall back to
    /// `paper_default` values.
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<Self> {
        let artifacts = doc.str_or("artifacts_dir", "artifacts")?;
        let model = doc.str_or("model", "nano")?;
        let mut cfg = ExperimentConfig::paper_default(&artifacts, &model);
        cfg.seed = doc.usize_or("seed", 0)? as u64;
        cfg.workers = doc.usize_or("diloco.workers", cfg.workers)?;
        cfg.inner_steps = doc.usize_or("diloco.inner_steps", cfg.inner_steps)?;
        cfg.rounds = doc.usize_or("diloco.rounds", cfg.rounds)?;
        cfg.pretrain_steps = doc.usize_or("diloco.pretrain_steps", cfg.pretrain_steps)?;
        cfg.weighted_average =
            doc.bool_or("diloco.weighted_average", cfg.weighted_average)?;
        cfg.prune_frac = doc.f64_or("diloco.prune_frac", cfg.prune_frac)?;
        cfg.sync_inner_opt = doc.bool_or("diloco.sync_inner_opt", false)?;

        let kind = doc.str_or("outer_opt.kind", "nesterov")?;
        let lr = doc.f64_or("outer_opt.lr", 0.7)? as f32;
        let mu = doc.f64_or("outer_opt.momentum", 0.9)? as f32;
        cfg.outer_opt = match kind.as_str() {
            "sgd" => OuterOptConfig::Sgd { lr },
            "sgdm" => OuterOptConfig::SgdM { lr, mu },
            "nesterov" => OuterOptConfig::Nesterov { lr, mu },
            "adam" => OuterOptConfig::Adam {
                lr,
                b1: doc.f64_or("outer_opt.b1", 0.9)? as f32,
                b2: doc.f64_or("outer_opt.b2", 0.95)? as f32,
                eps: doc.f64_or("outer_opt.eps", 0.1)? as f32,
            },
            other => anyhow::bail!("unknown outer_opt.kind {other:?}"),
        };

        let sched = doc.str_or("diloco.schedule", "constant")?;
        cfg.schedule = parse_schedule(&sched, cfg.workers)?;

        cfg.data.n_topics = doc.usize_or("data.topics", cfg.data.n_topics)?;
        cfg.data.n_docs = doc.usize_or("data.docs", cfg.data.n_docs)?;
        cfg.data.doc_len = doc.usize_or("data.doc_len", cfg.data.doc_len)?;
        cfg.data.non_iid = doc.bool_or("data.non_iid", cfg.data.non_iid)?;
        cfg.data.mix = doc.f64_or("data.mix", cfg.data.mix)?;
        cfg.data.holdout = doc.f64_or("data.holdout", cfg.data.holdout)?;

        cfg.comm.bandwidth_bps =
            doc.f64_or("comm.bandwidth_bps", cfg.comm.bandwidth_bps)?;
        cfg.comm.latency_s = doc.f64_or("comm.latency_s", cfg.comm.latency_s)?;
        cfg.comm.drop_prob = doc.f64_or("comm.drop_prob", cfg.comm.drop_prob)?;

        let fabric_kind = doc.str_or("fabric.kind", cfg.fabric.kind.name())?;
        cfg.fabric.kind = FabricKind::parse(&fabric_kind)?;
        cfg.fabric.host = doc.str_or("fabric.host", &cfg.fabric.host)?;
        let fabric_port = doc.usize_or("fabric.port", cfg.fabric.port as usize)?;
        anyhow::ensure!(
            fabric_port <= u16::MAX as usize,
            "fabric.port = {fabric_port} does not fit a TCP port"
        );
        cfg.fabric.port = fabric_port as u16;
        cfg.fabric.spawn = doc.bool_or("fabric.spawn", cfg.fabric.spawn)?;
        let worker_bin = doc.str_or("fabric.worker_bin", "")?;
        if !worker_bin.is_empty() {
            cfg.fabric.worker_bin = Some(worker_bin);
        }
        cfg.fabric.connect_timeout_s =
            doc.f64_or("fabric.connect_timeout_s", cfg.fabric.connect_timeout_s)?;
        cfg.fabric.phase_timeout_s =
            doc.f64_or("fabric.phase_timeout_s", cfg.fabric.phase_timeout_s)?;
        cfg.fabric.heartbeat_timeout_s = doc
            .f64_or("fabric.heartbeat_timeout_s", cfg.fabric.heartbeat_timeout_s)?;

        let engine = doc.str_or("engine.kind", "auto")?;
        cfg.engine = EngineConfig::parse(&engine)?;
        let threads = doc.usize_or("engine.threads", 0)?;
        if threads > 0 {
            cfg.engine = match cfg.engine {
                EngineConfig::Sequential => anyhow::bail!(
                    "engine.threads conflicts with engine.kind = \"sequential\""
                ),
                EngineConfig::Parallel { threads: t } if t != 0 && t != threads => {
                    anyhow::bail!(
                        "engine.threads = {threads} conflicts with engine.kind = {engine:?}"
                    )
                }
                _ => EngineConfig::Parallel { threads },
            };
        }
        cfg.fast_math = doc.bool_or("engine.fast_math", cfg.fast_math)?;

        let topo_kind = doc.str_or("topology.kind", "")?;
        let topo_groups = doc.usize_or("topology.groups", 0)?;
        anyhow::ensure!(
            topo_groups > 0 || doc.get("topology.groups").is_none(),
            "topology.groups must be >= 1 (got 0)"
        );
        cfg.topology = match (topo_kind.as_str(), topo_groups) {
            ("", 0) => TopologyConfig::Star,
            // A bare group count implies the hierarchical topology, like
            // a bare engine.threads implies the parallel engine.
            ("", g) => TopologyConfig::Hierarchical { groups: g },
            (kind, 0) => TopologyConfig::parse(kind)?,
            (kind, g) => match TopologyConfig::parse(kind)? {
                TopologyConfig::Hierarchical { groups } => {
                    anyhow::ensure!(
                        !kind.contains(':') || groups == g,
                        "topology.groups = {g} conflicts with topology.kind = {kind:?}"
                    );
                    TopologyConfig::Hierarchical { groups: g }
                }
                other => anyhow::bail!(
                    "topology.groups = {g} conflicts with topology.kind = {:?}",
                    other.name()
                ),
            },
        };

        cfg.stream.fragments = doc.usize_or("stream.fragments", cfg.stream.fragments)?;
        let schedule = doc.str_or("stream.schedule", cfg.stream.schedule.name())?;
        cfg.stream.schedule = SyncSchedule::parse(&schedule)?;
        let codec = doc.str_or("stream.codec", cfg.stream.codec.name())?;
        cfg.stream.codec = Codec::parse(&codec)?;
        cfg.stream.error_feedback =
            doc.bool_or("stream.error_feedback", cfg.stream.error_feedback)?;

        let speed = doc.str_or("speed.profile", "")?;
        if !speed.is_empty() {
            cfg.speed = SpeedConfig::parse(&speed)?;
        }
        cfg.sync.delay_rounds =
            doc.usize_or("sync.delay_rounds", cfg.sync.delay_rounds)?;
        cfg.sync.discount = doc.f64_or("sync.discount", cfg.sync.discount)?;

        let churn = doc.str_or("churn.schedule", "")?;
        if !churn.is_empty() {
            cfg.churn = Some(ChurnConfig::parse(&churn)?);
        }

        let attack = doc.str_or("adversary.attack", "")?;
        if !attack.is_empty() {
            let adv = AdversaryConfig {
                attack: AttackKind::parse(&attack)?,
                fraction: doc.f64_or("adversary.fraction", 0.25)?,
                scale: doc.f64_or("adversary.scale", 1.0)?,
            };
            adv.validate()?;
            cfg.adversary = Some(adv);
        }

        let aggregate = doc.str_or("aggregate.kind", "")?;
        if !aggregate.is_empty() {
            cfg.aggregate = AggregateConfig::parse(&aggregate)?;
        }

        cfg.ckpt.save_every = doc.usize_or("ckpt.save_every", 0)?;
        let ckpt_path = doc.str_or("ckpt.path", "")?;
        if !ckpt_path.is_empty() {
            cfg.ckpt.path = Some(ckpt_path);
        }
        let resume = doc.str_or("ckpt.resume", "")?;
        if !resume.is_empty() {
            cfg.ckpt.resume = Some(resume);
        }

        cfg.eval_every_rounds =
            doc.usize_or("eval.every_rounds", cfg.eval_every_rounds)?;
        cfg.eval_batches = doc.usize_or("eval.batches", cfg.eval_batches)?;
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Schedule mini-language: `constant`, `step:4,8`, `ramp:1,8`, or
/// `explicit:1,2,4,8,...`.
pub fn parse_schedule(s: &str, default_k: usize) -> anyhow::Result<ComputeSchedule> {
    if s == "constant" {
        return Ok(ComputeSchedule::Constant(default_k));
    }
    let (kind, args) = s
        .split_once(':')
        .ok_or_else(|| anyhow::anyhow!("bad schedule {s:?}"))?;
    let nums: Vec<usize> = args
        .split(',')
        .map(|x| x.trim().parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("bad schedule numbers in {s:?}: {e}"))?;
    match (kind, nums.as_slice()) {
        ("constant", [k]) => Ok(ComputeSchedule::Constant(*k)),
        ("step", [a, b]) => Ok(ComputeSchedule::Step { first: *a, second: *b }),
        ("ramp", [a, b]) => Ok(ComputeSchedule::Ramp { from: *a, to: *b }),
        ("explicit", xs) if !xs.is_empty() => {
            Ok(ComputeSchedule::Explicit(xs.to_vec()))
        }
        _ => anyhow::bail!("bad schedule {s:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_constant() {
        let s = ComputeSchedule::Constant(8);
        assert_eq!(s.workers_at(0, 10), 8);
        assert_eq!(s.workers_at(9, 10), 8);
        assert_eq!(s.total_worker_rounds(10), 80);
    }

    #[test]
    fn schedule_step_halves() {
        let s = ComputeSchedule::Step { first: 4, second: 8 };
        assert_eq!(s.workers_at(0, 10), 4);
        assert_eq!(s.workers_at(4, 10), 4);
        assert_eq!(s.workers_at(5, 10), 8);
        assert_eq!(s.total_worker_rounds(10), 4 * 5 + 8 * 5);
    }

    #[test]
    fn schedule_ramp_endpoints() {
        let s = ComputeSchedule::Ramp { from: 1, to: 8 };
        assert_eq!(s.workers_at(0, 8), 1);
        assert_eq!(s.workers_at(7, 8), 8);
        assert_eq!(s.max_workers(8), 8);
        // Monotone non-decreasing.
        let counts: Vec<_> = (0..8).map(|t| s.workers_at(t, 8)).collect();
        assert!(counts.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn schedule_parse_language() {
        assert_eq!(
            parse_schedule("constant", 8).unwrap(),
            ComputeSchedule::Constant(8)
        );
        assert_eq!(
            parse_schedule("step:8,4", 8).unwrap(),
            ComputeSchedule::Step { first: 8, second: 4 }
        );
        assert_eq!(
            parse_schedule("ramp:1,8", 8).unwrap(),
            ComputeSchedule::Ramp { from: 1, to: 8 }
        );
        assert_eq!(
            parse_schedule("explicit:1,1,2", 8).unwrap(),
            ComputeSchedule::Explicit(vec![1, 1, 2])
        );
        assert!(parse_schedule("bogus:1", 8).is_err());
    }

    #[test]
    fn fabric_defaults_to_the_bitwise_sim_backend() {
        let cfg = ExperimentConfig::paper_default("artifacts", "nano");
        assert_eq!(cfg.fabric.kind, FabricKind::Sim);
        assert!(cfg.fabric.validate().is_ok());
        // And an empty TOML doc keeps it that way — the golden traces
        // depend on `sim` staying the default.
        let doc = TomlDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.fabric.kind, FabricKind::Sim);
    }

    #[test]
    fn fabric_toml_keys_parse_and_validate() {
        let doc = TomlDoc::parse(
            r#"
            [fabric]
            kind = "tcp"
            host = "0.0.0.0"
            port = 9123
            spawn = false
            worker_bin = "/usr/local/bin/diloco"
            connect_timeout_s = 3.5
            phase_timeout_s = 45.0
            heartbeat_timeout_s = 1.5
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.fabric.kind, FabricKind::Tcp);
        assert_eq!(cfg.fabric.host, "0.0.0.0");
        assert_eq!(cfg.fabric.port, 9123);
        assert!(!cfg.fabric.spawn);
        assert_eq!(cfg.fabric.worker_bin.as_deref(), Some("/usr/local/bin/diloco"));
        assert_eq!(cfg.fabric.connect_timeout_s, 3.5);
        assert_eq!(cfg.fabric.phase_timeout_s, 45.0);
        assert_eq!(cfg.fabric.heartbeat_timeout_s, 1.5);

        assert!(FabricKind::parse("bogus").is_err());
        let bad_port = TomlDoc::parse("[fabric]\nport = 70000").unwrap();
        assert!(ExperimentConfig::from_toml(&bad_port)
            .unwrap_err()
            .to_string()
            .contains("fabric.port"));
        let bad_timeout =
            TomlDoc::parse("[fabric]\nphase_timeout_s = 0.0").unwrap();
        assert!(ExperimentConfig::from_toml(&bad_timeout)
            .unwrap_err()
            .to_string()
            .contains("phase_timeout_s"));
    }

    #[test]
    fn from_toml_roundtrip() -> anyhow::Result<()> {
        let doc = TomlDoc::parse(
            r#"
            seed = 7
            model = "nano"
            [diloco]
            workers = 4
            inner_steps = 50
            rounds = 3
            schedule = "ramp:1,4"
            prune_frac = 0.5
            [outer_opt]
            kind = "adam"
            lr = 0.3
            eps = 0.1
            [data]
            non_iid = false
            [comm]
            drop_prob = 0.3
            "#,
        )?;
        let cfg = ExperimentConfig::from_toml(&doc)?;
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.inner_steps, 50);
        assert_eq!(cfg.prune_frac, 0.5);
        assert!(!cfg.data.non_iid);
        assert_eq!(cfg.comm.drop_prob, 0.3);
        assert_eq!(cfg.schedule, ComputeSchedule::Ramp { from: 1, to: 4 });
        // Unparsed sections keep their defaults.
        assert_eq!(cfg.stream, StreamConfig::default());
        // A wrong optimizer variant is a proper error, not a test panic —
        // mirrors how config validation reports through anyhow.
        let OuterOptConfig::Adam { lr, eps, .. } = cfg.outer_opt else {
            anyhow::bail!("wrong opt {:?}", cfg.outer_opt.name());
        };
        assert!((lr - 0.3).abs() < 1e-6);
        assert!((eps - 0.1).abs() < 1e-6);
        Ok(())
    }

    #[test]
    fn from_toml_stream_section() -> anyhow::Result<()> {
        let doc = TomlDoc::parse(
            "[stream]\nfragments = 4\nschedule = \"staggered\"\ncodec = \"q8\"\n\
             error_feedback = true",
        )?;
        let cfg = ExperimentConfig::from_toml(&doc)?;
        assert_eq!(
            cfg.stream,
            StreamConfig {
                fragments: 4,
                schedule: SyncSchedule::Staggered,
                codec: Codec::Q8,
                error_feedback: true,
            }
        );
        // The sub-byte codecs parse from TOML too.
        let doc = TomlDoc::parse("[stream]\ncodec = \"q2\"")?;
        assert_eq!(ExperimentConfig::from_toml(&doc)?.stream.codec, Codec::Q2);
        assert!(!cfg.stream.is_monolithic());
        assert!(ExperimentConfig::paper_default("a", "nano")
            .stream
            .is_monolithic());
        Ok(())
    }

    #[test]
    fn from_toml_rejects_malformed_stream_section() {
        // Negative paths surface as anyhow errors through validate(),
        // never as panics.
        for bad in [
            "[stream]\nfragments = 0",
            "[stream]\ncodec = \"q3\"",
            "[stream]\nschedule = \"round-robin\"",
            "[stream]\nfragments = -3",
            "[stream]\nerror_feedback = \"maybe\"",
        ] {
            let Ok(doc) = TomlDoc::parse(bad) else { continue };
            let err = ExperimentConfig::from_toml(&doc)
                .expect_err(&format!("{bad:?} must be rejected"));
            assert!(!format!("{err:#}").is_empty());
        }
    }

    #[test]
    fn stream_cli_mini_language() {
        let s = StreamConfig::parse("fragments=4,schedule=staggered,codec=q8").unwrap();
        assert_eq!(s.fragments, 4);
        assert_eq!(s.schedule, SyncSchedule::Staggered);
        assert_eq!(s.codec, Codec::Q8);
        // Partial specs keep defaults.
        let s = StreamConfig::parse("codec=f16").unwrap();
        assert_eq!(s.fragments, 1);
        assert_eq!(s.schedule, SyncSchedule::EveryRound);
        assert_eq!(s.codec, Codec::F16);
        assert!(!s.error_feedback);
        let s = StreamConfig::parse("codec=q4,error_feedback=true").unwrap();
        assert_eq!(s.codec, Codec::Q4);
        assert!(s.error_feedback);
        assert!(StreamConfig::parse("error_feedback=maybe").is_err());
        assert!(StreamConfig::parse("fragments=0").is_err());
        assert!(StreamConfig::parse("fragments=two").is_err());
        assert!(StreamConfig::parse("bogus=1").is_err());
        assert!(StreamConfig::parse("fragments").is_err());
    }

    #[test]
    fn sync_schedule_fragments_due() {
        let every = SyncSchedule::EveryRound;
        assert_eq!(every.fragments_due(5, 3), vec![0, 1, 2]);
        assert!(!every.defers_barrier());
        let stag = SyncSchedule::Staggered;
        assert_eq!(stag.fragments_due(0, 4), vec![0]);
        assert_eq!(stag.fragments_due(6, 4), vec![2]);
        assert_eq!(stag.fragments_due(3, 1), vec![0]);
        let over = SyncSchedule::Overlapped;
        assert_eq!(over.fragments_due(1, 2), vec![0, 1]);
        assert!(over.defers_barrier());
        // Parse round-trips every schedule name.
        for s in [every, stag, over] {
            assert_eq!(SyncSchedule::parse(s.name()).unwrap(), s);
        }
        assert!(SyncSchedule::parse("sometimes").is_err());
    }

    #[test]
    fn topology_parse_language() {
        assert_eq!(TopologyConfig::parse("star").unwrap(), TopologyConfig::Star);
        assert_eq!(TopologyConfig::parse("ring").unwrap(), TopologyConfig::Ring);
        assert_eq!(
            TopologyConfig::parse("gossip").unwrap(),
            TopologyConfig::Gossip
        );
        assert_eq!(
            TopologyConfig::parse("hierarchical").unwrap(),
            TopologyConfig::Hierarchical { groups: 2 }
        );
        assert_eq!(
            TopologyConfig::parse("hier:4").unwrap(),
            TopologyConfig::Hierarchical { groups: 4 }
        );
        assert!(TopologyConfig::parse("hierarchical:0").is_err());
        assert!(TopologyConfig::parse("hierarchical:x").is_err());
        assert!(TopologyConfig::parse("mesh").is_err());
        // Name round-trips (hierarchical re-parses to the default G).
        for t in [TopologyConfig::Star, TopologyConfig::Ring, TopologyConfig::Gossip] {
            assert_eq!(TopologyConfig::parse(t.name()).unwrap(), t);
            assert!(!t.name().is_empty());
        }
        assert!(TopologyConfig::Ring.is_decentralized());
        assert!(TopologyConfig::Gossip.is_decentralized());
        assert!(!TopologyConfig::Star.is_decentralized());
        assert!(!TopologyConfig::Hierarchical { groups: 2 }.is_decentralized());
    }

    #[test]
    fn from_toml_topology_section() -> anyhow::Result<()> {
        let doc = TomlDoc::parse("[topology]\nkind = \"gossip\"")?;
        assert_eq!(
            ExperimentConfig::from_toml(&doc)?.topology,
            TopologyConfig::Gossip
        );
        // A bare group count implies the hierarchical topology.
        let doc = TomlDoc::parse("[topology]\ngroups = 4")?;
        assert_eq!(
            ExperimentConfig::from_toml(&doc)?.topology,
            TopologyConfig::Hierarchical { groups: 4 }
        );
        // kind + groups compose when they agree (or kind has no :G).
        let doc = TomlDoc::parse("[topology]\nkind = \"hierarchical\"\ngroups = 3")?;
        assert_eq!(
            ExperimentConfig::from_toml(&doc)?.topology,
            TopologyConfig::Hierarchical { groups: 3 }
        );
        // Absent section keeps the star default.
        let doc = TomlDoc::parse("seed = 1")?;
        assert_eq!(
            ExperimentConfig::from_toml(&doc)?.topology,
            TopologyConfig::Star
        );
        Ok(())
    }

    #[test]
    fn from_toml_rejects_malformed_topology() {
        for bad in [
            "[topology]\nkind = \"mesh\"",
            "[topology]\nkind = \"ring\"\ngroups = 2",
            "[topology]\nkind = \"hierarchical:4\"\ngroups = 2",
            "[topology]\nkind = \"hierarchical\"\ngroups = 0",
            "[topology]\ngroups = 0",
            "[topology]\nkind = \"ring\"\n[comm]\ndrop_prob = 0.3",
        ] {
            let Ok(doc) = TomlDoc::parse(bad) else { continue };
            ExperimentConfig::from_toml(&doc)
                .expect_err(&format!("{bad:?} must be rejected"));
        }
    }

    #[test]
    fn prune_now_composes_with_every_codec_and_topology() -> anyhow::Result<()> {
        // The three dense-only wire-format rejections are lifted: pruning
        // with a quantized codec, pruning on the ring, and pruning under
        // the hierarchical topology all validate (the sparse wire format
        // bills them exactly — see comm::wire and the coordinator tests).
        for ok in [
            "[diloco]\nprune_frac = 0.5\n[stream]\ncodec = \"q8\"",
            "[diloco]\nprune_frac = 0.5\n[stream]\ncodec = \"q4\"\n\
             error_feedback = true",
            "[topology]\nkind = \"ring\"\n[diloco]\nprune_frac = 0.5",
            "[topology]\nkind = \"hierarchical\"\n[diloco]\nprune_frac = 0.5",
            "[topology]\nkind = \"gossip\"\n[diloco]\nprune_frac = 0.25\n\
             [stream]\ncodec = \"q2\"",
        ] {
            let doc = TomlDoc::parse(ok)?;
            ExperimentConfig::from_toml(&doc)
                .map_err(|e| anyhow::anyhow!("{ok:?} must validate: {e:#}"))?;
        }
        Ok(())
    }

    #[test]
    fn validate_rejects_too_few_training_docs() {
        // The old behavior was a hard assert deep in shard_corpus; the
        // invariant now surfaces as a proper error at validation time.
        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        cfg.workers = 8;
        cfg.schedule = ComputeSchedule::Constant(8);
        cfg.data.n_docs = 6;
        let err = cfg.validate().expect_err("6 docs over 8 shards");
        assert!(format!("{err:#}").contains("training documents"));
        cfg.data.n_docs = 400;
        cfg.validate().unwrap();
        // The schedule's peak counts, not just diloco.workers.
        cfg.schedule = ComputeSchedule::Ramp { from: 1, to: 500 };
        assert!(cfg.validate().is_err());
        // holdout = 1.0 would hold out everything.
        cfg.schedule = ComputeSchedule::Constant(2);
        cfg.data.holdout = 1.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn churn_dsl_parse_and_roster() {
        let c = ChurnConfig::parse("leave:w1@r2,join:w4@r3").unwrap();
        // Worker 4 is beyond the static count of 3, so the pool grows.
        assert_eq!(c.pool_size(3), 5);
        assert_eq!(c.active_ids(0, 6, 3), vec![0, 1, 2]);
        assert_eq!(c.active_ids(2, 6, 3), vec![0, 2]); // w1 left
        assert_eq!(c.active_ids(3, 6, 3), vec![0, 2, 4]); // w4 joined
        // Leave-then-rejoin: the latest event at or before t wins.
        let c = ChurnConfig::parse("leave:w0@r1,join:w0@r3").unwrap();
        assert_eq!(c.active_ids(0, 5, 2), vec![0, 1]);
        assert_eq!(c.active_ids(1, 5, 2), vec![1]);
        assert_eq!(c.active_ids(2, 5, 2), vec![1]);
        assert_eq!(c.active_ids(3, 5, 2), vec![0, 1]);
        // Chronology is authoritative even when listed out of order.
        let c = ChurnConfig::parse("join:w0@r3,leave:w0@r1").unwrap();
        assert_eq!(c.active_ids(4, 5, 1), vec![0]);
        // ramp: replaces the static base roster.
        let c = ChurnConfig::parse("ramp:1..4").unwrap();
        assert_eq!(c.active_ids(0, 4, 8), vec![0]);
        assert_eq!(c.active_ids(3, 4, 8), vec![0, 1, 2, 3]);
        assert_eq!(c.pool_size(8), 4);
    }

    #[test]
    fn churn_dsl_rejects_malformed_items() {
        for bad in [
            "",
            "leave:3@r10",      // missing w prefix
            "leave:w3",         // missing round
            "leave:w3@10",      // missing r prefix
            "join:wx@r1",       // non-numeric worker
            "ramp:4",           // missing ..
            "ramp:0..4",        // empty start roster
            "ramp:1..2,ramp:2..3", // two ramps
            "pause:w1@r2",      // unknown kind
        ] {
            assert!(ChurnConfig::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn churn_validate_catches_bad_schedules() {
        // Event beyond the run's rounds.
        let c = ChurnConfig::parse("leave:w0@r9").unwrap();
        assert!(c.validate(4, 2).is_err());
        // Every worker gone at round 1.
        let c = ChurnConfig::parse("leave:w0@r1,leave:w1@r1").unwrap();
        assert!(c.validate(4, 2).is_err());
        // A leave naming a worker nothing ever activates is a typo, not
        // a reason to allocate a bigger pool.
        let c = ChurnConfig::parse("leave:w9@r1").unwrap();
        assert!(c.validate(4, 2).is_err());
        assert_eq!(c.pool_size(2), 2);
        // Leaving one of two workers is fine.
        let c = ChurnConfig::parse("leave:w1@r1").unwrap();
        c.validate(4, 2).unwrap();
    }

    #[test]
    fn experiment_config_churn_roster_and_validation() {
        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        cfg.workers = 3;
        cfg.schedule = ComputeSchedule::Constant(3);
        cfg.rounds = 6;
        cfg.churn = Some(ChurnConfig::parse("leave:w1@r2,join:w1@r4").unwrap());
        cfg.validate().unwrap();
        assert_eq!(cfg.active_ids(0), vec![0, 1, 2]);
        assert_eq!(cfg.active_ids(2), vec![0, 2]);
        assert_eq!(cfg.active_ids(4), vec![0, 1, 2]);
        assert_eq!(cfg.pool_size(), 3);
        // Without churn, the roster is the schedule prefix (pre-churn
        // behavior, bitwise).
        cfg.churn = None;
        cfg.schedule = ComputeSchedule::Step { first: 1, second: 3 };
        assert_eq!(cfg.active_ids(0), vec![0]);
        assert_eq!(cfg.active_ids(5), vec![0, 1, 2]);
        // Churn composes with the constant schedule only.
        cfg.churn = Some(ChurnConfig::parse("leave:w1@r2").unwrap());
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn ckpt_config_validation() {
        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        cfg.ckpt.save_every = 2;
        assert!(cfg.validate().is_err(), "save_every without a path");
        cfg.ckpt.path = Some("state.ckpt".into());
        cfg.validate().unwrap();
        cfg.ckpt = CkptConfig { save_every: 0, path: None, resume: Some("x".into()) };
        cfg.validate().unwrap();
    }

    #[test]
    fn from_toml_churn_and_ckpt_sections() -> anyhow::Result<()> {
        let doc = TomlDoc::parse(
            "[churn]\nschedule = \"leave:w1@r2\"\n\
             [ckpt]\nsave_every = 2\npath = \"state.ckpt\"\nresume = \"old.ckpt\"",
        )?;
        let cfg = ExperimentConfig::from_toml(&doc)?;
        assert_eq!(cfg.churn, Some(ChurnConfig::parse("leave:w1@r2")?));
        assert_eq!(cfg.ckpt.save_every, 2);
        assert_eq!(cfg.ckpt.path.as_deref(), Some("state.ckpt"));
        assert_eq!(cfg.ckpt.resume.as_deref(), Some("old.ckpt"));
        // Absent sections keep the defaults.
        let cfg = ExperimentConfig::from_toml(&TomlDoc::parse("seed = 1")?)?;
        assert_eq!(cfg.churn, None);
        assert_eq!(cfg.ckpt, CkptConfig::default());
        // Malformed churn DSL and ckpt combinations are proper errors.
        let doc = TomlDoc::parse("[churn]\nschedule = \"leave:3@r1\"")?;
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[ckpt]\nsave_every = 2")?;
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        Ok(())
    }

    #[test]
    fn speed_dsl_parse_and_factors() {
        let s = SpeedConfig::parse("w0=2.0,w2=1.0..3.0,jitter:0.25").unwrap();
        assert!(!s.is_uniform());
        assert_eq!(s.jitter, 0.25);
        assert_eq!(s.max_profiled_worker(), 3);
        // Constant factor holds every round; ramp hits its endpoints.
        // Jitter stays within ±25% of the base.
        for t in 0..8 {
            let f0 = s.factor(0, t, 8, 0);
            assert!(f0 > 2.0 * 0.75 - 1e-12 && f0 < 2.0 * 1.25 + 1e-12, "{f0}");
        }
        let no_jit = SpeedConfig::parse("w2=1.0..3.0").unwrap();
        assert_eq!(no_jit.factor(2, 0, 8, 0), 1.0);
        assert_eq!(no_jit.factor(2, 7, 8, 0), 3.0);
        assert_eq!(no_jit.factor(1, 5, 8, 0), 1.0, "unlisted worker is nominal");
        // Latest profile wins per worker.
        let dup = SpeedConfig::parse("w1=2.0,w1=4.0").unwrap();
        assert_eq!(dup.factor(1, 0, 8, 0), 4.0);
        // Jitter draws: deterministic in (seed, worker, round), varying
        // across rounds and seeds.
        let j = SpeedConfig::parse("jitter:0.3").unwrap();
        assert_eq!(j.factor(0, 3, 8, 7), j.factor(0, 3, 8, 7));
        assert_ne!(j.factor(0, 3, 8, 7), j.factor(0, 4, 8, 7));
        assert_ne!(j.factor(0, 3, 8, 7), j.factor(0, 3, 8, 8));
        // The empty model is uniform.
        assert!(SpeedConfig::parse("").unwrap().is_uniform());
        assert_eq!(SpeedConfig::default().factor(5, 3, 8, 0), 1.0);
    }

    #[test]
    fn speed_dsl_rejects_malformed_items() {
        for bad in [
            "w3",                  // no factor
            "3=2.0",               // missing w prefix
            "wx=2.0",              // non-numeric worker
            "w3=0",                // zero factor
            "w3=-1.5",             // negative factor
            "w3=0..2",             // zero ramp start
            "w3=nan",              // non-finite
            "jitter:1.0",          // amplitude must stay below 1
            "jitter:-0.1",         // negative amplitude
            "jitter:0.2,jitter:0.3", // two jitters
            "turbo:w1",            // unknown item
        ] {
            assert!(SpeedConfig::parse(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn sync_config_validation_and_composition() {
        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        assert!(cfg.sync.is_synchronous());
        cfg.sync.delay_rounds = 2;
        cfg.validate().unwrap();
        // Discount outside (0, 1] is rejected.
        cfg.sync.discount = 0.0;
        assert!(cfg.validate().is_err());
        cfg.sync.discount = 1.5;
        assert!(cfg.validate().is_err());
        cfg.sync.discount = 0.9;
        cfg.validate().unwrap();
        // Delay composes with centralized topologies only.
        cfg.topology = TopologyConfig::Ring;
        assert!(cfg.validate().is_err());
        cfg.topology = TopologyConfig::Gossip;
        assert!(cfg.validate().is_err());
        cfg.topology = TopologyConfig::Hierarchical { groups: 2 };
        cfg.validate().unwrap();
        cfg.topology = TopologyConfig::Star;
        // A delay past the run's rounds is a typo, not a schedule.
        cfg.sync.delay_rounds = cfg.rounds + 1;
        assert!(cfg.validate().is_err());
        // Speed profiles must name workers inside the pool.
        cfg.sync.delay_rounds = 0;
        cfg.speed = SpeedConfig::parse("w99=2.0").unwrap();
        assert!(cfg.validate().is_err());
        cfg.speed = SpeedConfig::parse("w7=2.0").unwrap();
        cfg.validate().unwrap();
    }

    #[test]
    fn speed_factors_roster_mapping() {
        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        cfg.workers = 4;
        cfg.schedule = ComputeSchedule::Constant(4);
        // Uniform model: all factors exactly 1.0 (the bitwise guarantee).
        assert_eq!(cfg.speed_factors(&[0, 1, 2, 3], 0), vec![1.0; 4]);
        cfg.speed = SpeedConfig::parse("w2=3.0").unwrap();
        assert_eq!(cfg.speed_factors(&[0, 2], 1), vec![1.0, 3.0]);
    }

    #[test]
    fn from_toml_speed_and_sync_sections() -> anyhow::Result<()> {
        let doc = TomlDoc::parse(
            "[speed]\nprofile = \"w3=2.0,jitter:0.2\"\n\
             [sync]\ndelay_rounds = 1\ndiscount = 0.8",
        )?;
        let cfg = ExperimentConfig::from_toml(&doc)?;
        assert_eq!(cfg.speed, SpeedConfig::parse("w3=2.0,jitter:0.2")?);
        assert_eq!(cfg.sync, SyncConfig { delay_rounds: 1, discount: 0.8 });
        // Absent sections keep the synchronous uniform defaults.
        let cfg = ExperimentConfig::from_toml(&TomlDoc::parse("seed = 1")?)?;
        assert!(cfg.speed.is_uniform());
        assert_eq!(cfg.sync, SyncConfig::default());
        // Malformed combinations are proper errors.
        for bad in [
            "[speed]\nprofile = \"w3=0\"",
            "[sync]\ndiscount = 0.0",
            "[sync]\ndelay_rounds = 1\n[topology]\nkind = \"ring\"",
        ] {
            let Ok(doc) = TomlDoc::parse(bad) else { continue };
            assert!(ExperimentConfig::from_toml(&doc).is_err(), "{bad:?}");
        }
        Ok(())
    }

    #[test]
    fn from_toml_rejects_unknown_opt() {
        let doc = TomlDoc::parse("[outer_opt]\nkind = \"lion\"").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn engine_parse_language() {
        assert_eq!(EngineConfig::parse("auto").unwrap(), EngineConfig::Auto);
        assert_eq!(
            EngineConfig::parse("sequential").unwrap(),
            EngineConfig::Sequential
        );
        assert_eq!(
            EngineConfig::parse("parallel").unwrap(),
            EngineConfig::Parallel { threads: 0 }
        );
        assert_eq!(
            EngineConfig::parse("parallel:4").unwrap(),
            EngineConfig::Parallel { threads: 4 }
        );
        assert!(EngineConfig::parse("gpu").is_err());
        assert!(EngineConfig::parse("parallel:x").is_err());
    }

    #[test]
    fn engine_env_override_is_pure() {
        assert_eq!(
            EngineConfig::from_env_var(None).unwrap(),
            EngineConfig::Auto
        );
        assert_eq!(
            EngineConfig::from_env_var(Some("sequential")).unwrap(),
            EngineConfig::Sequential
        );
        assert!(EngineConfig::from_env_var(Some("bogus")).is_err());
    }

    #[test]
    fn engine_auto_builds_by_worker_count() {
        use crate::engine::InnerPhaseExecutor as _;
        assert_eq!(EngineConfig::Auto.build(1).name(), "sequential");
        assert_eq!(EngineConfig::Auto.build(4).name(), "parallel");
        assert_eq!(EngineConfig::Sequential.build(8).name(), "sequential");
        assert_eq!(
            EngineConfig::Parallel { threads: 2 }.build(1).name(),
            "parallel"
        );
    }

    #[test]
    fn from_toml_engine_knob() {
        let doc = TomlDoc::parse("[engine]\nkind = \"parallel\"\nthreads = 3").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.engine, EngineConfig::Parallel { threads: 3 });
        let doc = TomlDoc::parse("[engine]\nkind = \"sequential\"").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.engine, EngineConfig::Sequential);
        // Bare threads cap implies the parallel engine.
        let doc = TomlDoc::parse("[engine]\nthreads = 2").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.engine, EngineConfig::Parallel { threads: 2 });
        // Matching redundant specs are fine.
        let doc = TomlDoc::parse("[engine]\nkind = \"parallel:2\"\nthreads = 2").unwrap();
        let cfg = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.engine, EngineConfig::Parallel { threads: 2 });
    }

    #[test]
    fn from_toml_fast_math_knob() {
        // Off by default — the bitwise golden-trace contract requires
        // every run to opt in to reordered float paths explicitly.
        let doc = TomlDoc::parse("").unwrap();
        assert!(!ExperimentConfig::from_toml(&doc).unwrap().fast_math);
        let doc = TomlDoc::parse("[engine]\nfast_math = true").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).unwrap().fast_math);
        let doc = TomlDoc::parse("[engine]\nfast_math = false").unwrap();
        assert!(!ExperimentConfig::from_toml(&doc).unwrap().fast_math);
    }

    #[test]
    fn from_toml_engine_conflicts_rejected() {
        // Same contradictions the CLI rejects must fail here too, not
        // silently pick a winner.
        let doc = TomlDoc::parse("[engine]\nkind = \"sequential\"\nthreads = 4").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("[engine]\nkind = \"parallel:8\"\nthreads = 2").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn adversary_dsl_and_attacker_math() {
        let a = AdversaryConfig::parse("flip:0.25:2.0").unwrap();
        assert_eq!(a.attack, AttackKind::FlipSign);
        assert_eq!(a.fraction, 0.25);
        assert_eq!(a.scale, 2.0);
        assert_eq!(a.label(), "flip:0.25:2");
        assert_eq!(a.n_attackers(8), 2);
        assert_eq!(a.n_attackers(7), 1); // floor
        let ids = a.attacker_ids(9, 8);
        assert_eq!(ids.len(), 2);
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        assert!(ids.iter().all(|&w| w < 8));
        assert_eq!(ids, a.attacker_ids(9, 8), "set is seed-deterministic");
        // Scale defaults to 1.0; every attack kind round-trips.
        assert_eq!(AdversaryConfig::parse("nan:0.125").unwrap().scale, 1.0);
        for kind in ["flip", "noise", "nan", "stale"] {
            let c = AdversaryConfig::parse(&format!("{kind}:0.25")).unwrap();
            assert_eq!(c.attack.name(), kind);
            assert_eq!(AttackKind::parse(kind).unwrap(), c.attack);
        }
        for bad in [
            "flip", "flip:0.0", "flip:1.0", "flip:-0.5", "flip:nope",
            "flip:0.25:0", "flip:0.25:inf", "flip:0.25:1:9", "melt:0.25", "",
        ] {
            assert!(AdversaryConfig::parse(bad).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn aggregate_dsl_round_trips() {
        for (s, want) in [
            ("mean", AggregateConfig::WeightedMean),
            ("median", AggregateConfig::CoordinateMedian),
            ("trimmed:1", AggregateConfig::TrimmedMean { trim: 1 }),
            ("trimmed:0", AggregateConfig::TrimmedMean { trim: 0 }),
            ("krum:2", AggregateConfig::Krum { f: 2 }),
        ] {
            let got = AggregateConfig::parse(s).unwrap();
            assert_eq!(got, want);
            assert_eq!(AggregateConfig::parse(&got.label()).unwrap(), got);
        }
        assert!(AggregateConfig::default().is_default());
        assert!(!AggregateConfig::CoordinateMedian.is_default());
        for bad in ["trimmed", "krum", "trimmed:x", "krum:-1", "average", ""] {
            assert!(AggregateConfig::parse(bad).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn from_toml_adversary_and_aggregate_sections() -> anyhow::Result<()> {
        let doc = TomlDoc::parse(
            "[adversary]\nattack = \"noise\"\nfraction = 0.25\nscale = 3.0\n\
             [aggregate]\nkind = \"trimmed:2\"",
        )?;
        let cfg = ExperimentConfig::from_toml(&doc)?;
        let adv = cfg.adversary.expect("adversary section parsed");
        assert_eq!(adv.attack, AttackKind::ScaledNoise);
        assert_eq!(adv.fraction, 0.25);
        assert_eq!(adv.scale, 3.0);
        assert_eq!(cfg.aggregate, AggregateConfig::TrimmedMean { trim: 2 });
        // fraction defaults to 0.25, scale to 1.0.
        let doc = TomlDoc::parse("[adversary]\nattack = \"flip\"")?;
        let adv = ExperimentConfig::from_toml(&doc)?.adversary.unwrap();
        assert_eq!(adv.fraction, 0.25);
        assert_eq!(adv.scale, 1.0);
        // Absent sections keep the honest mean defaults.
        let doc = TomlDoc::parse("seed = 3")?;
        let cfg = ExperimentConfig::from_toml(&doc)?;
        assert!(cfg.adversary.is_none());
        assert!(cfg.aggregate.is_default());
        Ok(())
    }

    #[test]
    fn validate_rejects_bad_adversary_and_aggregate_compositions() {
        let base = ExperimentConfig::paper_default("a", "nano");

        // Attacker count >= roster size: only fraction >= 1 can reach it
        // (floor(f·k) < k for any f < 1), and that is rejected at the
        // field level — constructed directly to bypass the DSL parser.
        let mut cfg = base.clone();
        cfg.adversary =
            Some(AdversaryConfig { attack: AttackKind::FlipSign, fraction: 1.0, scale: 1.0 });
        let err = cfg.validate().expect_err("all-attacker roster must fail");
        assert!(format!("{err}").starts_with("[adversary]"), "{err}");

        // Fraction that floors to zero attackers.
        let mut cfg = base.clone();
        cfg.adversary =
            Some(AdversaryConfig { attack: AttackKind::FlipSign, fraction: 0.05, scale: 1.0 });
        let err = cfg.validate().expect_err("zero attackers must fail");
        assert!(format!("{err}").contains("zero attackers"), "{err}");

        // Trim too large for k: 2*trim >= k.
        let mut cfg = base.clone();
        cfg.aggregate = AggregateConfig::TrimmedMean { trim: 4 }; // k = 8
        let err = cfg.validate().expect_err("over-trim must fail");
        assert!(format!("{err}").starts_with("[aggregate]"), "{err}");
        cfg.aggregate = AggregateConfig::TrimmedMean { trim: 3 };
        cfg.validate().expect("2*3 < 8 is fine");

        // Krum on k < 2f + 3.
        let mut cfg = base.clone();
        cfg.aggregate = AggregateConfig::Krum { f: 3 }; // needs 9 > 8
        assert!(cfg.validate().is_err());
        cfg.aggregate = AggregateConfig::Krum { f: 2 }; // needs 7 <= 8
        cfg.validate().expect("krum:2 on k=8 is fine");

        // fast_math composes with the mean path only.
        let mut cfg = base.clone();
        cfg.fast_math = true;
        cfg.aggregate = AggregateConfig::CoordinateMedian;
        let err = cfg.validate().expect_err("fast_math x robust must fail");
        assert!(format!("{err}").starts_with("[compose]"), "{err}");
        cfg.aggregate = AggregateConfig::WeightedMean;
        cfg.validate().expect("fast_math mean path is fine");
    }

    #[test]
    fn validate_errors_are_section_tagged() {
        // The dispatcher wraps every rejection in ConfigError, rendering
        // as "[section] message" with the original detail preserved.
        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        cfg.comm.drop_prob = 1.5;
        let err = cfg.validate().expect_err("bad drop_prob");
        let msg = format!("{err}");
        assert!(msg.starts_with("[comm]"), "{msg}");
        assert!(msg.contains("drop_prob"), "{msg}");
        let tagged = err.downcast_ref::<ConfigError>().expect("ConfigError");
        assert_eq!(tagged.section, "comm");

        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        cfg.stream.fragments = 0;
        let err = cfg.validate().expect_err("bad fragments");
        assert!(format!("{err}").starts_with("[stream]"), "{err}");

        let mut cfg = ExperimentConfig::paper_default("a", "nano");
        cfg.workers = 0;
        cfg.schedule = ComputeSchedule::Constant(1);
        let err = cfg.validate().expect_err("bad workers");
        assert!(format!("{err}").starts_with("[diloco]"), "{err}");
    }
}
