//! Rust-side mirror of `python/compile/configs.py` presets.
//!
//! The runtime always trusts the *manifest* (what was actually lowered);
//! these mirrors exist so the coordinator can sanity-check that the
//! artifacts on disk match the preset an experiment asked for, and so
//! Table 1 / Table 5 of the paper are asserted in unit tests without
//! touching python.

/// Architecture preset (paper Table 1 + scaled tiers, DESIGN.md §6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelPreset {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub vocab_size: usize,
    pub seq_len: usize,
}

impl ModelPreset {
    pub fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    /// Must agree with `ModelConfig.param_count()` in configs.py
    /// (asserted against the manifest in runtime tests).
    pub fn param_count(&self) -> usize {
        let (d, dh, nh, v, s) = (
            self.d_model,
            self.d_head,
            self.n_heads,
            self.vocab_size,
            self.seq_len,
        );
        let attn = d * (nh * dh) * 3 + (nh * dh) * d;
        let mlp = d * self.d_ff() + self.d_ff() + self.d_ff() * d + d;
        let block = attn + mlp + 4 * d;
        v * d + s * d + self.n_layers * block + 2 * d + d * v
    }
}

/// Paper Table 1.
pub const PAPER_60M: ModelPreset = ModelPreset {
    name: "60m", n_layers: 3, d_model: 896, n_heads: 16, d_head: 64,
    vocab_size: 32_000, seq_len: 1024,
};
pub const PAPER_150M: ModelPreset = ModelPreset {
    name: "150m", n_layers: 12, d_model: 896, n_heads: 16, d_head: 64,
    vocab_size: 32_000, seq_len: 1024,
};
pub const PAPER_400M: ModelPreset = ModelPreset {
    name: "400m", n_layers: 12, d_model: 1536, n_heads: 12, d_head: 128,
    vocab_size: 32_000, seq_len: 1024,
};

/// Scaled tiers (DESIGN.md §6).
pub const NANO: ModelPreset = ModelPreset {
    name: "nano", n_layers: 2, d_model: 64, n_heads: 4, d_head: 16,
    vocab_size: 256, seq_len: 32,
};
pub const MICRO: ModelPreset = ModelPreset {
    name: "micro", n_layers: 4, d_model: 128, n_heads: 4, d_head: 32,
    vocab_size: 512, seq_len: 64,
};
pub const TINY: ModelPreset = ModelPreset {
    name: "tiny", n_layers: 8, d_model: 256, n_heads: 8, d_head: 32,
    vocab_size: 2048, seq_len: 128,
};

pub const ALL: [&ModelPreset; 6] =
    [&PAPER_60M, &PAPER_150M, &PAPER_400M, &NANO, &MICRO, &TINY];

pub fn by_name(name: &str) -> Option<&'static ModelPreset> {
    ALL.iter().copied().find(|p| p.name == name)
}

/// Paper Table 5 (bold values) — the chosen hyperparameters.
pub mod paper_hparams {
    pub const INNER_LR: f64 = 4e-4;
    pub const WARMUP_STEPS: usize = 1000;
    pub const WEIGHT_DECAY: f64 = 0.1;
    pub const BATCH_SIZE: usize = 512;
    pub const SEQ_LEN: usize = 1024;
    pub const OUTER_NESTEROV_LR: f64 = 0.7;
    pub const OUTER_NESTEROV_MU: f64 = 0.9;
    pub const OUTER_ADAM_EPS: f64 = 0.1;
    pub const COMM_FREQ_H: usize = 500;
    pub const PRETRAIN_STEPS: usize = 24_000;
    pub const TOTAL_STEPS: usize = 88_000;
    pub const REPLICAS: usize = 8;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nano_param_count_matches_python() {
        // Value printed by configs.py / asserted in python tests.
        assert_eq!(NANO.param_count(), 134_400);
    }

    #[test]
    fn paper_sizes_near_nominal() {
        assert!((40e6..90e6).contains(&(PAPER_60M.param_count() as f64)));
        assert!((100e6..200e6).contains(&(PAPER_150M.param_count() as f64)));
        assert!((280e6..520e6).contains(&(PAPER_400M.param_count() as f64)));
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("micro"), Some(&MICRO));
        assert_eq!(by_name("nope"), None);
    }

    #[test]
    fn attention_dims_consistent() {
        // Table 1 uses nh*dh != d for some presets; check our formula's shape.
        for p in ALL {
            assert!(p.n_heads * p.d_head > 0);
            assert_eq!(p.d_ff(), 4 * p.d_model);
        }
    }
}
