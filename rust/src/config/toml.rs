//! TOML-subset parser (substrate — no toml/serde in the crate universe).
//!
//! Supports what experiment configs need: `[table]` and `[table.sub]`
//! headers, `key = value` with strings, integers, floats, booleans, and
//! homogeneous arrays, plus `#` comments. Keys flatten to dotted paths
//! (`model.name`), values land in a [`TomlDoc`] map. Unsupported TOML
//! (multiline strings, inline tables, dates, arrays-of-tables) is a parse
//! error, not silent misreading.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> anyhow::Result<i64> {
        match self {
            TomlValue::Int(x) => Ok(*x),
            _ => anyhow::bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let x = self.as_i64()?;
        if x < 0 {
            anyhow::bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            TomlValue::Float(x) => Ok(*x),
            TomlValue::Int(x) => Ok(*x as f64),
            _ => anyhow::bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => anyhow::bail!("expected bool, got {self:?}"),
        }
    }
}

/// A parsed document: dotted-path key → value.
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> anyhow::Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: bad table header", lineno + 1))?
                    .trim();
                if name.is_empty() || name.starts_with('[') {
                    anyhow::bail!("line {}: unsupported table header {line:?}", lineno + 1);
                }
                prefix = format!("{name}.");
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                anyhow::bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            let full = format!("{prefix}{key}");
            if doc.entries.insert(full.clone(), value).is_some() {
                anyhow::bail!("line {}: duplicate key {full:?}", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn load(path: &str) -> anyhow::Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> anyhow::Result<String> {
        match self.get(key) {
            Some(v) => Ok(v.as_str()?.to_string()),
            None => Ok(default.to_string()),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            Some(v) => v.as_usize(),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            Some(v) => v.as_f64(),
            None => Ok(default),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            Some(v) => v.as_bool(),
            None => Ok(default),
        }
    }

    /// Keys that were never read — surfaced as a config-typo warning.
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string literal.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> anyhow::Result<TomlValue> {
    if s.is_empty() {
        anyhow::bail!("empty value");
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("unterminated string {s:?}"))?;
        if body.contains('"') {
            anyhow::bail!("embedded quote in {s:?} (escapes unsupported)");
        }
        return Ok(TomlValue::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| anyhow::anyhow!("unterminated array {s:?}"))?
            .trim();
        if body.is_empty() {
            return Ok(TomlValue::Arr(vec![]));
        }
        let items = split_top_level(body)?;
        return Ok(TomlValue::Arr(
            items
                .iter()
                .map(|i| parse_value(i.trim()))
                .collect::<anyhow::Result<_>>()?,
        ));
    }
    let clean = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(x) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(x));
        }
    }
    if let Ok(x) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(x));
    }
    anyhow::bail!("cannot parse value {s:?}")
}

fn split_top_level(s: &str) -> anyhow::Result<Vec<String>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth = depth
                    .checked_sub(1)
                    .ok_or_else(|| anyhow::anyhow!("unbalanced brackets"))?;
                cur.push(c);
            }
            ',' if !in_str && depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_tables_and_scalars() {
        let doc = TomlDoc::parse(
            r#"
            # experiment
            seed = 17
            [model]
            name = "nano"   # preset
            lr = 4e-4
            deep = -1.5
            [diloco]
            workers = 8
            non_iid = true
            hs = [50, 100, 250]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("seed").unwrap().as_i64().unwrap(), 17);
        assert_eq!(doc.get("model.name").unwrap().as_str().unwrap(), "nano");
        assert!((doc.get("model.lr").unwrap().as_f64().unwrap() - 4e-4).abs() < 1e-12);
        assert_eq!(doc.get("diloco.workers").unwrap().as_usize().unwrap(), 8);
        assert!(doc.get("diloco.non_iid").unwrap().as_bool().unwrap());
        assert_eq!(
            doc.get("diloco.hs").unwrap(),
            &TomlValue::Arr(vec![
                TomlValue::Int(50),
                TomlValue::Int(100),
                TomlValue::Int(250)
            ])
        );
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        assert!(TomlDoc::parse("a 1").is_err());
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("a = \"unterminated").is_err());
        assert!(TomlDoc::parse("a = [1, 2").is_err());
    }

    #[test]
    fn comment_inside_string_preserved() {
        let doc = TomlDoc::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(doc.get("k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn defaults_api() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.usize_or("x", 9).unwrap(), 3);
        assert_eq!(doc.usize_or("y", 9).unwrap(), 9);
        assert_eq!(doc.str_or("name", "dflt").unwrap(), "dflt");
    }

    #[test]
    fn underscore_separators() {
        let doc = TomlDoc::parse("big = 88_000").unwrap();
        assert_eq!(doc.get("big").unwrap().as_i64().unwrap(), 88_000);
    }
}
