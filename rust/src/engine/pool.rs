//! The work pool's claim/output-slot protocol, isolated from
//! [`super::run_tasks`] so the loom model checker can drive it.
//!
//! Two tiny lock-step primitives make the pool order-deterministic:
//!
//! * [`ClaimQueue`] — a shared atomic counter handing out task indices.
//!   `fetch_add` is an atomic read-modify-write, so every index in
//!   `0..n` is claimed by exactly one worker, with no other shared
//!   state consulted.
//! * [`OutputSlots`] — one `Mutex<Option<T>>` per task index. Which
//!   *worker* fills a slot is scheduling-dependent; which *slot* an
//!   output lands in is a pure function of the claimed index, so
//!   reading the slots in index order restores task order exactly.
//!
//! Under `--cfg loom` the primitives compile against `loom::sync`, and
//! the `loom_model` tests exhaustively interleave a 2-worker / 3-task
//! pool to prove the protocol has no ordering- or visibility-dependent
//! outcome (every execution fills every slot exactly once). The real
//! `run_tasks` wires these same types against `std::sync`.

#[cfg(loom)]
use loom::sync::atomic::{AtomicUsize, Ordering};
#[cfg(loom)]
use loom::sync::Mutex;
#[cfg(not(loom))]
use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(not(loom))]
use std::sync::Mutex;

/// Shared task-index dispenser: `claim()` returns each index in `0..n`
/// exactly once (across all threads), then `None` forever.
pub struct ClaimQueue {
    next: AtomicUsize,
    n: usize,
}

impl ClaimQueue {
    pub fn new(n: usize) -> ClaimQueue {
        ClaimQueue {
            next: AtomicUsize::new(0),
            n,
        }
    }

    /// Claim the next unclaimed task index. `Relaxed` suffices: the
    /// counter itself is the only state the claim decides on (atomic
    /// RMW hands out each index exactly once regardless of ordering),
    /// and the subsequent task-state handoff is ordered by the per-slot
    /// `Mutex`, not by this counter.
    pub fn claim(&self) -> Option<usize> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i < self.n {
            Some(i)
        } else {
            None
        }
    }
}

/// One mutex-guarded output cell per task index. Filling is keyed by
/// the claimed index, so outputs are recovered in task order no matter
/// which worker ran what.
pub struct OutputSlots<T> {
    slots: Vec<Mutex<Option<T>>>,
}

impl<T> OutputSlots<T> {
    pub fn new(n: usize) -> OutputSlots<T> {
        OutputSlots {
            slots: (0..n).map(|_| Mutex::new(None)).collect(),
        }
    }

    /// Store task `i`'s output. Panics if the slot was already filled —
    /// under the [`ClaimQueue`] protocol that means a double-claim,
    /// which the loom model proves impossible.
    pub fn fill(&self, i: usize, value: T) {
        let prev = self.slots[i].lock().unwrap().replace(value);
        assert!(prev.is_none(), "output slot {i} filled twice (double-claimed task)");
    }

    /// Drain the outputs in task order. Panics if any slot is empty —
    /// i.e. a task was claimed but its worker never completed. Callers
    /// only reach this after joining every worker, so on the panic path
    /// (a worker died mid-task) the slots are never read.
    pub fn take_task_order(&self) -> Vec<T> {
        self.slots
            .iter()
            .enumerate()
            .map(|(i, m)| {
                m.lock()
                    .unwrap()
                    .take()
                    .unwrap_or_else(|| panic!("output slot {i} empty: task claimed but never run"))
            })
            .collect()
    }

    /// Number of slots (== number of tasks).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn claim_queue_hands_out_each_index_once_then_none() {
        let q = ClaimQueue::new(3);
        assert_eq!(q.claim(), Some(0));
        assert_eq!(q.claim(), Some(1));
        assert_eq!(q.claim(), Some(2));
        assert_eq!(q.claim(), None);
        assert_eq!(q.claim(), None); // saturated, stays None
    }

    #[test]
    fn slots_restore_task_order() {
        let s = OutputSlots::new(3);
        s.fill(2, "c");
        s.fill(0, "a");
        s.fill(1, "b");
        assert_eq!(s.take_task_order(), vec!["a", "b", "c"]);
    }

    #[test]
    #[should_panic(expected = "filled twice")]
    fn double_fill_panics() {
        let s = OutputSlots::new(1);
        s.fill(0, 1u8);
        s.fill(0, 2u8);
    }

    #[test]
    #[should_panic(expected = "never run")]
    fn empty_slot_panics_on_drain() {
        let s: OutputSlots<u8> = OutputSlots::new(2);
        s.fill(0, 1);
        let _ = s.take_task_order();
    }
}

/// Exhaustive interleaving check of the claim/slot protocol. Run with:
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_model`.
#[cfg(all(test, loom))]
mod loom_model {
    use super::{ClaimQueue, OutputSlots};
    use loom::sync::Arc;
    use loom::thread;

    #[test]
    fn claim_slot_protocol_fills_every_slot_exactly_once() {
        loom::model(|| {
            const TASKS: usize = 3;
            const WORKERS: usize = 2;
            let queue = Arc::new(ClaimQueue::new(TASKS));
            let slots = Arc::new(OutputSlots::new(TASKS));
            let handles: Vec<_> = (0..WORKERS)
                .map(|_| {
                    let queue = Arc::clone(&queue);
                    let slots = Arc::clone(&slots);
                    thread::spawn(move || {
                        // Same loop shape as run_tasks' workers: claim,
                        // "run" (here: i * 10), publish under the slot
                        // lock. fill() asserts no double-claim.
                        while let Some(i) = queue.claim() {
                            slots.fill(i, i * 10);
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            // Every interleaving must end with all slots filled once,
            // recovered in task order.
            assert_eq!(slots.take_task_order(), vec![0, 10, 20]);
        });
    }

    #[test]
    fn saturated_queue_never_yields_indices_out_of_range() {
        loom::model(|| {
            let queue = Arc::new(ClaimQueue::new(1));
            let a = {
                let queue = Arc::clone(&queue);
                thread::spawn(move || queue.claim())
            };
            let b = {
                let queue = Arc::clone(&queue);
                thread::spawn(move || queue.claim())
            };
            let (ra, rb) = (a.join().unwrap(), b.join().unwrap());
            // Exactly one thread wins the single task in every
            // interleaving; the loser sees None, never index 1.
            assert!(
                (ra == Some(0) && rb.is_none()) || (rb == Some(0) && ra.is_none()),
                "claims were {ra:?} / {rb:?}"
            );
        });
    }
}
