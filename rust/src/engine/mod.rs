//! Inner-phase execution engine — how island work actually runs.
//!
//! DiLoCo's premise is k islands training *concurrently* between rare
//! synchronizations, but execution strategy is a deployment concern, not
//! an algorithm concern. This module separates the two: the coordinator
//! describes a phase as one independent task per island, and an
//! [`InnerPhaseExecutor`] decides how those tasks map onto OS threads.
//!
//! Two implementations ship:
//!
//! * [`Sequential`] — the reference path: tasks run back-to-back on the
//!   calling thread, exactly like the pre-engine coordinator loop.
//! * [`ParallelIslands`] — tasks run under [`std::thread::scope`] with a
//!   configurable thread cap; islands execute truly concurrently against
//!   the shared (`Sync`) [`Runtime`].
//!
//! **Determinism contract:** outputs are returned in *island order*
//! (task i of the input vector is output i), never completion order, so
//! every downstream reduction — loss averaging, gradient sums, comm
//! billing — folds in the same order under either executor. Island tasks
//! are data-independent (each owns its worker's state and batch stream),
//! so the two executors produce bitwise-identical results; the
//! `parallel_matches_sequential_bitwise` integration test enforces this.
//!
//! Timing is likewise accumulated *locally* per island and reduced
//! deterministically by the caller: `max` over islands models simulated
//! wall-clock (islands overlap), `sum` models total CPU-seconds burned.

pub mod pool;

use crate::runtime::Runtime;
use crate::worker::Worker;
use pool::{ClaimQueue, OutputSlots};
use std::sync::Mutex;
use std::time::Instant;

/// Run `tasks` on a shared-queue work-stealing pool of (at most)
/// `threads` scoped worker threads, returning outputs in **task order**
/// (never completion order). This is the engine's generic fan-out
/// primitive: island phases, per-fragment reductions, and parallel outer
/// steps all dispatch through it.
///
/// Scheduling: workers claim the next unclaimed task index from a shared
/// atomic counter — a single global queue every idle worker steals from,
/// so a k=256 phase schedules 256 tasks onto ~N cores instead of
/// spawning 256 threads, and imbalanced task durations self-balance.
/// Which *worker* runs a task is nondeterministic; which *slot* its
/// output lands in is not, so downstream folds are order-deterministic
/// regardless of thread count (DESIGN.md §12).
///
/// `threads <= 1` (or a single task) degenerates to an inline sequential
/// loop on the calling thread — no threads, no locks.
///
/// **Panic behavior** (defined, not UB-by-accident — see the pool
/// edge-case tests): a panicking task unwinds its worker thread;
/// surviving workers keep draining the queue, then
/// [`std::thread::scope`] re-raises the panic once all workers have
/// joined. The output slots are never read on that path, so a partial
/// phase can never masquerade as a complete one. On the inline path the
/// panic propagates immediately. The claim/slot protocol itself lives
/// in [`pool`] and is loom-model-checked under `--cfg loom`.
pub fn run_tasks<'env, T: Send>(
    threads: usize,
    tasks: Vec<Box<dyn FnOnce() -> T + Send + 'env>>,
) -> Vec<T> {
    let n = tasks.len();
    if n == 0 {
        return Vec::new();
    }
    if threads <= 1 || n == 1 {
        return tasks.into_iter().map(|t| t()).collect();
    }
    let pending: Vec<Mutex<Option<Box<dyn FnOnce() -> T + Send + 'env>>>> =
        tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let queue = ClaimQueue::new(n);
    let slots: OutputSlots<T> = OutputSlots::new(n);
    let workers = threads.min(n);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| {
                while let Some(i) = queue.claim() {
                    let task = pending[i]
                        .lock()
                        .unwrap()
                        .take()
                        .expect("task index claimed exactly once");
                    slots.fill(i, task());
                }
            });
        }
    });
    slots.take_task_order()
}

/// What one island task reports back.
pub struct IslandOutput {
    /// Per-step losses, in step order.
    pub losses: Vec<f32>,
    /// Seconds spent inside PJRT executions (per-island compute).
    pub compute_s: f64,
    /// End-to-end wall seconds of the task (compute + batch prep).
    pub wall_s: f64,
    /// Optional task result (e.g. the DP baseline's gradient tensors).
    pub payload: Option<crate::runtime::Tensors>,
}

/// One island's unit of work. Boxed so heterogeneous phases (inner
/// steps, gradient computation) share one executor.
pub type IslandTask<'env> =
    Box<dyn FnOnce() -> anyhow::Result<IslandOutput> + Send + 'env>;

/// Strategy for running one phase of independent island tasks.
pub trait InnerPhaseExecutor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Run every task; outputs come back in island order. The first
    /// failing island (again in island order, not completion order)
    /// aborts the phase.
    fn run_islands<'env>(
        &self,
        tasks: Vec<IslandTask<'env>>,
    ) -> anyhow::Result<Vec<IslandOutput>>;

    /// Threads the coordinator should fan a phase of `n_tasks`
    /// order-independent reductions (per-fragment averages, partitioned
    /// outer steps) across. The sequential engine reduces inline (1);
    /// the parallel engine exposes its resolved thread cap so reductions
    /// ride the same pool sizing as island execution.
    fn reduce_threads(&self, _n_tasks: usize) -> usize {
        1
    }
}

/// Reference executor: islands run back-to-back on the calling thread.
///
/// ```
/// use diloco::engine::{InnerPhaseExecutor, IslandOutput, IslandTask, Sequential};
///
/// let tasks: Vec<IslandTask<'static>> = (0..3)
///     .map(|i| {
///         Box::new(move || {
///             Ok(IslandOutput {
///                 losses: vec![i as f32],
///                 compute_s: 0.0,
///                 wall_s: 0.0,
///                 payload: None,
///             })
///         }) as IslandTask<'static>
///     })
///     .collect();
/// let outs = Sequential.run_islands(tasks).unwrap();
/// // Island order, never completion order — the determinism contract.
/// assert_eq!(outs[2].losses, vec![2.0]);
/// ```
pub struct Sequential;

impl InnerPhaseExecutor for Sequential {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn run_islands<'env>(
        &self,
        tasks: Vec<IslandTask<'env>>,
    ) -> anyhow::Result<Vec<IslandOutput>> {
        tasks.into_iter().map(|t| t()).collect()
    }
}

/// Parallel executor: islands run on real OS threads (capped), mirroring
/// the paper's k-islands-in-parallel wall-clock model.
pub struct ParallelIslands {
    /// Maximum worker threads; 0 = one per available core.
    pub max_threads: usize,
}

impl ParallelIslands {
    pub fn new(max_threads: usize) -> ParallelIslands {
        ParallelIslands { max_threads }
    }

    /// Threads actually used for a phase of `n_tasks` islands.
    pub fn resolved_threads(&self, n_tasks: usize) -> usize {
        let cap = if self.max_threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.max_threads
        };
        cap.min(n_tasks).max(1)
    }
}

impl InnerPhaseExecutor for ParallelIslands {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn run_islands<'env>(
        &self,
        tasks: Vec<IslandTask<'env>>,
    ) -> anyhow::Result<Vec<IslandOutput>> {
        let n = tasks.len();
        let threads = self.resolved_threads(n);
        if n <= 1 || threads == 1 {
            return Sequential.run_islands(tasks);
        }
        // Work-stealing dispatch (see `run_tasks`): n tasks onto
        // `threads` pooled workers instead of the old one-thread-per-
        // chunk spawn, so k ≫ cores rounds schedule instead of thrash.
        // Collecting the task-ordered Results keeps the first error in
        // island order — the determinism contract.
        run_tasks(threads, tasks).into_iter().collect()
    }

    fn reduce_threads(&self, n_tasks: usize) -> usize {
        self.resolved_threads(n_tasks)
    }
}

/// Deterministic reduction of one finished inner phase.
pub struct InnerPhaseReport {
    /// Per-worker loss traces, in worker order.
    pub per_worker_losses: Vec<Vec<f32>>,
    per_worker_compute_s: Vec<f64>,
    per_worker_wall_s: Vec<f64>,
}

impl InnerPhaseReport {
    /// Assemble a report from traces produced off-engine. The TCP
    /// fabric runs inner phases in remote worker processes and ships
    /// the traces back; it uses this to hand the coordinator a report
    /// shaped exactly like the in-process engine path's.
    pub fn from_parts(
        per_worker_losses: Vec<Vec<f32>>,
        per_worker_compute_s: Vec<f64>,
        per_worker_wall_s: Vec<f64>,
    ) -> InnerPhaseReport {
        assert_eq!(per_worker_losses.len(), per_worker_compute_s.len());
        assert_eq!(per_worker_losses.len(), per_worker_wall_s.len());
        InnerPhaseReport { per_worker_losses, per_worker_compute_s, per_worker_wall_s }
    }

    /// Slowest island's PJRT compute — the simulated wall-clock cost of
    /// the phase (islands overlap).
    pub fn max_compute_s(&self) -> f64 {
        self.per_worker_compute_s.iter().fold(0.0, |a, &x| a.max(x))
    }

    /// Per-island PJRT compute seconds, in island order. The async
    /// scheduling layer scales these by per-worker speed factors before
    /// reducing, so the simulated wall-clock of a heterogeneous round is
    /// the true critical path (the straggler), not the raw max.
    pub fn per_worker_compute_s(&self) -> &[f64] {
        &self.per_worker_compute_s
    }

    /// Critical path of the phase under per-island speed factors:
    /// `max_i(compute_i · factor_i)`. With every factor exactly `1.0`
    /// this is bitwise [`Self::max_compute_s`] (`x * 1.0 == x` for every
    /// f64), which is what keeps homogeneous runs on the legacy trace.
    pub fn critical_path_s(&self, factors: &[f64]) -> f64 {
        debug_assert_eq!(factors.len(), self.per_worker_compute_s.len());
        self.per_worker_compute_s
            .iter()
            .zip(factors)
            .fold(0.0, |a, (&c, &f)| a.max(c * f))
    }

    /// Simulated seconds the phase's islands spent waiting at the round
    /// barrier for the straggler: `Σ_i (critical_path − compute_i ·
    /// factor_i)`. Zero for a single island; grows with speed
    /// heterogeneity — the quantity the async delayed loop exists to
    /// reclaim.
    pub fn idle_s(&self, factors: &[f64]) -> f64 {
        let crit = self.critical_path_s(factors);
        // detlint: allow(float_fold, timing column only (DESIGN.md §4 rule 3): reduced in fixed island order, never feeds model state)
        self.per_worker_compute_s
            .iter()
            .zip(factors)
            .map(|(&c, &f)| crit - c * f)
            .sum()
    }

    /// Total CPU-seconds across islands — the phase's entry in
    /// `phases.inner_compute_s` (a work counter, not wall time: under
    /// the parallel engine it exceeds elapsed time by design).
    pub fn total_wall_s(&self) -> f64 {
        // detlint: allow(float_fold, timing column only (DESIGN.md §4 rule 3): fixed island order, never feeds model state)
        self.per_worker_wall_s.iter().sum()
    }

    /// Simulated cost of this phase when a deferred transfer from the
    /// previous round's streaming sync is still in flight (Streaming
    /// DiLoCo's overlapped schedule): communication hides behind
    /// compute, so the phase costs whichever is slower. With no carry
    /// (`0.0`) this is exactly [`Self::max_compute_s`].
    pub fn overlapped_compute_s(&self, in_flight_comm_s: f64) -> f64 {
        self.max_compute_s().max(in_flight_comm_s)
    }
}

/// Run `h` inner steps on every worker through `exec`, reducing timing
/// in worker order. This is the coordinator's single entry point into
/// the engine for DiLoCo rounds and plain training alike.
pub fn run_inner_phase(
    exec: &dyn InnerPhaseExecutor,
    rt: &Runtime,
    workers: &mut [Worker],
    h: usize,
) -> anyhow::Result<InnerPhaseReport> {
    run_inner_phase_refs(exec, rt, workers.iter_mut().collect(), h)
}

/// As [`run_inner_phase`], over an arbitrary subset of a worker pool
/// selected by id. Elastic membership (churn) makes the active roster a
/// non-contiguous id set, so the engine resizes each round's island
/// phase to exactly the active workers: departed workers hold no thread,
/// burn no compute, and appear nowhere in the phase report. Outputs come
/// back in `ids` order (the determinism contract's fold order).
pub fn run_inner_phase_subset(
    exec: &dyn InnerPhaseExecutor,
    rt: &Runtime,
    workers: &mut [Worker],
    ids: &[usize],
    h: usize,
) -> anyhow::Result<InnerPhaseReport> {
    let pool = workers.len();
    let mut slots: Vec<Option<&mut Worker>> = workers.iter_mut().map(Some).collect();
    let mut picked: Vec<&mut Worker> = Vec::with_capacity(ids.len());
    for &id in ids {
        anyhow::ensure!(id < pool, "roster id {id} outside worker pool of {pool}");
        let w = slots[id]
            .take()
            .ok_or_else(|| anyhow::anyhow!("roster id {id} listed twice"))?;
        picked.push(w);
    }
    run_inner_phase_refs(exec, rt, picked, h)
}

/// Shared implementation: one island task per borrowed worker, outputs
/// reduced in the given order.
fn run_inner_phase_refs(
    exec: &dyn InnerPhaseExecutor,
    rt: &Runtime,
    workers: Vec<&mut Worker>,
    h: usize,
) -> anyhow::Result<InnerPhaseReport> {
    let tasks: Vec<IslandTask<'_>> = workers
        .into_iter()
        .map(|w| {
            Box::new(move || -> anyhow::Result<IslandOutput> {
                let before = w.compute_seconds;
                // detlint: allow(wall_clock, DESIGN.md §4 rule 3: islands time locally and the caller reduces deterministically; wall_s is a reporting column)
                let t0 = Instant::now();
                let mut losses = Vec::with_capacity(h);
                w.run_inner_steps(rt, h, &mut losses)?;
                Ok(IslandOutput {
                    losses,
                    compute_s: w.compute_seconds - before,
                    wall_s: t0.elapsed().as_secs_f64(),
                    payload: None,
                })
            }) as IslandTask<'_>
        })
        .collect();
    let outs = exec.run_islands(tasks)?;
    let mut report = InnerPhaseReport {
        per_worker_losses: Vec::with_capacity(outs.len()),
        per_worker_compute_s: Vec::with_capacity(outs.len()),
        per_worker_wall_s: Vec::with_capacity(outs.len()),
    };
    for o in outs {
        report.per_worker_losses.push(o.losses);
        report.per_worker_compute_s.push(o.compute_s);
        report.per_worker_wall_s.push(o.wall_s);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn counting_tasks(
        n: usize,
        started: &AtomicUsize,
    ) -> Vec<IslandTask<'_>> {
        (0..n)
            .map(|i| {
                Box::new(move || -> anyhow::Result<IslandOutput> {
                    started.fetch_add(1, Ordering::SeqCst);
                    Ok(IslandOutput {
                        losses: vec![i as f32],
                        compute_s: i as f64,
                        wall_s: 1.0,
                        payload: None,
                    })
                }) as IslandTask<'_>
            })
            .collect()
    }

    fn check_island_order(exec: &dyn InnerPhaseExecutor, n: usize) {
        let started = AtomicUsize::new(0);
        let outs = exec.run_islands(counting_tasks(n, &started)).unwrap();
        assert_eq!(started.load(Ordering::SeqCst), n);
        assert_eq!(outs.len(), n);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.losses, vec![i as f32], "output {i} out of island order");
        }
    }

    #[test]
    fn sequential_preserves_island_order() {
        check_island_order(&Sequential, 7);
    }

    #[test]
    fn parallel_preserves_island_order() {
        // More islands than threads → chunking must still land outputs in
        // island order; also the degenerate 1-thread and 1-task cases.
        for threads in [0, 1, 2, 3, 16] {
            let exec = ParallelIslands::new(threads);
            check_island_order(&exec, 7);
            check_island_order(&exec, 1);
        }
    }

    #[test]
    fn parallel_actually_uses_threads() {
        // Two tasks that can only finish if they overlap in time: each
        // waits for the other to start.
        use std::sync::Barrier;
        let barrier = Barrier::new(2);
        let b = &barrier;
        let tasks: Vec<IslandTask<'_>> = (0..2)
            .map(|i| {
                Box::new(move || -> anyhow::Result<IslandOutput> {
                    b.wait();
                    Ok(IslandOutput {
                        losses: vec![i as f32],
                        compute_s: 0.0,
                        wall_s: 0.0,
                        payload: None,
                    })
                }) as IslandTask<'_>
            })
            .collect();
        let outs = ParallelIslands::new(2).run_islands(tasks).unwrap();
        assert_eq!(outs.len(), 2);
    }

    #[test]
    fn first_error_in_island_order_wins() {
        fn failing_tasks() -> Vec<IslandTask<'static>> {
            (0..4)
                .map(|i| {
                    Box::new(move || -> anyhow::Result<IslandOutput> {
                        if i % 2 == 1 {
                            anyhow::bail!("island {i} failed")
                        }
                        Ok(IslandOutput {
                            losses: vec![],
                            compute_s: 0.0,
                            wall_s: 0.0,
                            payload: None,
                        })
                    }) as IslandTask<'static>
                })
                .collect()
        }
        for exec in [&ParallelIslands::new(4) as &dyn InnerPhaseExecutor, &Sequential] {
            let err = exec.run_islands(failing_tasks()).unwrap_err();
            assert!(
                err.to_string().contains("island 1"),
                "{}: wrong island won: {err}",
                exec.name()
            );
        }
    }

    #[test]
    fn report_reductions_are_max_and_sum() {
        let outs = vec![
            IslandOutput { losses: vec![1.0], compute_s: 2.0, wall_s: 3.0, payload: None },
            IslandOutput { losses: vec![2.0], compute_s: 5.0, wall_s: 4.0, payload: None },
        ];
        let mut report = InnerPhaseReport {
            per_worker_losses: Vec::new(),
            per_worker_compute_s: Vec::new(),
            per_worker_wall_s: Vec::new(),
        };
        for o in outs {
            report.per_worker_losses.push(o.losses);
            report.per_worker_compute_s.push(o.compute_s);
            report.per_worker_wall_s.push(o.wall_s);
        }
        assert_eq!(report.max_compute_s(), 5.0);
        assert_eq!(report.total_wall_s(), 7.0);
        // Overlap accounting: in-flight comm hides behind compute until
        // it exceeds the slowest island, then dominates the phase.
        assert_eq!(report.overlapped_compute_s(0.0), 5.0);
        assert_eq!(report.overlapped_compute_s(3.0), 5.0);
        assert_eq!(report.overlapped_compute_s(9.0), 9.0);
        // Per-worker times are exposed in island order for speed scaling.
        assert_eq!(report.per_worker_compute_s(), &[2.0, 5.0]);
        // Uniform factors reproduce the raw max bitwise; a straggler
        // factor moves the critical path and creates idle time.
        assert_eq!(report.critical_path_s(&[1.0, 1.0]), 5.0);
        assert_eq!(report.idle_s(&[1.0, 1.0]), 3.0); // island 0 waits 3s
        assert_eq!(report.critical_path_s(&[4.0, 1.0]), 8.0);
        assert_eq!(report.idle_s(&[4.0, 1.0]), 3.0); // island 1 waits now
        assert_eq!(report.critical_path_s(&[1.0, 2.0]), 10.0);
    }

    #[test]
    fn thread_cap_resolution() {
        assert_eq!(ParallelIslands::new(3).resolved_threads(8), 3);
        assert_eq!(ParallelIslands::new(16).resolved_threads(2), 2);
        assert!(ParallelIslands::new(0).resolved_threads(64) >= 1);
    }

    #[test]
    fn reduce_threads_follows_engine_kind() {
        assert_eq!(Sequential.reduce_threads(8), 1);
        assert_eq!(ParallelIslands::new(3).reduce_threads(8), 3);
        assert_eq!(ParallelIslands::new(3).reduce_threads(2), 2);
    }

    #[test]
    fn run_tasks_returns_outputs_in_task_order() {
        for threads in [0usize, 1, 2, 3, 7] {
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..20)
                .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
                .collect();
            let outs = run_tasks(threads, tasks);
            assert_eq!(outs, (0..20).map(|i| i * i).collect::<Vec<_>>());
        }
        let empty: Vec<Box<dyn FnOnce() -> usize + Send>> = Vec::new();
        assert!(run_tasks(4, empty).is_empty());
    }

    #[test]
    fn run_tasks_self_balances_imbalanced_durations() {
        // One long task plus many short ones: the pool must finish them
        // all and keep task order regardless of which worker ran what.
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..16)
            .map(|i| {
                Box::new(move || {
                    if i == 0 {
                        std::thread::sleep(std::time::Duration::from_millis(20));
                    }
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let outs = run_tasks(4, tasks);
        assert_eq!(outs, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn pool_handles_many_more_islands_than_threads() {
        // k=256 islands on a 3-thread pool: the old thread-per-chunk
        // engine spawned 3 threads here too, but the pool must also keep
        // island order at this scale with tasks claimed one at a time.
        let exec = ParallelIslands::new(3);
        check_island_order(&exec, 256);
    }

    #[test]
    fn run_tasks_with_fewer_tasks_than_threads() {
        // threads.min(n) caps the spawn count: 3 tasks on a "64-thread"
        // pool must run each task exactly once and keep task order.
        let ran = AtomicUsize::new(0);
        let ran_ref = &ran;
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..3)
            .map(|i| {
                Box::new(move || {
                    ran_ref.fetch_add(1, Ordering::SeqCst);
                    i + 100
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        assert_eq!(run_tasks(64, tasks), vec![100, 101, 102]);
        assert_eq!(ran.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn run_tasks_zero_and_one_thread_run_inline() {
        // threads == 0 and threads == 1 both take the inline sequential
        // path: tasks run on the calling thread, in task order.
        for threads in [0usize, 1] {
            let caller = std::thread::current().id();
            let tasks: Vec<Box<dyn FnOnce() -> std::thread::ThreadId + Send>> = (0..4)
                .map(|_| {
                    Box::new(std::thread::current)
                        as Box<dyn FnOnce() -> std::thread::ThreadId + Send>
                })
                .collect();
            let ids = run_tasks(threads, tasks);
            assert!(ids.iter().all(|id| *id == caller), "threads={threads} left the caller");
        }
    }

    #[test]
    fn run_tasks_panicking_task_propagates_and_pool_survives() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        // Defined poisoned-slot behavior: the panic unwinds out of
        // run_tasks (via thread::scope's join on the pooled path,
        // directly on the inline path); output slots are never read, so
        // a partial result can never be observed.
        for threads in [1usize, 4] {
            let survivors = AtomicUsize::new(0);
            let survivors_ref = &survivors;
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..6)
                .map(|i| {
                    Box::new(move || {
                        if i == 3 {
                            panic!("task 3 exploded");
                        }
                        survivors_ref.fetch_add(1, Ordering::SeqCst);
                        i
                    }) as Box<dyn FnOnce() -> usize + Send>
                })
                .collect();
            let result = catch_unwind(AssertUnwindSafe(|| run_tasks(threads, tasks)));
            assert!(result.is_err(), "threads={threads}: panic must propagate");
            // The pool is usable again afterwards — no global poisoning.
            let again: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..4)
                .map(|i| Box::new(move || i * 2) as Box<dyn FnOnce() -> usize + Send>)
                .collect();
            assert_eq!(run_tasks(threads, again), vec![0, 2, 4, 6]);
        }
    }

    #[test]
    fn run_tasks_inline_panic_carries_payload() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        // On the inline path the original panic payload is preserved
        // verbatim (no thread-join indirection).
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> =
            vec![Box::new(|| panic!("inline boom"))];
        let err = catch_unwind(AssertUnwindSafe(|| run_tasks(1, tasks))).unwrap_err();
        let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("inline boom"), "payload was {msg:?}");
    }
}
