//! The DiLoCo coordinator — Algorithm 1 of the paper, plus every ablation
//! knob the evaluation section exercises.
//!
//! One [`Coordinator`] owns the run: it synthesizes + shards data, warm
//! starts from `pretrain_steps` of plain training (paper Fig 3), then
//! executes T outer rounds. Each round: the schedule (Fig 7) picks the
//! active workers; each active worker runs H inner AdamW steps through the
//! AOT artifacts — dispatched through the configured [`crate::engine`]
//! executor, so islands run on real OS threads under `ParallelIslands`
//! with bitwise-identical results to the sequential reference path (see
//! DESIGN.md §determinism); outer gradients are optionally sign-pruned (Table 6),
//! split into the streaming fabric's fragments ([`crate::comm::fragment`]),
//! encoded by the configured codec ([`crate::comm::codec`]), shipped over
//! the simulated fabric with per-fragment drop injection (Fig 8),
//! weighted-averaged per fragment (§6.1), and applied through the outer
//! optimizer's per-fragment state (Fig 6). Fresh fragment values are
//! re-dispatched to every worker that landed them; a fragment whose
//! upload dropped keeps training from the worker's own parameters,
//! exactly as the paper specifies — with one fragment (the default) this
//! is classic DiLoCo, bitwise identical to the pre-streaming loop
//! (DESIGN.md §8 documents the streaming layer and its schedules).
//!
//! The *shape* of the reduction is itself pluggable
//! ([`crate::comm::topology`], DESIGN.md §9): the default star keeps the
//! single global replica above; the hierarchical topology keeps the same
//! math but routes it through group leaders so only `G` flows cross the
//! billed WAN; the decentralized topologies (ring, gossip) keep one
//! model + outer-optimizer state per worker and run a mixing-matrix
//! round loop instead, reporting per-replica and consensus perplexity
//! plus a consensus-distance metric.
//!
//! The *timing* of the reduction is pluggable too (the async scheduling
//! layer, DESIGN.md §11): a `[speed]` model makes islands
//! speed-heterogeneous — the simulated cost of a round becomes the
//! straggler's critical path, and the fast islands' barrier wait is
//! reported as idle time — while `sync.delay_rounds = D` applies each
//! round's outer contribution `D` rounds late (DiLoCoX-style delayed
//! merging), letting its transfer hide behind the next inner phase and
//! discounting stale contributions by `γ^staleness`. `D = 0` with
//! homogeneous speeds is the synchronous loop, bitwise.

pub mod adversary;
pub mod aggregate;
pub mod average;
pub mod baselines;
pub mod opt;
pub mod prune;
pub mod scratch;
pub mod stats;

use crate::checkpoint::{self, PendingFragment, PendingSync, TrainState, WorkerState};
use crate::comm::codec::Codec;
use crate::comm::fragment::FragmentPlan;
use crate::comm::{
    topology, wire, Direction, Fabric, RoundComm, SimNet, TcpFabric, TcpFabricSetup,
};
use crate::config::{ExperimentConfig, FabricKind, TopologyConfig};
use crate::data::batch::{BatchIter, EvalSet};
use crate::data::Dataset;
use crate::engine::{self, InnerPhaseExecutor};
use crate::metrics::{EvalPoint, RunMetrics, Stopwatch};
use crate::runtime::{Runtime, Tensors};
use crate::util::math;
use crate::worker::Worker;
use std::sync::Arc;

pub use stats::RoundStats;

/// Everything a finished run reports.
pub struct DilocoReport {
    pub metrics: RunMetrics,
    pub round_stats: Vec<RoundStats>,
    /// The global model under centralized topologies (star,
    /// hierarchical); the uniform consensus of the replicas under
    /// decentralized topologies (ring, gossip).
    pub final_params: Tensors,
    /// Rounds in which at least one of each worker's fragment uploads
    /// was dropped (with one fragment: rounds the outer gradient
    /// dropped, as before). Under the hierarchical topology a dropped
    /// leader hop counts against every member of the group.
    pub drops_per_worker: Vec<usize>,
    /// Fabric billing per round, in round order (golden-trace input).
    pub comm_per_round: Vec<RoundComm>,
    /// Final per-replica models (decentralized topologies only; empty
    /// for star/hierarchical, whose single replica is `final_params`).
    pub replica_params: Vec<Tensors>,
    /// Final per-replica evaluations, in replica order (decentralized
    /// topologies only) — the consensus eval is the last point of
    /// `metrics.eval_curve`.
    pub replica_evals: Vec<EvalPoint>,
}

pub struct Coordinator {
    pub cfg: ExperimentConfig,
    rt: Arc<Runtime>,
    pub dataset: Dataset,
    evalset: EvalSet,
    /// Inner-phase executor (built once from `cfg.engine` against the
    /// run's peak worker count).
    exec: Box<dyn InnerPhaseExecutor>,
}

impl Coordinator {
    /// Build the data pipeline for `cfg` against an already-loaded runtime
    /// (runtimes are reused across bench variants — compilation is paid
    /// once per artifact set).
    pub fn new(cfg: ExperimentConfig, rt: Arc<Runtime>) -> anyhow::Result<Coordinator> {
        cfg.validate()?;
        let mcfg = &rt.manifest.config;
        anyhow::ensure!(
            mcfg.name == cfg.model,
            "runtime holds {:?}, config wants {:?}",
            mcfg.name,
            cfg.model
        );
        let max_k = cfg.pool_size();
        let dataset = Dataset::build(&cfg.data, max_k, mcfg.vocab_size, cfg.seed)?;
        let evalset = EvalSet::new(
            &dataset.holdout,
            mcfg.batch_size,
            mcfg.seq_len,
            cfg.eval_batches,
        );
        let exec = cfg.engine.build(max_k);
        Ok(Coordinator { cfg, rt, dataset, evalset, exec })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// The executor island phases dispatch through.
    pub fn engine(&self) -> &dyn InnerPhaseExecutor {
        self.exec.as_ref()
    }

    /// Mean nll / PPL of `params` on the fixed validation windows.
    pub fn evaluate(&self, params: &Tensors) -> anyhow::Result<EvalPoint> {
        let mut sum = 0.0;
        let mut count = 0.0;
        for b in self.evalset.batches() {
            let (s, c) = self.rt.eval_batch(params, &b.tokens, &b.targets)?;
            sum += s;
            count += c;
        }
        let mean_nll = sum / count;
        Ok(EvalPoint { step: 0, mean_nll, ppl: math::ppl(mean_nll) })
    }

    /// Merged token stream over all shards (pretraining / plain baselines
    /// train on the full dataset, like the paper's single-worker runs).
    pub fn merged_stream(&self) -> Vec<i32> {
        let mut s = Vec::new();
        for shard in &self.dataset.shards {
            s.extend_from_slice(shard);
        }
        s
    }

    /// Plain (non-DiLoCo) training for `steps` steps from `init`.
    /// Returns final params; logs losses/evals into `metrics`.
    pub fn plain_train(
        &self,
        init: Tensors,
        start_step: f64,
        steps: usize,
        metrics: &mut RunMetrics,
        eval_every: usize,
    ) -> anyhow::Result<Tensors> {
        let mcfg = &self.rt.manifest.config;
        let mut worker = Worker::new(
            usize::MAX,
            init,
            Tensors::zeros(&self.rt.manifest),
            BatchIter::new(
                self.merged_stream(),
                mcfg.batch_size,
                mcfg.seq_len,
                self.cfg.rng().child(999),
            ),
        );
        worker.step = start_step;
        let mut done = 0usize;
        while done < steps {
            let h = (steps - done).min(self.cfg.inner_steps.max(1));
            let phase = engine::run_inner_phase(
                self.exec.as_ref(),
                &self.rt,
                std::slice::from_mut(&mut worker),
                h,
            )?;
            metrics.phases.inner_compute_s += phase.total_wall_s();
            metrics
                .loss_curve
                .extend_from_slice(&phase.per_worker_losses[0]);
            done += h;
            let at_boundary = eval_every > 0
                && (done / self.cfg.inner_steps.max(1))
                    % eval_every == 0;
            if at_boundary || done >= steps {
                let _t = Stopwatch::new(&mut metrics.phases.eval_s);
                let mut p = self.evaluate(&worker.params)?;
                p.step = start_step as usize + done;
                metrics.eval_curve.push(p);
            }
        }
        metrics.sim_compute_seconds += worker.compute_seconds;
        Ok(worker.params)
    }

    /// Full DiLoCo run: pretrain warm start, then T rounds of Algorithm 1.
    /// With `cfg.ckpt.resume` set, the run instead restores the full
    /// [`TrainState`] from disk and continues from its round — bitwise
    /// identical to never having stopped (DESIGN.md §10).
    pub fn run(&self) -> anyhow::Result<DilocoReport> {
        match self.cfg.ckpt.resume.clone() {
            Some(path) => self.resume_from_path(&path),
            None => self.run_from(None),
        }
    }

    /// Resume a run from a [`TrainState`] checkpoint written by a
    /// previous run of the *same* configuration (same seed, model, data,
    /// schedule, churn, stream, and topology settings — only `rounds`
    /// may grow). The pretrain phase is skipped: the state already
    /// embeds it.
    pub fn resume_from_path(&self, path: &str) -> anyhow::Result<DilocoReport> {
        let st = checkpoint::load_state(path, &self.rt.manifest)?;
        self.resume_from_state(st)
    }

    /// As [`Coordinator::resume_from_path`], from an in-memory state.
    pub fn resume_from_state(&self, st: TrainState) -> anyhow::Result<DilocoReport> {
        let cfg = &self.cfg;
        anyhow::ensure!(
            st.decentralized == cfg.topology.is_decentralized(),
            "checkpoint was written by a {} topology, config wants {} ({})",
            if st.decentralized { "decentralized" } else { "centralized" },
            if cfg.topology.is_decentralized() { "decentralized" } else { "centralized" },
            cfg.topology.name()
        );
        anyhow::ensure!(
            st.round <= cfg.rounds,
            "checkpoint is at round {} but the run has only {} rounds",
            st.round,
            cfg.rounds
        );
        // The churn ramp derives historical rosters from the *total*
        // round count, so growing `rounds` would silently re-derive a
        // different membership history and corrupt parked state.
        if let Some(churn) = &cfg.churn {
            anyhow::ensure!(
                churn.ramp.is_none() || cfg.rounds == st.total_rounds,
                "a churn ramp derives rosters from the total round count: \
                 the checkpoint was written by a {}-round run, config wants {}",
                st.total_rounds,
                cfg.rounds
            );
        }
        // The full id-indexed state must cover the pool consistently —
        // load_state guarantees this for on-disk states, but this entry
        // point also accepts hand-built in-memory states.
        let pool = cfg.pool_size();
        anyhow::ensure!(
            st.workers.len() == pool
                && st.refs.len() == pool
                && st.pending_adopt.len() == pool
                && st.drops_per_worker.len() == pool,
            "checkpoint worker pool is {} (refs {}, pending {}, drops {}), \
             config wants {pool}",
            st.workers.len(),
            st.refs.len(),
            st.pending_adopt.len(),
            st.drops_per_worker.len()
        );
        anyhow::ensure!(
            st.replicas.len() == if st.decentralized { pool } else { 0 },
            "checkpoint stores {} replicas for a pool of {pool}",
            st.replicas.len()
        );
        anyhow::ensure!(
            st.outer.len() == if st.decentralized { pool } else { 1 },
            "checkpoint stores {} outer optimizers for a pool of {pool}",
            st.outer.len()
        );
        anyhow::ensure!(
            !st.decentralized || st.pending_sync.is_empty(),
            "a decentralized checkpoint cannot carry {} delayed contribution \
             batches (delay composes with centralized topologies only)",
            st.pending_sync.len()
        );
        anyhow::ensure!(
            st.pending_sync.iter().all(|b| b.round < st.round),
            "checkpoint at round {} holds a pending batch from a later round",
            st.round
        );
        let metrics = RunMetrics::new(&format!(
            "diloco_k{}_h{}_{}",
            cfg.workers,
            cfg.inner_steps,
            cfg.outer_opt.name()
        ));
        let global = st.global.clone();
        if cfg.topology.is_decentralized() {
            self.run_decentralized(global, metrics, Some(st))
        } else {
            self.run_centralized(global, metrics, Some(st))
        }
    }

    /// As [`Coordinator::run`], but optionally starting from
    /// caller-provided parameters.
    /// A provided `init` is treated as *already pretrained* for
    /// `cfg.pretrain_steps` steps (shared warm start across bench
    /// variants): the pretrain phase is skipped but the workers' global
    /// step counter — and hence the baked inner-lr schedule — resumes
    /// from `pretrain_steps`.
    pub fn run_from(&self, init: Option<Tensors>) -> anyhow::Result<DilocoReport> {
        let cfg = &self.cfg;
        let mut metrics = RunMetrics::new(&format!(
            "diloco_k{}_h{}_{}",
            cfg.workers,
            cfg.inner_steps,
            cfg.outer_opt.name()
        ));

        // θ(0): explicit init (already pretrained) or fresh init followed
        // by the pretraining phase.
        let global = match init {
            Some(p) => p,
            None => {
                let fresh = self.rt.init_params()?;
                if cfg.pretrain_steps > 0 {
                    self.plain_train(
                        fresh,
                        0.0,
                        cfg.pretrain_steps,
                        &mut metrics,
                        cfg.eval_every_rounds,
                    )?
                } else {
                    fresh
                }
            }
        };
        // Decentralized topologies (ring, gossip) keep one replica per
        // worker and mix peer-to-peer — a structurally different round
        // loop. Star and hierarchical continue in `run_centralized` with
        // the single global replica (the star path is the PR-2 loop,
        // bitwise).
        if cfg.topology.is_decentralized() {
            self.run_decentralized(global, metrics, None)
        } else {
            self.run_centralized(global, metrics, None)
        }
    }

    /// Restore the worker pool's inner state (params, AdamW moments,
    /// step counters, batch-stream RNG cursors) from a checkpoint.
    fn restore_pool(workers: &mut [Worker], saved: &[WorkerState]) {
        debug_assert_eq!(workers.len(), saved.len());
        for (w, ws) in workers.iter_mut().zip(saved) {
            w.params = ws.params.clone();
            w.opt_m = ws.opt_m.clone();
            w.opt_v = ws.opt_v.clone();
            w.step = ws.step;
            w.iter.set_rng_state(ws.rng);
        }
    }

    /// Snapshot the worker pool's inner state for a [`TrainState`] save.
    fn snapshot_pool(workers: &[Worker]) -> Vec<WorkerState> {
        workers
            .iter()
            .map(|w| WorkerState {
                params: w.params.clone(),
                opt_m: w.opt_m.clone(),
                opt_v: w.opt_v.clone(),
                step: w.step,
                rng: w.iter.rng_state(),
            })
            .collect()
    }

    /// Whether round `t`'s boundary is a periodic-save point.
    fn save_due(&self, t: usize) -> bool {
        self.cfg.ckpt.save_every > 0 && (t + 1) % self.cfg.ckpt.save_every == 0
    }

    /// Write the periodic [`TrainState`] for round boundary `t + 1` —
    /// the shared tail of both round loops (DESIGN.md §10). Callers gate
    /// on [`Coordinator::save_due`] so optimizer snapshots are only
    /// taken when a save actually happens. The state is cloned into an
    /// owned record before serializing — one transient extra copy of the
    /// training state per save, acceptable at current model scales; a
    /// borrow-based writer is the upgrade path if checkpointing ever
    /// dominates memory at production scale.
    #[allow(clippy::too_many_arguments)]
    fn save_state_now(
        &self,
        t: usize,
        decentralized: bool,
        global: &Tensors,
        replicas: &[Tensors],
        outer: Vec<opt::OuterOptSnapshot>,
        workers: &[Worker],
        refs: &[Tensors],
        pending_adopt: &[Vec<bool>],
        drops_per_worker: &[usize],
        carry_comm_s: f64,
        codec_err_sq_total: f64,
        pending_sync: &[PendingSync],
        residuals: &[Tensors],
        stale: Vec<(usize, Tensors)>,
    ) -> anyhow::Result<()> {
        let path = self
            .cfg
            .ckpt
            .path
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("ckpt.save_every without ckpt.path"))?;
        let st = TrainState {
            round: t + 1,
            total_rounds: self.cfg.rounds,
            decentralized,
            global: global.clone(),
            replicas: replicas.to_vec(),
            outer,
            workers: Self::snapshot_pool(workers),
            refs: refs.to_vec(),
            pending_adopt: pending_adopt.to_vec(),
            drops_per_worker: drops_per_worker.to_vec(),
            carry_comm_s,
            codec_err_sq_total,
            pending_sync: pending_sync.to_vec(),
            residuals: residuals.to_vec(),
            stale,
        };
        checkpoint::save_state(path, &self.rt.manifest, &st)
    }

    /// Build the round loop's communication fabric (DESIGN.md §14).
    /// `sim` — the default — is the billing/drop oracle the golden
    /// traces pin; `tcp` wraps the *same* seeded [`SimNet`] (so byte
    /// bills and drop keys stay bitwise-identical) around real worker
    /// OS processes that run the inner phases over sockets.
    fn build_fabric(&self) -> anyhow::Result<Box<dyn Fabric>> {
        let cfg = &self.cfg;
        let sim = SimNet::new(
            cfg.comm.bandwidth_bps,
            cfg.comm.latency_s,
            cfg.comm.drop_prob,
            cfg.rng().child(7),
        );
        match cfg.fabric.kind {
            FabricKind::Sim => Ok(Box::new(sim)),
            FabricKind::Tcp => {
                let max_k = cfg.pool_size();
                let mcfg = &self.rt.manifest.config;
                let shards: Vec<Vec<i32>> = (0..max_k)
                    .map(|i| self.dataset.shards[i % self.dataset.shards.len()].clone())
                    .collect();
                let setup = TcpFabricSetup {
                    sim,
                    pool: max_k,
                    host: cfg.fabric.host.clone(),
                    port: cfg.fabric.port,
                    // Rendezvous credential: both ends must agree on the
                    // run before a socket gets a worker slot.
                    run_id: format!("{}-s{}", cfg.model, cfg.seed),
                    spawn: cfg.fabric.spawn,
                    worker_bin: cfg.fabric.worker_bin.clone(),
                    spawn_extra: cfg.fabric.spawn_extra.clone(),
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    model: cfg.model.clone(),
                    shards,
                    batch_size: mcfg.batch_size,
                    seq_len: mcfg.seq_len,
                    leaf_sizes: self
                        .rt
                        .manifest
                        .params
                        .iter()
                        .map(|s| s.elements())
                        .collect(),
                    connect_timeout_s: cfg.fabric.connect_timeout_s,
                    phase_timeout_s: cfg.fabric.phase_timeout_s,
                    heartbeat_timeout_s: cfg.fabric.heartbeat_timeout_s,
                };
                Ok(Box::new(TcpFabric::new(setup)?))
            }
        }
    }

    /// Which pool workers were ever active before `round` — a pure
    /// function of the config, so a resumed run re-derives it instead of
    /// checkpointing roster history. Fresh joiners (never active) adopt
    /// the current global/consensus model and the run's global step
    /// counter at their first active round; rejoining leavers restore
    /// their parked state instead.
    fn ever_active_before(&self, round: usize, pool: usize) -> Vec<bool> {
        let mut ever = vec![false; pool];
        for t in 0..round {
            for id in self.cfg.active_ids(t) {
                ever[id] = true;
            }
        }
        ever
    }

    /// Centralized round loop (star, hierarchical topologies): one
    /// global model, workers upload outer gradients, the coordinator
    /// averages and steps. `resume` continues a checkpointed run from
    /// its saved round.
    fn run_centralized(
        &self,
        global: Tensors,
        mut metrics: RunMetrics,
        resume: Option<TrainState>,
    ) -> anyhow::Result<DilocoReport> {
        let cfg = &self.cfg;
        let mcfg = &self.rt.manifest.config;
        let rng = cfg.rng();
        let mut global = global;
        // Hierarchical topology: contiguous worker groups whose leaders
        // carry the only billed WAN hops (None = star default).
        let hier_cfg = match cfg.topology {
            TopologyConfig::Hierarchical { groups } => Some(groups),
            _ => None,
        };

        // Worker pool sized to the run's peak roster (schedule and churn).
        let max_k = cfg.pool_size();
        let zeros = Tensors::zeros(&self.rt.manifest);
        let mut workers: Vec<Worker> = (0..max_k)
            .map(|i| {
                let shard = self.dataset.shards[i % self.dataset.shards.len()].clone();
                let mut w = Worker::new(
                    i,
                    global.clone(),
                    zeros.clone(),
                    BatchIter::new(
                        shard,
                        mcfg.batch_size,
                        mcfg.seq_len,
                        rng.child(100 + i as u64),
                    ),
                );
                w.step = cfg.pretrain_steps as f64;
                w
            })
            .collect();
        // Streaming partial-sync plan: the parameter space split into P
        // fragments (P = 1 ⇒ the monolithic pre-streaming hot path,
        // bitwise identical — the golden-trace suite pins it).
        let plan = FragmentPlan::for_tensors(&zeros, cfg.stream.fragments);
        let n_frag = plan.n_fragments();
        let codec = cfg.stream.codec;
        // refs[w] — the last global values worker w adopted, per
        // fragment: the baseline its outer gradient is measured against.
        let mut refs: Vec<Tensors> = (0..max_k).map(|_| global.clone()).collect();
        // Per-worker error-feedback residuals (MuLoCo, arXiv:2505.23725):
        // what the last compressed upload failed to carry, replayed into
        // the next outer delta. Empty when the knob is off, so the
        // default path allocates (and touches) nothing.
        let ef = cfg.stream.error_feedback;
        let mut residuals: Vec<Tensors> =
            if ef { (0..max_k).map(|_| zeros.clone()).collect() } else { Vec::new() };
        // pending_adopt[w][f] — worker w re-adopts the current global
        // fragment f at its next active round (all true initially: every
        // worker starts synced, exactly as the monolithic loop did).
        let mut pending_adopt: Vec<Vec<bool>> = vec![vec![true; n_frag]; max_k];
        let mut drops_per_worker = vec![0usize; max_k];
        // Transfer time deferred into the next inner phase (overlapped
        // schedule, and every non-final round of a delayed run); 0.0
        // under synchronous barrier schedules.
        let mut carry_comm_s = 0.0f64;
        let mut codec_err_sq_total = 0.0f64;
        let mut outer = opt::OuterOpt::new(&cfg.outer_opt, &zeros);
        // Reusable round-local buffers (extracted payloads, fragment
        // averages, weight tables, discount-scaled copies): after the
        // first round every lease is a recycled buffer, so the steady
        // state of the round loop performs no heap allocation for them.
        let mut scratch = scratch::RoundScratch::new();
        // Delayed contribution queue (DESIGN.md §11), oldest batch
        // first: round t's batch is folded into the global model at the
        // end of round t + D. With D = 0 a batch is applied in the round
        // that produced it — the synchronous legacy loop, bitwise.
        let delay = cfg.sync.delay_rounds;
        let mut pending: Vec<PendingSync> = Vec::new();
        // Outer aggregation estimator (`[aggregate]`, DESIGN.md §16):
        // the weighted mean by default — bitwise the legacy reduction —
        // or a Byzantine-robust estimator. Robust estimators reduce
        // serially (they lease per-coordinate columns from the arena),
        // so the parallel fragment fan-out below stays gated on
        // `agg.is_mean()`.
        let agg = aggregate::build(&cfg.aggregate);
        // Byzantine attacker model (`[adversary]`, DESIGN.md §16):
        // corrupts compromised workers' outer deltas after the inner
        // phase and before pruning/codec/billing, so byte bills are
        // invariant under attack.
        let mut adv: Option<adversary::Adversary> = cfg
            .adversary
            .as_ref()
            .map(|a| adversary::Adversary::new(a, cfg.seed, max_k));
        let mut start_round = 0usize;

        // Resume: overwrite every piece of mutable loop state with the
        // checkpointed record. Everything else that shapes the trace —
        // dataset, fragment plan, drop keys, eval windows — is a pure
        // function of the config, so nothing more is needed for the
        // continuation to be bitwise.
        if let Some(st) = resume {
            anyhow::ensure!(
                st.pending_adopt.iter().all(|p| p.len() == n_frag),
                "checkpoint has {} fragments, config wants {n_frag}",
                st.pending_adopt.first().map_or(0, |p| p.len())
            );
            for b in &st.pending_sync {
                for fr in &b.frags {
                    anyhow::ensure!(
                        fr.fragment < n_frag
                            && fr.avg.len() == plan.elements(fr.fragment),
                        "pending batch from round {} carries fragment {} with {} \
                         elements; the run's plan wants {} of {n_frag} fragments",
                        b.round,
                        fr.fragment,
                        fr.avg.len(),
                        plan.elements(fr.fragment.min(n_frag - 1)),
                    );
                }
            }
            start_round = st.round;
            Self::restore_pool(&mut workers, &st.workers);
            refs = st.refs;
            pending_adopt = st.pending_adopt;
            drops_per_worker = st.drops_per_worker;
            carry_comm_s = st.carry_comm_s;
            codec_err_sq_total = st.codec_err_sq_total;
            pending = st.pending_sync;
            // Pre-v3 checkpoints (and runs saved with error feedback
            // off) carry no residuals — resume with zeros.
            if ef && !st.residuals.is_empty() {
                residuals = st.residuals;
            }
            // Pre-v4 checkpoints (and non-stale-replay runs) park no
            // stale deltas — a resumed stale-replay attacker then ships
            // one honest delta first, exactly like round 0.
            if let Some(a) = adv.as_mut() {
                a.restore_stale(st.stale);
            }
            let snap = st
                .outer
                .into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("checkpoint has no outer optimizer state"))?;
            outer = opt::OuterOpt::restore(&cfg.outer_opt, &zeros, snap, n_frag)?;
        }
        // Elastic membership: who has ever been active (fresh joiners
        // warm-start; rejoining leavers restore parked state).
        let mut ever_active = self.ever_active_before(start_round, max_k);

        let mut net = self.build_fabric()?;
        let mut round_stats = Vec::with_capacity(cfg.rounds);
        let payload = self.rt.manifest.param_bytes() as u64;

        for t in start_round..cfg.rounds {
            // The round's active roster: churn events when configured,
            // else the schedule's prefix 0..k_t (pre-churn loop,
            // bitwise). The fabric gets a veto: a TCP peer that stopped
            // answering heartbeats leaves the roster as `[churn]` (the
            // sim fabric passes the roster through untouched).
            let roster = net.filter_roster(t, cfg.active_ids(t))?;
            let k_t = roster.len();
            // Per-island compute-speed factors (all exactly 1.0 under
            // the uniform model) and the round's active-id mask for
            // apply-time download billing.
            let factors = cfg.speed_factors(&roster, t);
            let mut active = vec![false; max_k];
            for &id in &roster {
                active[id] = true;
            }
            let due = cfg.stream.schedule.fragments_due(t, n_frag);
            let hier_groups: Option<Vec<Vec<usize>>> =
                hier_cfg.map(|g| topology::hier_groups(k_t, g));

            // Fresh joiners adopt the current global model and the run's
            // global step counter at their first active round (a no-op at
            // round 0, where the pool is initialized exactly like this).
            if cfg.churn.is_some() {
                for &id in &roster {
                    if !ever_active[id] {
                        for flag in pending_adopt[id].iter_mut() {
                            *flag = true;
                        }
                        workers[id].step =
                            (cfg.pretrain_steps + t * cfg.inner_steps) as f64;
                    }
                    ever_active[id] = true;
                }
            }

            // Re-dispatch: every fragment whose sync the worker completed
            // adopts the current global values; other fragments keep the
            // worker's local progress (Fig 8 desync, between-sync drift
            // under the staggered schedule, and a departed worker's
            // parked desync across its absence).
            for &wid in &roster {
                let w = &mut workers[wid];
                let pa = &mut pending_adopt[wid];
                for (f, flag) in pa.iter_mut().enumerate() {
                    if *flag {
                        plan.copy_fragment(&global, &mut w.params, f);
                        plan.copy_fragment(&global, &mut refs[wid], f);
                        *flag = false;
                    }
                }
            }

            // Inner phase: H steps per active worker, dispatched through
            // the engine (real threads under ParallelIslands) and resized
            // to the round's roster — departed workers hold no thread.
            // Losses are averaged across workers per roster index,
            // folding in roster order regardless of which island finished
            // first. A deferred transfer from the previous round overlaps
            // this phase. The round's simulated cost is its *critical
            // path*: the slowest island's speed-scaled compute (bitwise
            // the raw max under the uniform speed model).
            let (phase, vanished) =
                match net.run_phase(&mut workers, &roster, cfg.inner_steps)? {
                    // Remote fabric: the phase ran on worker processes;
                    // `vanished` flags peers that died mid-phase.
                    Some(out) => (out.report, out.vanished),
                    // Local fabric: the engine runs the islands here —
                    // the golden path, nobody vanishes.
                    None => (
                        engine::run_inner_phase_subset(
                            self.exec.as_ref(),
                            &self.rt,
                            &mut workers,
                            &roster,
                            cfg.inner_steps,
                        )?,
                        vec![false; k_t],
                    ),
                };
            let crit = phase.critical_path_s(&factors);
            metrics.sim_compute_seconds += crit.max(carry_comm_s);
            carry_comm_s = 0.0;
            let idle = phase.idle_s(&factors);
            metrics.sim_idle_seconds += idle;
            metrics.phases.inner_compute_s += phase.total_wall_s();
            // Fold losses over the workers that finished the phase. With
            // none vanished the filter keeps every row in roster order —
            // the identical addition sequence, bitwise.
            let live = vanished.iter().filter(|&&v| !v).count().max(1);
            for s in 0..cfg.inner_steps {
                // detlint: allow(float_fold, roster-order f32 fold pinned bitwise by the golden trace; rewriting through math:: would widen to f64 and break it)
                let avg = phase
                    .per_worker_losses
                    .iter()
                    .zip(&vanished)
                    .filter(|&(_, &v)| !v)
                    .map(|(l, _)| l[s])
                    .sum::<f32>()
                    / live as f32;
                metrics.loss_curve.push(avg);
            }

            // Communication phase: prune, encode + upload each due
            // fragment (per-fragment keyed drops), average per fragment.
            let _outer_timer = Stopwatch::new(&mut metrics.phases.outer_opt_s);
            if k_t > 1 {
                metrics.comm_bytes_up_baseline += k_t as u64 * payload;
            }
            // Per due fragment: received payloads + weights, roster order.
            let mut frag_rx: Vec<Vec<Vec<f32>>> = vec![Vec::new(); due.len()];
            let mut frag_wts: Vec<Vec<f64>> = vec![Vec::new(); due.len()];
            // sent[i][di] — roster position i landed fragment di this round.
            let mut sent = vec![vec![false; due.len()]; k_t];
            // Full (fragment-assembled) deltas of contributing workers,
            // for the round's cosine/norm statistics.
            let mut received_assembled: Vec<Tensors> = Vec::new();
            let mut codec_err_sq = 0.0f64;
            // Pass 1 — payload computation, roster order: outer
            // gradient, (optional) error-feedback replay, sign-pruning,
            // transcode to wire values, and the *exact* billed size of
            // every due fragment. Aggregated hops (the hierarchical
            // leader, billed in pass 2) need every member's support
            // before any byte crosses the fabric, which is why billing
            // is no longer interleaved with payload computation. The
            // reorder is trace-invariant: drop decisions are a pure
            // function of (fabric seed, round, worker, fragment, hop),
            // and lane billing is additive within a round.
            let pruned = cfg.prune_frac > 0.0;
            let mut weights_v: Vec<f64> = Vec::with_capacity(k_t);
            let mut deltas: Vec<Tensors> = Vec::with_capacity(k_t);
            // Per (roster position, due fragment): wire values, codec
            // error, billed bytes, sparse support, EF intended values.
            let mut up_vals: Vec<Vec<Vec<f32>>> = Vec::with_capacity(k_t);
            let mut up_errs: Vec<Vec<f64>> = Vec::with_capacity(k_t);
            let mut up_bytes: Vec<Vec<u64>> = Vec::with_capacity(k_t);
            let mut up_support: Vec<Vec<Option<wire::Support>>> =
                Vec::with_capacity(k_t);
            let mut up_intended: Vec<Vec<Vec<f32>>> = Vec::with_capacity(k_t);
            for &wid in &roster {
                let w = &workers[wid];
                let mut delta = refs[wid].delta(&w.params);
                // Byzantine corruption happens exactly here: after the
                // honest inner phase produced the outer delta, before
                // error-feedback replay, pruning, the codec, and any
                // billing — a corrupted round ships the same bytes.
                if let Some(a) = adv.as_mut() {
                    a.corrupt(t, wid, &mut delta);
                }
                if ef {
                    // Error feedback (MuLoCo): replay what the last
                    // compressed upload of each due fragment failed to
                    // carry, so compression error accumulates into
                    // later rounds instead of being silently dropped.
                    for &f in &due {
                        plan.add_fragment(&residuals[wid], &mut delta, f);
                    }
                }
                // The values the worker *intends* to ship, recorded
                // before prune + codec so the residual can be measured
                // against them once the wire values are known.
                let intended: Vec<Vec<f32>> = if ef {
                    due.iter()
                        .map(|&f| {
                            let mut v = scratch.lease();
                            plan.extract_into(&delta, f, &mut v);
                            v
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                // Sign-pruning (Table 6) applies to the whole outer
                // gradient before fragmenting; each fragment then ships
                // as a sparse (bitmap + non-zeros) payload billed at
                // its own exact density — the proportional estimate
                // (and the dense-only validate() rejections it forced)
                // are gone.
                if pruned {
                    prune::prune_sign(&mut delta, cfg.prune_frac);
                }
                weights_v.push(if cfg.weighted_average && cfg.data.non_iid {
                    self.dataset.shard_doc_counts
                        [wid % self.dataset.shard_doc_counts.len()]
                        as f64
                } else {
                    1.0
                });
                let mut vals_f = Vec::with_capacity(due.len());
                let mut errs_f = Vec::with_capacity(due.len());
                let mut bytes_f = Vec::with_capacity(due.len());
                let mut sup_f = Vec::with_capacity(due.len());
                for &f in &due {
                    let mut vals = scratch.lease();
                    // k=1 "accelerating a single worker" (Fig 9): the
                    // outer step is local, nothing crosses the fabric —
                    // no codec, no billing, no drops.
                    if k_t == 1 {
                        plan.extract_into(&delta, f, &mut vals);
                        errs_f.push(0.0);
                        bytes_f.push(0);
                        sup_f.push(None);
                    } else if pruned {
                        // Sparse wire format: the support (which
                        // positions ship) is fixed by pruning *before*
                        // quantization; the codec then encodes only the
                        // survivors. Billed bytes are the fragment's
                        // bitmap + encoded non-zeros — exactly
                        // `pruned_payload_bytes` at f32 (comm::wire
                        // pins the reconciliation).
                        plan.extract_into(&delta, f, &mut vals);
                        let sup = wire::Support::from_values(&vals);
                        errs_f
                            .push(codec.transcode_sparse(&mut vals, plan.slices(f)));
                        bytes_f.push(wire::sparse_payload_bytes(
                            codec,
                            plan.elements(f),
                            sup.nnz(),
                            plan.slices(f).len(),
                        ));
                        sup_f.push(Some(sup));
                    } else {
                        // Dense: extract and transcode fuse into one
                        // pass where the wire format permits
                        // (bitwise-identical values).
                        errs_f.push(crate::comm::codec::extract_transcode(
                            codec, &plan, &delta, f, &mut vals,
                        ));
                        bytes_f.push(
                            codec.encoded_bytes(plan.elements(f), plan.slices(f).len()),
                        );
                        sup_f.push(None);
                    }
                    vals_f.push(vals);
                }
                deltas.push(delta);
                up_vals.push(vals_f);
                up_errs.push(errs_f);
                up_bytes.push(bytes_f);
                up_support.push(sup_f);
                up_intended.push(intended);
            }

            // Pass 2 — hierarchical delivery: one droppable aggregate
            // per (group, due fragment) on the leader's WAN lane, keyed
            // (round, leader, fragment, hop 1). Member payloads ride
            // free intra-group links, so a dropped leader hop excludes
            // — and desyncs — the whole group for that fragment. The
            // leader re-aggregates its members' payloads, so the hop
            // bills the density the aggregate actually ships: the union
            // of the member supports when pruned, the dense fragment
            // otherwise.
            let hier_landed: Option<Vec<Vec<bool>>> = hier_groups.as_ref().map(|gs| {
                due.iter()
                    .enumerate()
                    .map(|(di, &f)| {
                        let mut landed = vec![false; k_t];
                        for g in gs {
                            let ok = if k_t == 1 {
                                true
                            } else {
                                let bytes = if pruned {
                                    let mut u =
                                        wire::Support::empty(plan.elements(f));
                                    for &m in g {
                                        u.union_with(
                                            up_support[m][di].as_ref().expect(
                                                "pruned payloads carry supports",
                                            ),
                                        );
                                    }
                                    wire::sparse_payload_bytes(
                                        codec,
                                        plan.elements(f),
                                        u.nnz(),
                                        plan.slices(f).len(),
                                    )
                                } else {
                                    codec.encoded_bytes(
                                        plan.elements(f),
                                        plan.slices(f).len(),
                                    )
                                };
                                net.try_send_gen(
                                    bytes,
                                    Direction::Up,
                                    t,
                                    roster[g[0]],
                                    f,
                                    topology::HOP_LEADER_UP,
                                    delay,
                                )
                            };
                            for &m in g {
                                landed[m] = ok;
                            }
                        }
                        landed
                    })
                    .collect()
            });
            // Pass 3 — star uploads (per-fragment keyed drops), error
            // feedback bookkeeping, and assembly, in the same roster ×
            // due order the fused loop used, so the default trace is
            // bitwise unchanged.
            for (i, delta) in deltas.into_iter().enumerate() {
                let wid = roster[i];
                let w = &workers[wid];
                // With the exact f32 codec the received values ARE the
                // delta's, so the stats tensor can reuse `delta` instead
                // of being re-assembled (the default hot path moves it,
                // exactly like the pre-streaming loop did).
                let lossless = codec == Codec::F32 || k_t == 1;
                let mut assembled: Option<Tensors> = None;
                let mut dropped_any = false;
                for (di, &f) in due.iter().enumerate() {
                    let vals = std::mem::take(&mut up_vals[i][di]);
                    // A worker that vanished mid-phase has nothing to
                    // upload: its round is booked as a drop (never true
                    // on the sim fabric, so the gate is trace-neutral).
                    let ok = !vanished[i]
                        && match &hier_landed {
                            // Hierarchical: the group leader's hop already
                            // decided this fragment's fate for every member
                            // (indexed by roster position).
                            Some(landed) => landed[di][i],
                            None => {
                                k_t == 1
                                    || net.try_send_gen(
                                        up_bytes[i][di],
                                        Direction::Up,
                                        t,
                                        wid,
                                        f,
                                        0,
                                        delay,
                                    )
                            }
                        };
                    if ef {
                        // residual = intended − what actually shipped. A
                        // dropped fragment clears its residual instead:
                        // drops lose the round's contribution entirely
                        // (the Fig-8 semantics) — error feedback repairs
                        // *compression* loss only.
                        let mut res = std::mem::take(&mut up_intended[i][di]);
                        if ok {
                            for (r, v) in res.iter_mut().zip(&vals) {
                                *r -= *v;
                            }
                        } else {
                            res.iter_mut().for_each(|r| *r = 0.0);
                        }
                        plan.scatter(&res, f, &mut residuals[wid]);
                        scratch.recycle(res);
                    }
                    if ok {
                        codec_err_sq += up_errs[i][di];
                        if !lossless {
                            let a = assembled.get_or_insert_with(|| zeros.clone());
                            plan.scatter(&vals, f, a);
                        }
                        frag_rx[di].push(vals);
                        frag_wts[di].push(weights_v[i]);
                        sent[i][di] = true;
                    } else {
                        dropped_any = true;
                        scratch.recycle(vals);
                    }
                    // Landed or dropped, the worker keeps training this
                    // fragment from its own parameters until its next
                    // re-adopt, so rebase its reference: a dropped
                    // fragment's next upload covers only post-drop
                    // progress (the monolithic Fig-8 semantics), and a
                    // landed fragment's uploads during a delay window
                    // each cover exactly one round (no double counting).
                    // With D = 0 the landed rebase is unobservable — the
                    // re-adopt at the next active round overwrites the
                    // reference before it is ever read.
                    plan.copy_fragment(&w.params, &mut refs[wid], f);
                }
                if dropped_any {
                    drops_per_worker[wid] += 1;
                }
                let sent_any = sent[i].iter().any(|&s| s);
                if sent_any {
                    let a = match assembled {
                        Some(a) => a,
                        None if !dropped_any && due.len() == n_frag => delta,
                        None => {
                            // Lossless but partial: keep only the
                            // fragments that actually landed.
                            let mut a = zeros.clone();
                            for (di, &f) in due.iter().enumerate() {
                                if sent[i][di] {
                                    plan.copy_fragment(&delta, &mut a, f);
                                }
                            }
                            a
                        }
                    };
                    received_assembled.push(a);
                }
            }

            // Average each landed fragment over its contributors — the
            // identical per-element arithmetic (and fragment order) the
            // synchronous loop performed inline. Fragments are disjoint,
            // so under a parallel engine the per-fragment reductions fan
            // out across the work-stealing pool; outputs are collected
            // in due order either way, keeping the trace bitwise. The
            // fused kernel reproduces the legacy scale-then-axpy op
            // order exactly; `[engine] fast_math` opts into the
            // tolerance-gated pairwise tree (DESIGN.md §12).
            let fast_math = cfg.fast_math;
            let nonempty: Vec<usize> =
                (0..due.len()).filter(|&di| !frag_rx[di].is_empty()).collect();
            let reduce_threads = self.exec.reduce_threads(nonempty.len());
            let mut frag_avgs: Vec<Option<Vec<f32>>> = vec![None; due.len()];
            // Robust-aggregation outcome accumulators for the round's
            // stats columns: rejected contributions sum; trimmed weight
            // mass averages over the round's aggregation calls. Both
            // stay zero on the mean path.
            let mut agg_rejected = 0usize;
            let mut agg_trim_sum = 0.0f64;
            let mut agg_calls = 0usize;
            if agg.is_mean() && reduce_threads > 1 && nonempty.len() > 1 {
                let mut tasks: Vec<
                    Box<dyn FnOnce() -> (usize, Vec<f32>, Vec<f32>) + Send + '_>,
                > = Vec::with_capacity(nonempty.len());
                for &di in &nonempty {
                    let (mut norm, mut out) = (scratch.lease(), scratch.lease());
                    let (rx, wts) = (&frag_rx[di], &frag_wts[di]);
                    tasks.push(Box::new(move || {
                        if fast_math {
                            average::weighted_average_pairwise_into(
                                rx, wts, &mut norm, &mut out,
                            );
                        } else {
                            aggregate::WeightedMean
                                .mean_into(rx, wts, &mut norm, &mut out);
                        }
                        (di, norm, out)
                    }));
                }
                for (di, norm, out) in engine::run_tasks(reduce_threads, tasks) {
                    scratch.recycle(norm);
                    frag_avgs[di] = Some(out);
                }
            } else if agg.is_mean() {
                for &di in &nonempty {
                    let (mut norm, mut out) = (scratch.lease(), scratch.lease());
                    if fast_math {
                        average::weighted_average_pairwise_into(
                            &frag_rx[di], &frag_wts[di], &mut norm, &mut out,
                        );
                    } else {
                        aggregate::WeightedMean.mean_into(
                            &frag_rx[di], &frag_wts[di], &mut norm, &mut out,
                        );
                    }
                    scratch.recycle(norm);
                    frag_avgs[di] = Some(out);
                }
            } else {
                // Robust estimators (`[aggregate]` ≠ mean) reduce each
                // fragment serially: every call leases per-coordinate
                // columns from the shared arena, and the due order is
                // the deterministic fold order. `fast_math` composes
                // with the mean only — validate() rejects the rest.
                for &di in &nonempty {
                    let mut out = scratch.lease();
                    let views: Vec<&[f32]> =
                        frag_rx[di].iter().map(|v| v.as_slice()).collect();
                    let outcome = agg.aggregate_into(
                        &views, &frag_wts[di], &mut scratch, &mut out,
                    );
                    agg_rejected += outcome.rejected;
                    agg_trim_sum += outcome.trimmed_mass;
                    agg_calls += 1;
                    frag_avgs[di] = Some(out);
                }
            }
            // Contributor payloads are done — park them for next round.
            for rx in &mut frag_rx {
                for b in rx.drain(..) {
                    scratch.recycle(b);
                }
            }

            // Queue the round's batch. With D = 0 the batch is applied
            // immediately below, bitwise the legacy sequence; with D > 0
            // it waits out its delay while its transfer hides behind the
            // next inner phase.
            let mut frags: Vec<PendingFragment> = Vec::new();
            let mut avg_assembled: Option<Tensors> = None;
            for (di, &f) in due.iter().enumerate() {
                let Some(avg) = frag_avgs[di].take() else { continue };
                plan.scatter(&avg, f, avg_assembled.get_or_insert_with(|| zeros.clone()));
                let landed: Vec<usize> = roster
                    .iter()
                    .enumerate()
                    .filter(|&(i, _)| sent[i][di])
                    .map(|(_, &wid)| wid)
                    .collect();
                // Download billing targets at apply time: the landed
                // workers under star; the landed group *leaders* under
                // hierarchical (members ride free intra-group links);
                // nobody when the round synced locally (k = 1).
                let down_to: Vec<usize> = if k_t <= 1 {
                    Vec::new()
                } else if let (Some(gs), Some(hl)) = (&hier_groups, &hier_landed) {
                    gs.iter()
                        .filter(|g| hl[di][g[0]])
                        .map(|g| roster[g[0]])
                        .collect()
                } else {
                    landed.clone()
                };
                frags.push(PendingFragment { fragment: f, avg, landed, down_to });
            }
            let stats_rec = avg_assembled.as_ref().map(|avg| {
                let mut rs = stats::round_stats(t, &received_assembled, avg);
                rs.fragments_synced = frags.len();
                rs.codec_err_l2 = codec_err_sq.sqrt();
                rs.active_workers = k_t;
                rs.idle_s = idle;
                rs.rejected = agg_rejected;
                if agg_calls > 0 {
                    rs.trimmed_mass = agg_trim_sum / agg_calls as f64;
                }
                rs
            });
            if stats_rec.is_some() {
                codec_err_sq_total += codec_err_sq;
            }
            if !frags.is_empty() {
                pending.push(PendingSync { round: t, frags, stats: stats_rec });
            }

            // Apply every batch whose delay has elapsed (see
            // `apply_pending_batch`). With D = 0 the batch just queued
            // is applied right here — the synchronous legacy sequence,
            // bitwise.
            while pending.first().is_some_and(|b| b.round + delay <= t) {
                let batch = pending.remove(0);
                let threads = self.exec.reduce_threads(batch.frags.len());
                apply_pending_batch(
                    batch,
                    t,
                    cfg.sync.discount,
                    &plan,
                    &active,
                    &mut global,
                    &mut outer,
                    &mut pending_adopt,
                    net.as_mut(),
                    &mut round_stats,
                    &mut scratch,
                    threads,
                )?;
            }

            // Overlapped rounds — the streaming `overlapped` schedule
            // and every non-final round of a delayed run — defer their
            // transfer into the next inner phase; the final round has no
            // next phase, so it closes as a normal barrier and its
            // billing row says so.
            if (cfg.stream.schedule.defers_barrier() || delay > 0) && t + 1 < cfg.rounds
            {
                carry_comm_s = net.end_round_deferred();
            } else {
                net.end_round();
            }

            // End-of-run drain: batches still in flight after the final
            // round each close their own barrier (one billing row per
            // batch), so no contribution is ever lost and every drain
            // row's cost stays bounded by a synchronous round's — the
            // overlap-billing invariant benches/async_delay.rs asserts.
            if t + 1 == cfg.rounds {
                while !pending.is_empty() {
                    let batch = pending.remove(0);
                    let threads = self.exec.reduce_threads(batch.frags.len());
                    apply_pending_batch(
                        batch,
                        t,
                        cfg.sync.discount,
                        &plan,
                        &active,
                        &mut global,
                        &mut outer,
                        &mut pending_adopt,
                        net.as_mut(),
                        &mut round_stats,
                        &mut scratch,
                        threads,
                    )?;
                    net.end_round();
                }
            }
            drop(_outer_timer);

            // Evaluation of the *global* model.
            let at_eval = cfg.eval_every_rounds > 0
                && (t + 1) % cfg.eval_every_rounds == 0;
            if at_eval || t + 1 == cfg.rounds {
                let _t = Stopwatch::new(&mut metrics.phases.eval_s);
                let mut p = self.evaluate(&global)?;
                p.step = cfg.pretrain_steps + (t + 1) * cfg.inner_steps;
                metrics.eval_curve.push(p);
            }

            // Periodic TrainState save — the record captures every bit
            // of mutable loop state at this round boundary, delayed
            // batches still in flight included, so a resumed run
            // continues bitwise (DESIGN.md §10, §11).
            if self.save_due(t) {
                self.save_state_now(
                    t,
                    false,
                    &global,
                    &[],
                    vec![outer.snapshot()],
                    &workers,
                    &refs,
                    &pending_adopt,
                    &drops_per_worker,
                    carry_comm_s,
                    codec_err_sq_total,
                    &pending,
                    &residuals,
                    adv.as_ref().map(|a| a.stale_entries()).unwrap_or_default(),
                )?;
            }
        }

        let cs = net.stats();
        metrics.comm_bytes = cs.total_bytes();
        metrics.comm_bytes_up = cs.bytes_up;
        metrics.comm_messages = cs.messages;
        metrics.comm_dropped = cs.dropped;
        metrics.sim_comm_seconds = cs.sim_comm_seconds;
        metrics.codec_err_l2 = codec_err_sq_total.sqrt();

        Ok(DilocoReport {
            metrics,
            round_stats,
            final_params: global,
            drops_per_worker,
            comm_per_round: cs.per_round.clone(),
            replica_params: Vec::new(),
            replica_evals: Vec::new(),
        })
    }

    /// Decentralized round loop (ring, gossip topologies): every worker
    /// keeps its own model replica and outer-optimizer state. Each round
    /// the topology's deterministic transfer schedule moves the
    /// (fragmented, codec-encoded) outer gradients between peers over
    /// the billed fabric, and every replica applies its own row of the
    /// mixing matrix through its own outer optimizer. The eval curve
    /// tracks the uniform *consensus* of the active replicas; the final
    /// report adds per-replica models and evals plus a per-round
    /// consensus-distance metric in the round stats.
    fn run_decentralized(
        &self,
        global: Tensors,
        mut metrics: RunMetrics,
        resume: Option<TrainState>,
    ) -> anyhow::Result<DilocoReport> {
        let cfg = &self.cfg;
        let mcfg = &self.rt.manifest.config;
        let rng = cfg.rng();
        let topo = cfg.topology.build(cfg.seed);

        let max_k = cfg.pool_size();
        let zeros = Tensors::zeros(&self.rt.manifest);
        let mut workers: Vec<Worker> = (0..max_k)
            .map(|i| {
                let shard = self.dataset.shards[i % self.dataset.shards.len()].clone();
                let mut w = Worker::new(
                    i,
                    global.clone(),
                    zeros.clone(),
                    BatchIter::new(
                        shard,
                        mcfg.batch_size,
                        mcfg.seq_len,
                        rng.child(100 + i as u64),
                    ),
                );
                w.step = cfg.pretrain_steps as f64;
                w
            })
            .collect();
        let plan = FragmentPlan::for_tensors(&zeros, cfg.stream.fragments);
        let n_frag = plan.n_fragments();
        let codec = cfg.stream.codec;
        // One model replica + outer-optimizer state per worker, all
        // starting from the shared (pretrained) initialization.
        let mut replicas: Vec<Tensors> = (0..max_k).map(|_| global.clone()).collect();
        let mut outers = opt::OuterOpt::replicated(&cfg.outer_opt, &zeros, max_k);
        // Reusable round-local buffers — same allocation-free steady
        // state as the centralized loop (see `RoundScratch`).
        let mut scratch = scratch::RoundScratch::new();
        let fast_math = cfg.fast_math;
        // Pluggable outer aggregation + Byzantine attacker model, as on
        // the centralized loop (DESIGN.md §16). Here the estimator runs
        // inside each mixing row: a robust row aggregates the positive-
        // weight peer payloads it would otherwise have averaged.
        let agg = aggregate::build(&cfg.aggregate);
        let mut adv: Option<adversary::Adversary> = cfg
            .adversary
            .as_ref()
            .map(|a| adversary::Adversary::new(a, cfg.seed, max_k));
        let mut refs: Vec<Tensors> = (0..max_k).map(|_| global.clone()).collect();
        // Per-worker error-feedback residuals, exactly as on the
        // centralized loop. Decentralized senders always mix their own
        // wire values, so the residual here measures pure compression
        // loss (a dropped gossip exchange deprives the *peer* and is
        // handled by the mixing row, not by error feedback).
        let ef = cfg.stream.error_feedback;
        let mut residuals: Vec<Tensors> =
            if ef { (0..max_k).map(|_| zeros.clone()).collect() } else { Vec::new() };
        let mut pending_adopt: Vec<Vec<bool>> = vec![vec![true; n_frag]; max_k];
        let mut drops_per_worker = vec![0usize; max_k];
        let mut carry_comm_s = 0.0f64;
        let mut codec_err_sq_total = 0.0f64;
        // Uniform consensus of the active replicas, refreshed per round
        // — what the eval curve and `final_params` report.
        let mut consensus = global.clone();
        let mut start_round = 0usize;

        // Resume: overwrite every piece of mutable loop state with the
        // checkpointed record (the `global` argument already carries the
        // saved consensus).
        if let Some(st) = resume {
            anyhow::ensure!(
                st.pending_adopt.iter().all(|p| p.len() == n_frag),
                "checkpoint has {} fragments, config wants {n_frag}",
                st.pending_adopt.first().map_or(0, |p| p.len())
            );
            anyhow::ensure!(
                st.outer.len() == max_k,
                "checkpoint has {} outer optimizers, pool wants {max_k}",
                st.outer.len()
            );
            start_round = st.round;
            Self::restore_pool(&mut workers, &st.workers);
            replicas = st.replicas;
            outers = st
                .outer
                .into_iter()
                .map(|snap| opt::OuterOpt::restore(&cfg.outer_opt, &zeros, snap, n_frag))
                .collect::<anyhow::Result<Vec<_>>>()?;
            refs = st.refs;
            pending_adopt = st.pending_adopt;
            drops_per_worker = st.drops_per_worker;
            carry_comm_s = st.carry_comm_s;
            codec_err_sq_total = st.codec_err_sq_total;
            // Pre-v3 checkpoints (and runs saved with error feedback
            // off) carry no residuals — resume with zeros.
            if ef && !st.residuals.is_empty() {
                residuals = st.residuals;
            }
            // Pre-v4 checkpoints park no stale-replay deltas; a resumed
            // attacker then ships one honest delta, like round 0.
            if let Some(a) = adv.as_mut() {
                a.restore_stale(st.stale);
            }
        }
        let mut ever_active = self.ever_active_before(start_round, max_k);

        let mut net = self.build_fabric()?;
        let mut round_stats = Vec::with_capacity(cfg.rounds);
        let payload = self.rt.manifest.param_bytes() as u64;
        let mut last_roster: Vec<usize> = Vec::new();

        for t in start_round..cfg.rounds {
            // Fabric roster veto, as on the centralized loop: a dead TCP
            // peer leaves as `[churn]`; the sim fabric is a passthrough.
            let roster = net.filter_roster(t, cfg.active_ids(t))?;
            let k_t = roster.len();
            last_roster = roster.clone();
            let factors = cfg.speed_factors(&roster, t);
            let due = cfg.stream.schedule.fragments_due(t, n_frag);

            // Fresh joiners warm-start from the current *consensus*
            // model (their replica had never trained); rejoining leavers
            // keep their parked replica and outer momentum.
            if cfg.churn.is_some() {
                for &id in &roster {
                    if !ever_active[id] {
                        // A no-op at round 0, where every replica is the
                        // shared (pretrained) init == the consensus.
                        replicas[id] = consensus.clone();
                        for flag in pending_adopt[id].iter_mut() {
                            *flag = true;
                        }
                        workers[id].step =
                            (cfg.pretrain_steps + t * cfg.inner_steps) as f64;
                    }
                    ever_active[id] = true;
                }
            }

            // Every worker re-adopts its own replica's freshly stepped
            // fragments — there is no central model to download.
            for &wid in &roster {
                let w = &mut workers[wid];
                let pa = &mut pending_adopt[wid];
                for (f, flag) in pa.iter_mut().enumerate() {
                    if *flag {
                        plan.copy_fragment(&replicas[wid], &mut w.params, f);
                        plan.copy_fragment(&replicas[wid], &mut refs[wid], f);
                        *flag = false;
                    }
                }
            }

            // Speed-scaled critical path + idle, exactly as on the
            // centralized loop (uniform factors reproduce the raw max
            // bitwise). Decentralized topologies reject `delay_rounds`,
            // so the only async-layer effect here is heterogeneity.
            let (phase, vanished) =
                match net.run_phase(&mut workers, &roster, cfg.inner_steps)? {
                    Some(out) => (out.report, out.vanished),
                    None => (
                        engine::run_inner_phase_subset(
                            self.exec.as_ref(),
                            &self.rt,
                            &mut workers,
                            &roster,
                            cfg.inner_steps,
                        )?,
                        vec![false; k_t],
                    ),
                };
            let crit = phase.critical_path_s(&factors);
            metrics.sim_compute_seconds += crit.max(carry_comm_s);
            carry_comm_s = 0.0;
            let idle = phase.idle_s(&factors);
            metrics.sim_idle_seconds += idle;
            metrics.phases.inner_compute_s += phase.total_wall_s();
            // Live-only loss fold — identical addition order (and hence
            // bitwise) when nobody vanished, as on the centralized loop.
            let live = vanished.iter().filter(|&&v| !v).count().max(1);
            for s in 0..cfg.inner_steps {
                // detlint: allow(float_fold, roster-order f32 fold pinned bitwise by the golden trace; rewriting through math:: would widen to f64 and break it)
                let avg = phase
                    .per_worker_losses
                    .iter()
                    .zip(&vanished)
                    .filter(|&(_, &v)| !v)
                    .map(|(l, _)| l[s])
                    .sum::<f32>()
                    / live as f32;
                metrics.loss_curve.push(avg);
            }

            let _outer_timer = Stopwatch::new(&mut metrics.phases.outer_opt_s);
            if k_t > 1 {
                metrics.comm_bytes_up_baseline += k_t as u64 * payload;
            }

            // Outer gradients, §6.1 weights, and wire payloads per
            // worker, in roster order (the deterministic fold order).
            // payloads[di][j] holds the *transcoded* wire values of due
            // fragment di from roster position j — what every receiver
            // (and the sender itself) mixes, so codec loss is part of
            // the simulated algorithm exactly as on the star path.
            let mut weights: Vec<f64> = Vec::with_capacity(k_t);
            let mut worker_bytes: Vec<Vec<u64>> = Vec::with_capacity(k_t);
            let mut payloads: Vec<Vec<Vec<f32>>> = vec![Vec::new(); due.len()];
            // Assembled wire-value deltas for the round statistics.
            let mut received_assembled: Vec<Tensors> = Vec::with_capacity(k_t);
            // Lossless full coverage (the f32 every-round default): the
            // wire values ARE the delta's, so the stats tensor moves the
            // delta instead of being re-assembled — same fast path as
            // the star loop.
            let lossless_full =
                (codec == Codec::F32 || k_t == 1) && due.len() == n_frag;
            let mut codec_err_sq = 0.0f64;
            let pruned = cfg.prune_frac > 0.0;
            // Per (roster position, due fragment) sparse supports — what
            // the ring needs to bill each chunk hop by the density of
            // the partial sum it actually carries.
            let mut supports: Vec<Vec<Option<wire::Support>>> =
                Vec::with_capacity(k_t);
            for &wid in &roster {
                let w = &workers[wid];
                let mut delta = refs[wid].delta(&w.params);
                // Byzantine corruption: after the inner phase, before
                // error feedback, pruning, the codec, and billing —
                // identical placement to the centralized loop.
                if let Some(a) = adv.as_mut() {
                    a.corrupt(t, wid, &mut delta);
                }
                if ef {
                    // Error feedback: replay the last round's
                    // compression residual into this outer delta.
                    for &f in &due {
                        plan.add_fragment(&residuals[wid], &mut delta, f);
                    }
                }
                let mut intended: Vec<Vec<f32>> = if ef {
                    due.iter()
                        .map(|&f| {
                            let mut v = scratch.lease();
                            plan.extract_into(&delta, f, &mut v);
                            v
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                if pruned {
                    prune::prune_sign(&mut delta, cfg.prune_frac);
                }
                weights.push(if cfg.weighted_average && cfg.data.non_iid {
                    self.dataset.shard_doc_counts
                        [wid % self.dataset.shard_doc_counts.len()]
                        as f64
                } else {
                    1.0
                });
                let mut bytes_per_frag = Vec::with_capacity(due.len());
                let mut sup_f: Vec<Option<wire::Support>> =
                    Vec::with_capacity(due.len());
                let mut assembled: Option<Tensors> = None;
                for (di, &f) in due.iter().enumerate() {
                    let mut vals = scratch.lease();
                    // k = 1: the outer step is local — no codec, no
                    // fabric. Pruned payloads fix their support before
                    // quantization and bill exact sparse bytes; dense
                    // payloads keep the fused extract + transcode pass.
                    if k_t == 1 {
                        plan.extract_into(&delta, f, &mut vals);
                        bytes_per_frag.push(0);
                        sup_f.push(None);
                    } else if pruned {
                        plan.extract_into(&delta, f, &mut vals);
                        let sup = wire::Support::from_values(&vals);
                        codec_err_sq +=
                            codec.transcode_sparse(&mut vals, plan.slices(f));
                        bytes_per_frag.push(wire::sparse_payload_bytes(
                            codec,
                            plan.elements(f),
                            sup.nnz(),
                            plan.slices(f).len(),
                        ));
                        sup_f.push(Some(sup));
                    } else {
                        codec_err_sq += crate::comm::codec::extract_transcode(
                            codec, &plan, &delta, f, &mut vals,
                        );
                        bytes_per_frag.push(
                            codec.encoded_bytes(plan.elements(f), plan.slices(f).len()),
                        );
                        sup_f.push(None);
                    }
                    if ef {
                        // residual = intended − wire values. The sender
                        // always mixes its own wire values, so this is
                        // pure compression loss; peer-side drops are the
                        // mixing row's business.
                        let mut res = std::mem::take(&mut intended[di]);
                        for (r, v) in res.iter_mut().zip(&vals) {
                            *r -= *v;
                        }
                        plan.scatter(&res, f, &mut residuals[wid]);
                        scratch.recycle(res);
                    }
                    if !lossless_full {
                        plan.scatter(
                            &vals,
                            f,
                            assembled.get_or_insert_with(|| zeros.clone()),
                        );
                    }
                    payloads[di].push(vals);
                }
                worker_bytes.push(bytes_per_frag);
                supports.push(sup_f);
                received_assembled.push(match assembled {
                    Some(a) => a,
                    None => delta,
                });
            }

            let transfers = topo.transfers(t, k_t);
            let mut dropped_any = vec![false; k_t];
            let mut fragments_synced = 0usize;
            let mut avg_assembled: Option<Tensors> = None;
            // Robust-aggregation outcome accumulators: one sample per
            // *performed* aggregation (the ring's shared row counts
            // once, not once per replica). Zero on the mean path.
            let mut agg_rejected = 0usize;
            let mut agg_trim_sum = 0.0f64;
            let mut agg_calls = 0usize;
            for (di, &f) in due.iter().enumerate() {
                // Execute the fragment's transfer schedule against the
                // fabric; the schedule speaks roster *positions*, which
                // map through `roster` onto worker ids for lane billing
                // and drop keys (identity when the roster is the static
                // prefix). landed[s] = position s's outgoing
                // contribution was delivered to its receiver(s).
                let mut landed = vec![true; k_t];
                // A vanished peer contributes nothing to its neighbours
                // this round — the mixing rows treat it exactly like a
                // dropped hop (never flagged on the sim fabric).
                for (pos, &v) in vanished.iter().enumerate() {
                    if v {
                        landed[pos] = false;
                        dropped_any[pos] = true;
                    }
                }
                if k_t > 1 {
                    for tr in &transfers {
                        let Some(lane) = tr.lane else { continue };
                        if vanished[tr.sender] {
                            continue;
                        }
                        let bytes = match tr.chunk {
                            Some((c, of)) => {
                                let n = plan.elements(f);
                                let chunk_n = topology::chunk_elems(n, c, of);
                                if pruned {
                                    // A ring chunk at hop h carries the
                                    // partial sum of h+1 consecutive
                                    // positions' contributions (capped at
                                    // k once the all-gather phase streams
                                    // full sums), so bill the union of
                                    // their supports restricted to the
                                    // chunk's element range.
                                    let start = c * n / of;
                                    let m = (tr.hop + 1).min(k_t);
                                    let mut u = wire::Support::empty(n);
                                    for j in 0..m {
                                        let pos = (tr.sender + k_t - j) % k_t;
                                        u.union_with(
                                            supports[pos][di]
                                                .as_ref()
                                                .expect("pruned payloads carry supports"),
                                        );
                                    }
                                    wire::sparse_payload_bytes(
                                        codec,
                                        chunk_n,
                                        u.nnz_in_range(start, start + chunk_n),
                                        1,
                                    )
                                } else {
                                    codec.encoded_bytes(chunk_n, 1)
                                }
                            }
                            None => worker_bytes[tr.sender][di],
                        };
                        if tr.droppable {
                            debug_assert_eq!(
                                lane, tr.sender,
                                "droppable hops bill the sender's lane"
                            );
                            if !net.try_send_hop(
                                bytes,
                                tr.dir,
                                t,
                                roster[tr.sender],
                                f,
                                tr.hop,
                            ) {
                                landed[tr.sender] = false;
                                dropped_any[tr.sender] = true;
                            }
                        } else {
                            net.send_reliable_to(bytes, tr.dir, roster[lane]);
                        }
                    }
                }

                // Mixing + per-replica outer steps, replica order. Raw
                // rows feed the same normalize/scale/axpy scalar ops as
                // the star average, so the all-landed uniform case is
                // bitwise-equal to the star path per replica.
                let rows = topo.mixing_raw(t, k_t, &weights, &landed);
                // Mixed averages land in leased scratch (the arena is
                // threaded through as an argument so the closure holds
                // no long-lived &mut). `fast_math` swaps the reduction
                // for the tolerance-gated pairwise tree (DESIGN.md §12);
                // a non-mean `[aggregate]` estimator replaces it with a
                // robust reduction over the row's positive-weight peers,
                // and each call reports its rejection outcome.
                let mix = |row: &[f64],
                           scratch: &mut scratch::RoundScratch|
                 -> Option<(Vec<f32>, aggregate::AggregateOutcome)> {
                    let mut pl: Vec<&[f32]> = Vec::with_capacity(k_t);
                    let mut wt: Vec<f64> = Vec::with_capacity(k_t);
                    for (j, &wgt) in row.iter().enumerate() {
                        if wgt > 0.0 {
                            pl.push(&payloads[di][j]);
                            wt.push(wgt);
                        }
                    }
                    if pl.is_empty() {
                        return None;
                    }
                    let mut out = scratch.lease();
                    let outcome = if agg.is_mean() {
                        let mut norm = scratch.lease();
                        if fast_math {
                            average::weighted_average_pairwise_into(
                                &pl, &wt, &mut norm, &mut out,
                            );
                        } else {
                            aggregate::WeightedMean
                                .mean_into(&pl, &wt, &mut norm, &mut out);
                        }
                        scratch.recycle(norm);
                        aggregate::AggregateOutcome::default()
                    } else {
                        agg.aggregate_into(&pl, &wt, scratch, &mut out)
                    };
                    Some((out, outcome))
                };
                // All-equal rows (the ring) share one mixed average
                // instead of recomputing k bit-identical ones.
                let shared = (rows.len() > 1
                    && rows.windows(2).all(|w| w[0] == w[1]))
                .then(|| mix(&rows[0], &mut scratch))
                .flatten();
                if let Some((_, oc)) = &shared {
                    agg_rejected += oc.rejected;
                    agg_trim_sum += oc.trimmed_mass;
                    agg_calls += 1;
                }
                for (r, row) in rows.iter().enumerate() {
                    let mut owned: Option<Vec<f32>> = None;
                    let mixed: &[f32] = if let Some((m, _)) = &shared {
                        m
                    } else {
                        match mix(row, &mut scratch) {
                            Some((m, oc)) => {
                                agg_rejected += oc.rejected;
                                agg_trim_sum += oc.trimmed_mass;
                                agg_calls += 1;
                                owned = Some(m);
                                owned.as_deref().unwrap()
                            }
                            None => continue,
                        }
                    };
                    let rid = roster[r];
                    outers[rid].step_fragment(&mut replicas[rid], mixed, plan.slices(f), f);
                    pending_adopt[rid][f] = true;
                    if let Some(m) = owned {
                        scratch.recycle(m);
                    }
                }
                if let Some((m, _)) = shared {
                    scratch.recycle(m);
                }
                fragments_synced += 1;
                // Field average over every active worker — the analogue
                // of the star's received average, for the round stats.
                // The generic kernel reduces the owned payloads directly
                // (no per-round Vec-of-refs); stats stay on the default
                // bitwise reduction regardless of `fast_math`.
                let mut norm = scratch.lease();
                let mut avg = scratch.lease();
                aggregate::WeightedMean.mean_into(
                    &payloads[di], &weights, &mut norm, &mut avg,
                );
                plan.scatter(&avg, f, avg_assembled.get_or_insert_with(|| zeros.clone()));
                scratch.recycle(norm);
                scratch.recycle(avg);
            }
            // Contributor payloads are done — park them for next round.
            for pl in &mut payloads {
                for b in pl.drain(..) {
                    scratch.recycle(b);
                }
            }

            for (pos, dropped) in dropped_any.iter().enumerate() {
                if *dropped {
                    drops_per_worker[roster[pos]] += 1;
                }
            }
            if let Some(avg) = &avg_assembled {
                let mut rs = stats::round_stats(t, &received_assembled, avg);
                rs.fragments_synced = fragments_synced;
                rs.codec_err_l2 = codec_err_sq.sqrt();
                rs.active_workers = k_t;
                rs.idle_s = idle;
                rs.rejected = agg_rejected;
                if agg_calls > 0 {
                    rs.trimmed_mass = agg_trim_sum / agg_calls as f64;
                }
                let active_replicas: Vec<&Tensors> =
                    roster.iter().map(|&id| &replicas[id]).collect();
                consensus = average::uniform_average_refs(&active_replicas);
                rs.consensus_dist =
                    stats::consensus_distance_refs(&active_replicas, &consensus);
                round_stats.push(rs);
                codec_err_sq_total += codec_err_sq;
                for &id in &roster {
                    anyhow::ensure!(
                        replicas[id].all_finite(),
                        "outer step produced non-finite parameters at round {t}"
                    );
                }
            }

            if cfg.stream.schedule.defers_barrier() && t + 1 < cfg.rounds {
                carry_comm_s = net.end_round_deferred();
            } else {
                net.end_round();
            }
            drop(_outer_timer);

            // Evaluation of the *consensus* model.
            let at_eval = cfg.eval_every_rounds > 0
                && (t + 1) % cfg.eval_every_rounds == 0;
            if at_eval || t + 1 == cfg.rounds {
                let _t = Stopwatch::new(&mut metrics.phases.eval_s);
                let mut p = self.evaluate(&consensus)?;
                p.step = cfg.pretrain_steps + (t + 1) * cfg.inner_steps;
                metrics.eval_curve.push(p);
            }

            // Periodic TrainState save (DESIGN.md §10): the whole pool —
            // replicas, per-replica outer state, parked workers included.
            // Decentralized loops never hold delayed batches (validate()
            // rejects the composition), so the queue is always empty.
            if self.save_due(t) {
                self.save_state_now(
                    t,
                    true,
                    &consensus,
                    &replicas,
                    outers.iter().map(|o| o.snapshot()).collect(),
                    &workers,
                    &refs,
                    &pending_adopt,
                    &drops_per_worker,
                    carry_comm_s,
                    codec_err_sq_total,
                    &[],
                    &residuals,
                    adv.as_ref().map(|a| a.stale_entries()).unwrap_or_default(),
                )?;
            }
        }

        let cs = net.stats();
        metrics.comm_bytes = cs.total_bytes();
        metrics.comm_bytes_up = cs.bytes_up;
        metrics.comm_messages = cs.messages;
        metrics.comm_dropped = cs.dropped;
        metrics.sim_comm_seconds = cs.sim_comm_seconds;
        metrics.codec_err_l2 = codec_err_sq_total.sqrt();
        let comm_per_round = cs.per_round.clone();

        // No round executed (a zero-round run, or a resume whose
        // checkpoint is already at cfg.rounds): still report the final
        // roster's replicas, exactly as the straight run did.
        if last_roster.is_empty() {
            last_roster = if cfg.rounds > 0 {
                cfg.active_ids(cfg.rounds - 1)
            } else {
                vec![0]
            };
        }

        // Per-replica finals: each island in the final roster, evaluated
        // once on its own model.
        let mut replica_evals = Vec::with_capacity(last_roster.len());
        if cfg.rounds > 0 {
            let _t = Stopwatch::new(&mut metrics.phases.eval_s);
            for &id in &last_roster {
                let mut p = self.evaluate(&replicas[id])?;
                p.step = cfg.pretrain_steps + cfg.rounds * cfg.inner_steps;
                replica_evals.push(p);
            }
        }
        let replica_params: Vec<Tensors> = last_roster
            .iter()
            .map(|&id| replicas[id].clone())
            .collect();

        Ok(DilocoReport {
            metrics,
            round_stats,
            final_params: consensus,
            drops_per_worker,
            comm_per_round,
            replica_params,
            replica_evals,
        })
    }
}

/// Fold one delayed contribution batch into the global model at round
/// `t` — the shared apply path of the unified round loop (DESIGN.md
/// §11). Each synced fragment steps through its own slice of the
/// outer-optimizer state, discounted by `discount^staleness` (the
/// scaling is skipped when the factor is exactly 1.0, so the
/// synchronous path performs the identical arithmetic); landed workers
/// re-adopt at their next active round; the full-precision download
/// bills to the batch's targets still in the apply round's roster — a
/// worker that departed mid-flight adopts for free on rejoin, like any
/// joiner. The batch's upload-round statistics are stamped with the
/// realized staleness and appended to the run's `round_stats`.
#[allow(clippy::too_many_arguments)]
fn apply_pending_batch(
    batch: PendingSync,
    t: usize,
    discount: f64,
    plan: &FragmentPlan,
    active: &[bool],
    global: &mut Tensors,
    outer: &mut opt::OuterOpt,
    pending_adopt: &mut [Vec<bool>],
    net: &mut dyn Fabric,
    round_stats: &mut Vec<RoundStats>,
    scratch: &mut scratch::RoundScratch,
    threads: usize,
) -> anyhow::Result<()> {
    let staleness = t - batch.round;
    let scale = if discount < 1.0 && staleness > 0 {
        discount.powi(staleness as i32) as f32
    } else {
        1.0
    };
    let PendingSync { round, frags, stats } = batch;
    // Discount-scaled copies live in leased scratch; with the factor
    // exactly 1.0 the averages are stepped in place — the identical
    // arithmetic of the synchronous path, no copy at all.
    let scaled: Vec<Option<Vec<f32>>> = frags
        .iter()
        .map(|fr| {
            (scale != 1.0).then(|| {
                let mut s = scratch.lease();
                s.extend(fr.avg.iter().map(|&v| v * scale));
                s
            })
        })
        .collect();
    {
        // The batch's fragments are disjoint parameter ranges, so the
        // whole batch steps through the outer optimizer in one (possibly
        // parallel) call. `step_fragments` wants ascending fragment ids;
        // reordering is bitwise-neutral across disjoint fragments.
        let mut batch_refs: Vec<(usize, &[f32])> = frags
            .iter()
            .zip(&scaled)
            .map(|(fr, s)| (fr.fragment, s.as_deref().unwrap_or(&fr.avg[..])))
            .collect();
        batch_refs.sort_unstable_by_key(|&(f, _)| f);
        outer.step_fragments(global, &batch_refs, plan, threads);
    }
    for (fr, s) in frags.into_iter().zip(scaled) {
        for &wid in &fr.landed {
            pending_adopt[wid][fr.fragment] = true;
        }
        for &wid in &fr.down_to {
            if active[wid] {
                net.send_reliable_to(
                    4 * plan.elements(fr.fragment) as u64,
                    Direction::Down,
                    wid,
                );
            }
        }
        scratch.recycle(fr.avg);
        if let Some(s) = s {
            scratch.recycle(s);
        }
    }
    anyhow::ensure!(
        global.all_finite(),
        "outer step produced non-finite parameters at round {t} \
         (batch from round {round})"
    );
    if let Some(mut rs) = stats {
        rs.staleness = staleness;
        round_stats.push(rs);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputeSchedule, OuterOptConfig};

    fn runtime() -> Option<Arc<Runtime>> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("nano.manifest.json")
            .exists()
            .then(|| Arc::new(Runtime::load(dir, "nano").unwrap()))
    }

    fn fast_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_default(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
            "nano",
        );
        cfg.workers = 2;
        cfg.schedule = ComputeSchedule::Constant(2);
        cfg.inner_steps = 5;
        cfg.rounds = 2;
        cfg.pretrain_steps = 5;
        cfg.eval_batches = 1;
        cfg.data.n_docs = 60;
        cfg.data.doc_len = 120;
        cfg
    }

    #[test]
    fn diloco_runs_and_reports() {
        let Some(rt) = runtime() else { return };
        let coord = Coordinator::new(fast_cfg(), rt).unwrap();
        let report = coord.run().unwrap();
        // 5 pretrain + 2 rounds × 5 inner steps of loss points.
        assert_eq!(report.metrics.loss_curve.len(), 15);
        assert_eq!(report.round_stats.len(), 2);
        assert!(report.round_stats.iter().all(|rs| rs.active_workers == 2));
        assert!(report.metrics.final_ppl().is_finite());
        assert!(report.final_params.all_finite());
        // Communication: 2 workers × 2 rounds, up + down each.
        assert_eq!(report.metrics.comm_messages, 8);
        assert_eq!(
            report.metrics.comm_bytes,
            8 * coord.runtime().manifest.param_bytes() as u64
        );
    }

    #[test]
    fn single_worker_has_zero_comm() {
        let Some(rt) = runtime() else { return };
        let mut cfg = fast_cfg();
        cfg.workers = 1;
        cfg.schedule = ComputeSchedule::Constant(1);
        let coord = Coordinator::new(cfg, rt).unwrap();
        let report = coord.run().unwrap();
        assert_eq!(report.metrics.comm_bytes, 0);
        assert_eq!(report.metrics.comm_messages, 0);
        assert_eq!(report.round_stats.len(), 2); // outer steps still happen
    }

    #[test]
    fn full_drop_leaves_global_unchanged() {
        let Some(rt) = runtime() else { return };
        let mut cfg = fast_cfg();
        cfg.comm.drop_prob = 1.0;
        cfg.pretrain_steps = 0;
        let coord = Coordinator::new(cfg, rt.clone()).unwrap();
        let init = rt.init_params().unwrap();
        let report = coord.run_from(Some(init.clone())).unwrap();
        // Every upload dropped ⇒ no outer step ever ⇒ global == init.
        assert_eq!(report.final_params, init);
        assert!(report.round_stats.is_empty());
        assert_eq!(report.drops_per_worker.iter().sum::<usize>(), 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(rt) = runtime() else { return };
        let r1 = Coordinator::new(fast_cfg(), rt.clone())
            .unwrap()
            .run()
            .unwrap();
        let r2 = Coordinator::new(fast_cfg(), rt).unwrap().run().unwrap();
        assert_eq!(r1.metrics.loss_curve, r2.metrics.loss_curve);
        assert_eq!(r1.final_params, r2.final_params);
    }

    #[test]
    fn schedule_controls_active_workers() {
        let Some(rt) = runtime() else { return };
        let mut cfg = fast_cfg();
        cfg.schedule = ComputeSchedule::Step { first: 1, second: 2 };
        cfg.rounds = 2;
        let coord = Coordinator::new(cfg, rt).unwrap();
        let report = coord.run().unwrap();
        // Round 0: k=1 (no fabric traffic), round 1: k=2 (2 up + 2 down).
        assert_eq!(report.metrics.comm_messages, 4);
    }
}
