//! The DiLoCo coordinator — Algorithm 1 of the paper, plus every ablation
//! knob the evaluation section exercises.
//!
//! One [`Coordinator`] owns the run: it synthesizes + shards data, warm
//! starts from `pretrain_steps` of plain training (paper Fig 3), then
//! executes T outer rounds. Each round: the schedule (Fig 7) picks the
//! active workers; each active worker runs H inner AdamW steps through the
//! AOT artifacts — dispatched through the configured [`crate::engine`]
//! executor, so islands run on real OS threads under `ParallelIslands`
//! with bitwise-identical results to the sequential reference path (see
//! DESIGN.md §determinism); outer gradients are optionally sign-pruned (Table 6),
//! shipped over the simulated fabric with drop injection (Fig 8),
//! weighted-averaged (§6.1), and applied by the outer optimizer (Fig 6).
//! Fresh parameters are re-dispatched to every worker that communicated;
//! a worker whose upload dropped keeps training from its own parameters,
//! exactly as the paper specifies.

pub mod average;
pub mod baselines;
pub mod opt;
pub mod prune;
pub mod stats;

use crate::comm::{Direction, SimNet};
use crate::config::ExperimentConfig;
use crate::data::batch::{BatchIter, EvalSet};
use crate::data::Dataset;
use crate::engine::{self, InnerPhaseExecutor};
use crate::metrics::{EvalPoint, RunMetrics, Stopwatch};
use crate::runtime::{Runtime, Tensors};
use crate::util::math;
use crate::worker::Worker;
use std::sync::Arc;

pub use stats::RoundStats;

/// Everything a finished run reports.
pub struct DilocoReport {
    pub metrics: RunMetrics,
    pub round_stats: Vec<RoundStats>,
    pub final_params: Tensors,
    /// Rounds in which each worker's outer gradient was dropped.
    pub drops_per_worker: Vec<usize>,
}

pub struct Coordinator {
    pub cfg: ExperimentConfig,
    rt: Arc<Runtime>,
    pub dataset: Dataset,
    evalset: EvalSet,
    /// Inner-phase executor (built once from `cfg.engine` against the
    /// run's peak worker count).
    exec: Box<dyn InnerPhaseExecutor>,
}

impl Coordinator {
    /// Build the data pipeline for `cfg` against an already-loaded runtime
    /// (runtimes are reused across bench variants — compilation is paid
    /// once per artifact set).
    pub fn new(cfg: ExperimentConfig, rt: Arc<Runtime>) -> anyhow::Result<Coordinator> {
        let mcfg = &rt.manifest.config;
        anyhow::ensure!(
            mcfg.name == cfg.model,
            "runtime holds {:?}, config wants {:?}",
            mcfg.name,
            cfg.model
        );
        let max_k = cfg.schedule.max_workers(cfg.rounds).max(cfg.workers);
        let dataset = Dataset::build(&cfg.data, max_k, mcfg.vocab_size, cfg.seed);
        let evalset = EvalSet::new(
            &dataset.holdout,
            mcfg.batch_size,
            mcfg.seq_len,
            cfg.eval_batches,
        );
        let exec = cfg.engine.build(max_k);
        Ok(Coordinator { cfg, rt, dataset, evalset, exec })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }

    /// The executor island phases dispatch through.
    pub fn engine(&self) -> &dyn InnerPhaseExecutor {
        self.exec.as_ref()
    }

    /// Mean nll / PPL of `params` on the fixed validation windows.
    pub fn evaluate(&self, params: &Tensors) -> anyhow::Result<EvalPoint> {
        let mut sum = 0.0;
        let mut count = 0.0;
        for b in self.evalset.batches() {
            let (s, c) = self.rt.eval_batch(params, &b.tokens, &b.targets)?;
            sum += s;
            count += c;
        }
        let mean_nll = sum / count;
        Ok(EvalPoint { step: 0, mean_nll, ppl: math::ppl(mean_nll) })
    }

    /// Merged token stream over all shards (pretraining / plain baselines
    /// train on the full dataset, like the paper's single-worker runs).
    pub fn merged_stream(&self) -> Vec<i32> {
        let mut s = Vec::new();
        for shard in &self.dataset.shards {
            s.extend_from_slice(shard);
        }
        s
    }

    /// Plain (non-DiLoCo) training for `steps` steps from `init`.
    /// Returns final params; logs losses/evals into `metrics`.
    pub fn plain_train(
        &self,
        init: Tensors,
        start_step: f64,
        steps: usize,
        metrics: &mut RunMetrics,
        eval_every: usize,
    ) -> anyhow::Result<Tensors> {
        let mcfg = &self.rt.manifest.config;
        let mut worker = Worker::new(
            usize::MAX,
            init,
            Tensors::zeros(&self.rt.manifest),
            BatchIter::new(
                self.merged_stream(),
                mcfg.batch_size,
                mcfg.seq_len,
                self.cfg.rng().child(999),
            ),
        );
        worker.step = start_step;
        let mut done = 0usize;
        while done < steps {
            let h = (steps - done).min(self.cfg.inner_steps.max(1));
            let phase = engine::run_inner_phase(
                self.exec.as_ref(),
                &self.rt,
                std::slice::from_mut(&mut worker),
                h,
            )?;
            metrics.phases.inner_compute_s += phase.total_wall_s();
            metrics
                .loss_curve
                .extend_from_slice(&phase.per_worker_losses[0]);
            done += h;
            let at_boundary = eval_every > 0
                && (done / self.cfg.inner_steps.max(1))
                    % eval_every == 0;
            if at_boundary || done >= steps {
                let _t = Stopwatch::new(&mut metrics.phases.eval_s);
                let mut p = self.evaluate(&worker.params)?;
                p.step = start_step as usize + done;
                metrics.eval_curve.push(p);
            }
        }
        metrics.sim_compute_seconds += worker.compute_seconds;
        Ok(worker.params)
    }

    /// Full DiLoCo run: pretrain warm start, then T rounds of Algorithm 1.
    pub fn run(&self) -> anyhow::Result<DilocoReport> {
        self.run_from(None)
    }

    /// As [`run`], but optionally starting from caller-provided parameters.
    /// A provided `init` is treated as *already pretrained* for
    /// `cfg.pretrain_steps` steps (shared warm start across bench
    /// variants): the pretrain phase is skipped but the workers' global
    /// step counter — and hence the baked inner-lr schedule — resumes
    /// from `pretrain_steps`.
    pub fn run_from(&self, init: Option<Tensors>) -> anyhow::Result<DilocoReport> {
        let cfg = &self.cfg;
        let mcfg = &self.rt.manifest.config;
        let mut metrics = RunMetrics::new(&format!(
            "diloco_k{}_h{}_{}",
            cfg.workers,
            cfg.inner_steps,
            cfg.outer_opt.name()
        ));
        let rng = cfg.rng();

        // θ(0): explicit init (already pretrained) or fresh init followed
        // by the pretraining phase.
        let global = match init {
            Some(p) => p,
            None => {
                let fresh = self.rt.init_params()?;
                if cfg.pretrain_steps > 0 {
                    self.plain_train(
                        fresh,
                        0.0,
                        cfg.pretrain_steps,
                        &mut metrics,
                        cfg.eval_every_rounds,
                    )?
                } else {
                    fresh
                }
            }
        };
        let mut global = global;

        // Worker pool sized to the schedule's maximum.
        let max_k = cfg.schedule.max_workers(cfg.rounds).max(1);
        let zeros = Tensors::zeros(&self.rt.manifest);
        let mut workers: Vec<Worker> = (0..max_k)
            .map(|i| {
                let shard = self.dataset.shards[i % self.dataset.shards.len()].clone();
                let mut w = Worker::new(
                    i,
                    global.clone(),
                    zeros.clone(),
                    BatchIter::new(
                        shard,
                        mcfg.batch_size,
                        mcfg.seq_len,
                        rng.child(100 + i as u64),
                    ),
                );
                w.step = cfg.pretrain_steps as f64;
                w
            })
            .collect();
        // Workers desynced by a dropped upload keep local params (Fig 8).
        let mut synced = vec![true; max_k];
        let mut drops_per_worker = vec![0usize; max_k];

        let mut net = SimNet::new(
            cfg.comm.bandwidth_bps,
            cfg.comm.latency_s,
            cfg.comm.drop_prob,
            rng.child(7),
        );
        let mut outer = opt::OuterOpt::new(&cfg.outer_opt, &zeros);
        let mut round_stats = Vec::with_capacity(cfg.rounds);
        let payload = self.rt.manifest.param_bytes() as u64;

        for t in 0..cfg.rounds {
            let k_t = cfg.schedule.workers_at(t, cfg.rounds).min(max_k).max(1);
            let active = &mut workers[..k_t];

            // Re-dispatch θ(t-1) to synced workers; desynced ones continue
            // from their own parameters.
            let mut starts: Vec<Tensors> = Vec::with_capacity(k_t);
            for w in active.iter_mut() {
                if synced[w.id] {
                    w.set_params(global.clone());
                }
                starts.push(w.params.clone());
            }

            // Inner phase: H steps per active worker, dispatched through
            // the engine (real threads under ParallelIslands). Losses are
            // averaged across workers per step index, folding in worker
            // order regardless of which island finished first.
            let phase =
                engine::run_inner_phase(self.exec.as_ref(), &self.rt, active, cfg.inner_steps)?;
            metrics.sim_compute_seconds += phase.max_compute_s();
            metrics.phases.inner_compute_s += phase.total_wall_s();
            for s in 0..cfg.inner_steps {
                let avg = phase.per_worker_losses.iter().map(|l| l[s]).sum::<f32>() / k_t as f32;
                metrics.loss_curve.push(avg);
            }

            // Communication phase: prune, upload (drops possible), average.
            let _outer_timer = Stopwatch::new(&mut metrics.phases.outer_opt_s);
            let mut received: Vec<Tensors> = Vec::with_capacity(k_t);
            let mut weights: Vec<f64> = Vec::with_capacity(k_t);
            let mut uploaded = vec![false; k_t];
            for (i, w) in active.iter_mut().enumerate() {
                let mut delta = starts[i].delta(&w.params);
                let bytes = if cfg.prune_frac > 0.0 {
                    let zeroed = prune::prune_sign(&mut delta, cfg.prune_frac);
                    prune::pruned_payload_bytes(delta.total_elements(), zeroed)
                } else {
                    payload
                };
                // k=1 "accelerating a single worker" (Fig 9): the outer
                // step is local, nothing crosses the fabric. Uploads are
                // keyed by (round, worker) so drop outcomes don't depend
                // on arrival order.
                let ok = if k_t == 1 {
                    true
                } else {
                    net.try_send(bytes, Direction::Up, t, w.id)
                };
                if ok {
                    uploaded[i] = true;
                    received.push(delta);
                    weights.push(if cfg.weighted_average && cfg.data.non_iid {
                        self.dataset.shard_doc_counts
                            [w.id % self.dataset.shard_doc_counts.len()]
                            as f64
                    } else {
                        1.0
                    });
                } else {
                    drops_per_worker[w.id] += 1;
                }
            }

            if !received.is_empty() {
                let avg = average::weighted_average(&received, &weights);
                round_stats.push(stats::round_stats(t, &received, &avg));
                outer.step(&mut global, &avg);
                anyhow::ensure!(
                    global.all_finite(),
                    "outer step produced non-finite parameters at round {t}"
                );
            }

            // Download: workers that communicated get θ(t); others stay
            // desynced until their next successful round.
            for (i, w) in active.iter().enumerate() {
                if uploaded[i] {
                    if k_t > 1 {
                        net.send_reliable(payload, Direction::Down);
                    }
                    synced[w.id] = true;
                } else {
                    synced[w.id] = false;
                }
            }
            net.end_round();
            drop(_outer_timer);

            // Evaluation of the *global* model.
            let at_eval = cfg.eval_every_rounds > 0
                && (t + 1) % cfg.eval_every_rounds == 0;
            if at_eval || t + 1 == cfg.rounds {
                let _t = Stopwatch::new(&mut metrics.phases.eval_s);
                let mut p = self.evaluate(&global)?;
                p.step = cfg.pretrain_steps + (t + 1) * cfg.inner_steps;
                metrics.eval_curve.push(p);
            }
        }

        let cs = net.stats();
        metrics.comm_bytes = cs.total_bytes();
        metrics.comm_bytes_up = cs.bytes_up;
        metrics.comm_messages = cs.messages;
        metrics.comm_dropped = cs.dropped;
        metrics.sim_comm_seconds = cs.sim_comm_seconds;

        Ok(DilocoReport {
            metrics,
            round_stats,
            final_params: global,
            drops_per_worker,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ComputeSchedule, OuterOptConfig};

    fn runtime() -> Option<Arc<Runtime>> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("nano.manifest.json")
            .exists()
            .then(|| Arc::new(Runtime::load(dir, "nano").unwrap()))
    }

    fn fast_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::paper_default(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
            "nano",
        );
        cfg.workers = 2;
        cfg.schedule = ComputeSchedule::Constant(2);
        cfg.inner_steps = 5;
        cfg.rounds = 2;
        cfg.pretrain_steps = 5;
        cfg.eval_batches = 1;
        cfg.data.n_docs = 60;
        cfg.data.doc_len = 120;
        cfg
    }

    #[test]
    fn diloco_runs_and_reports() {
        let Some(rt) = runtime() else { return };
        let coord = Coordinator::new(fast_cfg(), rt).unwrap();
        let report = coord.run().unwrap();
        // 5 pretrain + 2 rounds × 5 inner steps of loss points.
        assert_eq!(report.metrics.loss_curve.len(), 15);
        assert_eq!(report.round_stats.len(), 2);
        assert!(report.metrics.final_ppl().is_finite());
        assert!(report.final_params.all_finite());
        // Communication: 2 workers × 2 rounds, up + down each.
        assert_eq!(report.metrics.comm_messages, 8);
        assert_eq!(
            report.metrics.comm_bytes,
            8 * coord.runtime().manifest.param_bytes() as u64
        );
    }

    #[test]
    fn single_worker_has_zero_comm() {
        let Some(rt) = runtime() else { return };
        let mut cfg = fast_cfg();
        cfg.workers = 1;
        cfg.schedule = ComputeSchedule::Constant(1);
        let coord = Coordinator::new(cfg, rt).unwrap();
        let report = coord.run().unwrap();
        assert_eq!(report.metrics.comm_bytes, 0);
        assert_eq!(report.metrics.comm_messages, 0);
        assert_eq!(report.round_stats.len(), 2); // outer steps still happen
    }

    #[test]
    fn full_drop_leaves_global_unchanged() {
        let Some(rt) = runtime() else { return };
        let mut cfg = fast_cfg();
        cfg.comm.drop_prob = 1.0;
        cfg.pretrain_steps = 0;
        let coord = Coordinator::new(cfg, rt.clone()).unwrap();
        let init = rt.init_params().unwrap();
        let report = coord.run_from(Some(init.clone())).unwrap();
        // Every upload dropped ⇒ no outer step ever ⇒ global == init.
        assert_eq!(report.final_params, init);
        assert!(report.round_stats.is_empty());
        assert_eq!(report.drops_per_worker.iter().sum::<usize>(), 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let Some(rt) = runtime() else { return };
        let r1 = Coordinator::new(fast_cfg(), rt.clone())
            .unwrap()
            .run()
            .unwrap();
        let r2 = Coordinator::new(fast_cfg(), rt).unwrap().run().unwrap();
        assert_eq!(r1.metrics.loss_curve, r2.metrics.loss_curve);
        assert_eq!(r1.final_params, r2.final_params);
    }

    #[test]
    fn schedule_controls_active_workers() {
        let Some(rt) = runtime() else { return };
        let mut cfg = fast_cfg();
        cfg.schedule = ComputeSchedule::Step { first: 1, second: 2 };
        cfg.rounds = 2;
        let coord = Coordinator::new(cfg, rt).unwrap();
        let report = coord.run().unwrap();
        // Round 0: k=1 (no fabric traffic), round 1: k=2 (2 up + 2 down).
        assert_eq!(report.metrics.comm_messages, 4);
    }
}
