//! Outer-gradient averaging (Algorithm 1 line 12).
//!
//! Uniform mean in the i.i.d. regime; shard-size-weighted mean in the
//! non-i.i.d. regime (paper §6.1 "Weighted Average of Outer Gradients":
//! at k=64 cluster imbalance is striking and weighting by example count
//! is beneficial).

use crate::runtime::Tensors;
use crate::util::math;

/// Weighted average of deltas. `weights` need not be normalized; they are
/// divided by their sum. Panics on empty input or all-zero weights.
pub fn weighted_average(deltas: &[Tensors], weights: &[f64]) -> Tensors {
    assert!(!deltas.is_empty(), "no outer gradients to average");
    assert_eq!(deltas.len(), weights.len());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "all-zero averaging weights");
    let mut acc = deltas[0].clone();
    acc.scale((weights[0] / total) as f32);
    for (d, &w) in deltas[1..].iter().zip(&weights[1..]) {
        acc.axpy((w / total) as f32, d);
    }
    acc
}

/// Uniform average.
pub fn average(deltas: &[Tensors]) -> Tensors {
    weighted_average(deltas, &vec![1.0; deltas.len()])
}

/// Uniform average over borrowed tensor trees — the consensus of a
/// (possibly non-contiguous) roster of replicas under elastic
/// membership. Performs the *same* scalar operations in the same order
/// as [`average`], so a contiguous roster reproduces it bitwise.
pub fn uniform_average_refs(ts: &[&Tensors]) -> Tensors {
    assert!(!ts.is_empty(), "no replicas to average");
    let total = ts.len() as f64;
    let mut acc = ts[0].clone();
    acc.scale((1.0 / total) as f32);
    for t in &ts[1..] {
        acc.axpy((1.0 / total) as f32, t);
    }
    acc
}

/// Weighted average of flat fragment payloads — the streaming fabric's
/// per-fragment reduction. Performs the *same* scalar operations in the
/// same order as [`weighted_average`] (normalize, scale the first
/// payload, axpy the rest), so a single fragment covering the whole
/// parameter space reproduces the monolithic average bitwise — the
/// property tests below pin that equivalence.
#[deprecated(
    since = "0.10.0",
    note = "use `coordinator::aggregate::WeightedMean::mean` (the Aggregator API)"
)]
pub fn weighted_average_flat(payloads: &[Vec<f32>], weights: &[f64]) -> Vec<f32> {
    let mut norm = Vec::new();
    let mut out = Vec::new();
    fused_weighted_mean_into(payloads, weights, &mut norm, &mut out);
    out
}

/// Element block width for the fused reduction: payloads are walked one
/// block at a time so the accumulator block stays cache-hot across all k
/// payload passes, instead of streaming the full accumulator k times.
const BLOCK: usize = 512;

/// Allocation-free fused weighted average — the hot-path kernel every
/// other signature (and the [`crate::coordinator::aggregate::WeightedMean`]
/// aggregator) delegates to. `norm` and `out` are caller-provided
/// scratch (leased from [`super::scratch::RoundScratch`] on the round
/// loop); both are cleared before use, so reuse across rounds cannot
/// leak stale values.
///
/// **Bitwise contract:** for each element `i` the scalar operations are
/// `out[i] = payload₀[i] * w₀`, then `out[i] += wⱼ * payloadⱼ[i]` for
/// j = 1..k in payload order — exactly the per-element sequence of the
/// legacy scale-then-axpy passes (elements are independent, so blocking
/// the element loop cannot reorder any individual element's arithmetic).
/// The block structure only changes *memory traversal*, k passes over a
/// cache-resident block instead of k passes over the whole fragment; the
/// property tests pin equality with the multi-pass reference bit for
/// bit. Float-op *reordering* lives only in the opt-in
/// [`weighted_average_pairwise_into`].
///
/// This file is one of the two D4-audited float-fold homes (DESIGN.md
/// §15), which is why the kernel body — including the `weights` total —
/// lives here rather than in `coordinator/aggregate.rs`.
pub fn fused_weighted_mean_into<P: AsRef<[f32]>>(
    payloads: &[P],
    weights: &[f64],
    norm: &mut Vec<f32>,
    out: &mut Vec<f32>,
) {
    assert!(!payloads.is_empty(), "no fragment payloads to average");
    assert_eq!(payloads.len(), weights.len());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "all-zero averaging weights");
    norm.clear();
    norm.extend(weights.iter().map(|&w| (w / total) as f32));
    let first = payloads[0].as_ref();
    let n = first.len();
    out.clear();
    out.resize(n, 0.0);
    for p in payloads {
        assert_eq!(p.as_ref().len(), n, "payload arity");
    }
    let mut start = 0usize;
    while start < n {
        let end = (start + BLOCK).min(n);
        let acc = &mut out[start..end];
        // out[i] = p₀[i] * w₀ — same scalar product as scaling a copy.
        for (o, &x) in acc.iter_mut().zip(&first[start..end]) {
            *o = x * norm[0];
        }
        for (p, &w) in payloads[1..].iter().zip(&norm[1..]) {
            math::axpy(acc, w, &p.as_ref()[start..end]);
        }
        start = end;
    }
}

/// Legacy name for [`fused_weighted_mean_into`] — a zero-cost delegating
/// shim kept for one release so out-of-tree callers migrate at their own
/// pace. Bitwise-identical by construction; the shim property test pins
/// it.
#[deprecated(
    since = "0.10.0",
    note = "use `coordinator::aggregate::Aggregator` / `WeightedMean::mean_into`"
)]
pub fn weighted_average_into<P: AsRef<[f32]>>(
    payloads: &[P],
    weights: &[f64],
    norm: &mut Vec<f32>,
    out: &mut Vec<f32>,
) {
    fused_weighted_mean_into(payloads, weights, norm, out);
}

/// Opt-in (`[engine] fast_math = true`) pairwise-tree reduction across
/// the k payloads: halves are averaged recursively and combined, so the
/// addition order differs from the sequential fold — **not** bitwise
/// with the default path, but tighter error growth (O(log k) vs O(k))
/// and a shorter dependence chain. Tolerance-tested against the scalar
/// reference; golden traces require `fast_math = false`. Allocates
/// O(log k) temporaries per call (documented exception to the
/// zero-allocation steady state — the payload buffers dwarf them).
pub fn weighted_average_pairwise_into<P: AsRef<[f32]>>(
    payloads: &[P],
    weights: &[f64],
    norm: &mut Vec<f32>,
    out: &mut Vec<f32>,
) {
    assert!(!payloads.is_empty(), "no fragment payloads to average");
    assert_eq!(payloads.len(), weights.len());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "all-zero averaging weights");
    norm.clear();
    norm.extend(weights.iter().map(|&w| (w / total) as f32));
    let n = payloads[0].as_ref().len();
    for p in payloads {
        assert_eq!(p.as_ref().len(), n, "payload arity");
    }
    let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_ref()).collect();
    out.clear();
    out.resize(n, 0.0);
    pairwise_sum(&refs, norm, out);
}

/// out[i] = Σⱼ wⱼ·payloadⱼ[i] over `payloads`, combining halves
/// pairwise. Leaf runs (≤ 2 payloads) fold directly.
fn pairwise_sum(payloads: &[&[f32]], w: &[f32], out: &mut [f32]) {
    debug_assert!(!payloads.is_empty());
    if payloads.len() <= 2 {
        for (o, &x) in out.iter_mut().zip(payloads[0]) {
            *o = x * w[0];
        }
        if let Some(p) = payloads.get(1) {
            math::axpy(out, w[1], p);
        }
        return;
    }
    let mid = payloads.len() / 2;
    let mut right = vec![0.0f32; out.len()];
    pairwise_sum(&payloads[..mid], &w[..mid], out);
    pairwise_sum(&payloads[mid..], &w[mid..], &mut right);
    math::add_assign(out, &right);
}

/// As [`weighted_average_flat`], over borrowed payload slices — the
/// sync-topology mixing step ([`crate::comm::topology`]) averages the
/// same wire payloads once per receiving replica, so it borrows instead
/// of cloning. Scalar operations and their order are identical to
/// [`weighted_average`] / [`weighted_average_flat`]; the topology
/// property tests pin the bitwise equivalence (ring row == star row ⇒
/// ring average == star average, bit for bit).
///
/// ```
/// #![allow(deprecated)]
/// use diloco::coordinator::average::weighted_average_refs;
///
/// let a = [0.0f32, 2.0];
/// let b = [4.0f32, 6.0];
/// let avg = weighted_average_refs(&[&a, &b], &[1.0, 1.0]);
/// assert_eq!(avg, vec![2.0, 4.0]);
/// ```
#[deprecated(
    since = "0.10.0",
    note = "use `coordinator::aggregate::WeightedMean::mean` (the Aggregator API)"
)]
pub fn weighted_average_refs(payloads: &[&[f32]], weights: &[f64]) -> Vec<f32> {
    let mut norm = Vec::new();
    let mut out = Vec::new();
    fused_weighted_mean_into(payloads, weights, &mut norm, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn t(vals: &[f32]) -> Tensors {
        Tensors::from_raw(vec![vals.to_vec()])
    }

    /// Non-deprecated convenience over the fused kernel for the tests
    /// below (the production owned-payload entry point is now
    /// `aggregate::WeightedMean`).
    fn flat_mean(payloads: &[Vec<f32>], weights: &[f64]) -> Vec<f32> {
        let mut norm = Vec::new();
        let mut out = Vec::new();
        fused_weighted_mean_into(payloads, weights, &mut norm, &mut out);
        out
    }

    #[test]
    fn uniform_mean() {
        let avg = average(&[t(&[1.0, 2.0]), t(&[3.0, 4.0])]);
        assert_eq!(avg.iter_flat().collect::<Vec<f32>>(), vec![2.0, 3.0]);
    }

    #[test]
    fn weights_normalize() {
        let avg = weighted_average(&[t(&[0.0]), t(&[10.0])], &[3.0, 1.0]);
        assert!((avg.iter_flat().next().unwrap() - 2.5).abs() < 1e-6);
    }

    #[test]
    fn single_delta_is_identity() {
        let d = t(&[1.5, -2.5]);
        let avg = average(&[d.clone()]);
        assert_eq!(avg, d);
    }

    #[test]
    fn uniform_average_refs_matches_average_bitwise() {
        // The churn consensus path must be the same arithmetic as the
        // contiguous-slice consensus it replaced.
        let a = t(&[1.0, 2.0, 3.0]);
        let b = t(&[0.1, -5.0, 6.5]);
        let c = t(&[-1.0, 0.5, 2.5]);
        let owned = average(&[a.clone(), b.clone(), c.clone()]);
        let by_ref = uniform_average_refs(&[&a, &b, &c]);
        for (x, y) in owned.iter_flat().zip(by_ref.iter_flat()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic]
    fn empty_input_panics() {
        average(&[]);
    }

    #[test]
    #[should_panic]
    fn zero_weights_panic() {
        weighted_average(&[t(&[1.0])], &[0.0]);
    }

    #[test]
    fn prop_permutation_invariant() {
        check("uniform average is permutation-invariant", 50, |g| {
            let n = g.usize_in(2..6);
            let len = g.usize_in(1..30);
            let deltas: Vec<Tensors> = (0..n)
                .map(|_| {
                    let mut v = g.f32_vec(len..len + 1, 3.0);
                    v.resize(len, 0.0);
                    t(&v)
                })
                .collect();
            let mut reversed = deltas.clone();
            reversed.reverse();
            let a = average(&deltas);
            let b = average(&reversed);
            for (x, y) in a.iter_flat().zip(b.iter_flat()) {
                assert!((x - y).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn prop_single_fragment_average_matches_legacy_bitwise() {
        // The streaming fabric's P = 1 path must be indistinguishable
        // from the monolithic average — exact bit equality, not toleranced.
        check("flat average (P=1) == legacy average bitwise", 60, |g| {
            let k = g.usize_in(1..6);
            let len = g.usize_in(1..40);
            let deltas: Vec<Tensors> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(len..len + 1, 3.0);
                    v.resize(len, 0.0);
                    t(&v)
                })
                .collect();
            let weights: Vec<f64> =
                (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
            let legacy = weighted_average(&deltas, &weights);
            let payloads: Vec<Vec<f32>> = deltas
                .iter()
                .map(|d| d.iter_flat().collect())
                .collect();
            let flat = flat_mean(&payloads, &weights);
            let legacy_flat: Vec<f32> = legacy.iter_flat().collect();
            assert_eq!(flat.len(), legacy_flat.len());
            for (a, b) in flat.iter().zip(&legacy_flat) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
            }
        });
    }

    #[test]
    fn prop_fragmented_average_assembles_to_legacy_bitwise() {
        // Splitting the parameter space into P fragments, averaging each
        // independently, and reassembling must equal the monolithic
        // average bitwise when every fragment has the same contributors.
        use crate::comm::fragment::FragmentPlan;
        check("per-fragment average assembles to legacy", 40, |g| {
            let k = g.usize_in(1..5);
            let len = g.usize_in(2..40);
            let p = g.usize_in(1..8);
            let deltas: Vec<Tensors> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(len..len + 1, 2.0);
                    v.resize(len, 0.0);
                    t(&v)
                })
                .collect();
            let weights: Vec<f64> =
                (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
            let legacy = weighted_average(&deltas, &weights);
            let plan = FragmentPlan::for_tensors(&deltas[0], p);
            let mut assembled = deltas[0].clone();
            assembled.scale(0.0);
            for f in 0..plan.n_fragments() {
                let payloads: Vec<Vec<f32>> =
                    deltas.iter().map(|d| plan.extract(d, f)).collect();
                let avg = flat_mean(&payloads, &weights);
                plan.scatter(&avg, f, &mut assembled);
            }
            for (a, b) in assembled.iter_flat().zip(legacy.iter_flat()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
            }
        });
    }

    /// The PR-5 multi-pass reference: copy payload 0, scale it, then one
    /// full axpy pass per remaining payload — the arithmetic the fused
    /// block-walking kernel must reproduce bit for bit.
    fn multipass_reference(payloads: &[&[f32]], weights: &[f64]) -> Vec<f32> {
        let total: f64 = weights.iter().sum();
        let mut acc = payloads[0].to_vec();
        math::scale_scalar(&mut acc, (weights[0] / total) as f32);
        for (p, &w) in payloads[1..].iter().zip(&weights[1..]) {
            math::axpy_scalar(&mut acc, (w / total) as f32, p);
        }
        acc
    }

    use crate::util::math;

    #[test]
    fn prop_fused_average_matches_multipass_bitwise() {
        // Block-walking the element space with dirty reused scratch must
        // equal the scalar multi-pass fold bitwise at every length —
        // including lengths straddling the BLOCK boundary and odd tails.
        check("fused weighted_average_into == multipass bitwise", 60, |g| {
            let k = g.usize_in(1..7);
            let n = g.usize_in(1..40) * g.usize_in(1..40);
            let payloads: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(n..n + 1, 3.0);
                    v.resize(n, 0.0);
                    v
                })
                .collect();
            let weights: Vec<f64> = (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
            let refs: Vec<&[f32]> =
                payloads.iter().map(|p| p.as_slice()).collect();
            let want = multipass_reference(&refs, &weights);
            let mut norm = vec![f32::NAN; 2]; // dirty scratch
            let mut out = vec![f32::NAN; n + 3];
            super::fused_weighted_mean_into(&payloads, &weights, &mut norm, &mut out);
            assert_eq!(out.len(), want.len());
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
            }
        });
    }

    #[test]
    fn fused_average_covers_block_boundaries() {
        // Exactly BLOCK, BLOCK±1, and a multi-block length.
        for n in [super::BLOCK - 1, super::BLOCK, super::BLOCK + 1, 3 * super::BLOCK + 5] {
            let payloads: Vec<Vec<f32>> = (0..3)
                .map(|j| (0..n).map(|i| (i + j) as f32 * 0.125 - 7.0).collect())
                .collect();
            let weights = [1.0, 2.5, 0.25];
            let refs: Vec<&[f32]> =
                payloads.iter().map(|p| p.as_slice()).collect();
            let want = multipass_reference(&refs, &weights);
            let got = flat_mean(&payloads, &weights);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn prop_pairwise_average_within_tolerance_of_sequential() {
        // The fast_math tree reduction reorders additions — not bitwise,
        // but it must stay within float-rounding distance of the
        // sequential fold (both are exact in infinite precision).
        check("pairwise average ≈ sequential average", 50, |g| {
            let k = g.usize_in(1..12);
            let n = g.usize_in(1..200);
            let payloads: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(n..n + 1, 3.0);
                    v.resize(n, 0.0);
                    v
                })
                .collect();
            let weights: Vec<f64> = (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
            let seq = flat_mean(&payloads, &weights);
            let mut norm = Vec::new();
            let mut out = Vec::new();
            super::weighted_average_pairwise_into(
                &payloads, &weights, &mut norm, &mut out,
            );
            assert_eq!(out.len(), seq.len());
            let mag: f64 = payloads
                .iter()
                .flat_map(|p| p.iter())
                .map(|&x| x.abs() as f64)
                .fold(0.0, f64::max);
            let tol = 1e-5 * (1.0 + mag) * k as f64;
            for (a, b) in out.iter().zip(&seq) {
                assert!(
                    ((a - b) as f64).abs() <= tol,
                    "pairwise {a} vs sequential {b} (tol {tol})"
                );
            }
        });
    }

    #[test]
    fn pairwise_average_of_one_or_two_is_bitwise() {
        // Leaf runs fold exactly like the sequential path, so k ≤ 2
        // pairwise results are bitwise even under fast_math.
        for k in [1usize, 2] {
            let payloads: Vec<Vec<f32>> = (0..k)
                .map(|j| (0..37).map(|i| (i * (j + 1)) as f32 * 0.3 - 4.0).collect())
                .collect();
            let weights: Vec<f64> = (0..k).map(|j| 1.0 + j as f64).collect();
            let seq = flat_mean(&payloads, &weights);
            let mut norm = Vec::new();
            let mut out = Vec::new();
            super::weighted_average_pairwise_into(
                &payloads, &weights, &mut norm, &mut out,
            );
            for (a, b) in out.iter().zip(&seq) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    #[allow(deprecated)]
    fn prop_deprecated_shims_delegate_bitwise() {
        // The three legacy names are pure delegating shims over the
        // fused kernel: same bits, every length, dirty scratch included.
        check("deprecated trio == fused kernel bitwise", 40, |g| {
            let k = g.usize_in(1..6);
            let n = g.usize_in(1..60);
            let payloads: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(n..n + 1, 3.0);
                    v.resize(n, 0.0);
                    v
                })
                .collect();
            let weights: Vec<f64> = (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
            let want = flat_mean(&payloads, &weights);
            let flat = weighted_average_flat(&payloads, &weights);
            let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
            let by_ref = weighted_average_refs(&refs, &weights);
            let mut norm = vec![f32::NAN; 1];
            let mut into = vec![f32::NAN; n + 2];
            weighted_average_into(&payloads, &weights, &mut norm, &mut into);
            for got in [&flat, &by_ref, &into] {
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
                }
            }
        });
    }

    #[test]
    fn prop_average_within_bounds() {
        check("average lies within elementwise min/max", 50, |g| {
            let len = g.usize_in(1..20);
            let k = g.usize_in(2..5);
            let deltas: Vec<Tensors> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(len..len + 1, 2.0);
                    v.resize(len, 0.0);
                    t(&v)
                })
                .collect();
            let avg: Vec<f32> = average(&deltas).iter_flat().collect();
            for i in 0..len {
                let col: Vec<f32> =
                    deltas.iter().map(|d| d.leaves()[0][i]).collect();
                let lo = col.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                assert!(
                    avg[i] >= lo - 1e-5 && avg[i] <= hi + 1e-5,
                    "avg {} outside [{lo}, {hi}]",
                    avg[i]
                );
            }
        });
    }
}
