//! Deterministic Byzantine attacker model (`[adversary]` config).
//!
//! A fixed subset of the worker pool is compromised for the whole run;
//! each attacker corrupts its outer delta **after the inner phase and
//! before the wire** — the honest inner training, byte billing, drop
//! schedule, and topology routing are all untouched, so byte bills are
//! invariant under both attack and aggregator choice (a bench hard
//! assert).
//!
//! # Keying (DESIGN.md §16)
//!
//! Like `[speed]` jitter and `[churn]` rosters, everything here is a
//! pure function of `(seed, round, worker)`:
//!
//! - the attacker **set** is `Rng::new(seed).child(ADVERSARY_STREAM)`
//!   choosing ⌊fraction·pool⌋ distinct ids once per run;
//! - per-round **draws** (the scaled-noise elements) come from
//!   `Rng::new(seed).child(ADVERSARY_STREAM).child(worker).child(round)`,
//!   so they replay bit-identically across sequential/parallel engines
//!   and across save→resume, regardless of what any other stream
//!   consumed.
//!
//! The only cross-round state is the stale-replay swap buffer, which is
//! serialized in `TrainState` (v4) so resumed runs replay the same
//! stale deltas.

use crate::config::{AdversaryConfig, AttackKind, ADVERSARY_STREAM};
use crate::runtime::Tensors;
use crate::util::rng::Rng;

/// Per-run attacker state: the compromised id set plus the stale-replay
/// swap buffers. Owned by the coordinator round loop.
pub struct Adversary {
    attack: AttackKind,
    scale: f64,
    seed: u64,
    member: Vec<bool>,
    ids: Vec<usize>,
    stale: Vec<Option<Tensors>>,
}

impl Adversary {
    /// Derive the run's attacker set from the config (see module docs
    /// for the keying). `pool` is the full worker pool size — attacker
    /// identity is independent of churn rosters, so a parked-and-
    /// rejoined attacker stays an attacker.
    pub fn new(cfg: &AdversaryConfig, seed: u64, pool: usize) -> Adversary {
        let ids = cfg.attacker_ids(seed, pool);
        let mut member = vec![false; pool];
        for &w in &ids {
            member[w] = true;
        }
        Adversary {
            attack: cfg.attack,
            scale: cfg.scale,
            seed,
            member,
            ids,
            stale: (0..pool).map(|_| None).collect(),
        }
    }

    /// The sorted compromised worker ids.
    pub fn attacker_ids(&self) -> &[usize] {
        &self.ids
    }

    pub fn is_attacker(&self, wid: usize) -> bool {
        self.member.get(wid).copied().unwrap_or(false)
    }

    /// Corrupt `delta` in place if `wid` is compromised; returns whether
    /// a corruption was applied. Must be called exactly once per
    /// (round, synced worker), in any order — no attack depends on call
    /// order within a round (stale-replay state is per-worker).
    pub fn corrupt(&mut self, round: usize, wid: usize, delta: &mut Tensors) -> bool {
        if !self.is_attacker(wid) {
            return false;
        }
        match self.attack {
            AttackKind::FlipSign => delta.scale(-(self.scale as f32)),
            AttackKind::ScaledNoise => {
                let mut rng = Rng::new(self.seed)
                    .child(ADVERSARY_STREAM)
                    .child(wid as u64)
                    .child(round as u64);
                let s = self.scale;
                delta.for_each_mut(|x| *x = (s * rng.normal()) as f32);
            }
            AttackKind::NanBomb => delta.for_each_mut(|x| *x = f32::NAN),
            AttackKind::StaleReplay => match self.stale[wid].as_mut() {
                // Ship the previous corrupted-round delta, keep the
                // current one for next time.
                Some(prev) => std::mem::swap(delta, prev),
                // First attack round: nothing stale to replay yet —
                // ship the honest delta and remember it.
                None => self.stale[wid] = Some(delta.clone()),
            },
        }
        true
    }

    /// Stale-replay buffers for checkpointing: `(worker id, parked
    /// delta)` pairs in ascending id order. Empty unless the attack is
    /// stale-replay and at least one attacker has synced.
    pub fn stale_entries(&self) -> Vec<(usize, Tensors)> {
        let mut out = Vec::new();
        for (w, slot) in self.stale.iter().enumerate() {
            if let Some(t) = slot {
                out.push((w, t.clone()));
            }
        }
        out
    }

    /// Restore checkpointed stale-replay buffers (inverse of
    /// [`stale_entries`](Self::stale_entries)). Ids beyond the pool are
    /// ignored (roster shrank between save and resume is rejected
    /// upstream by the resume config checks).
    pub fn restore_stale(&mut self, entries: Vec<(usize, Tensors)>) {
        for (w, t) in entries {
            if w < self.stale.len() {
                self.stale[w] = Some(t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AdversaryConfig;

    fn t(vals: &[f32]) -> Tensors {
        Tensors::from_raw(vec![vals.to_vec()])
    }

    fn cfg(attack: AttackKind, fraction: f64, scale: f64) -> AdversaryConfig {
        AdversaryConfig { attack, fraction, scale }
    }

    #[test]
    fn attacker_set_is_seed_deterministic_and_sized_by_floor() {
        let c = cfg(AttackKind::FlipSign, 0.25, 1.0);
        let a = Adversary::new(&c, 7, 8);
        let b = Adversary::new(&c, 7, 8);
        assert_eq!(a.attacker_ids(), b.attacker_ids());
        assert_eq!(a.attacker_ids().len(), 2); // floor(0.25 * 8)
        assert!(a.attacker_ids().windows(2).all(|w| w[0] < w[1]));
        assert!(a.attacker_ids().iter().all(|&w| w < 8));
        // A different seed picks a different set (with overwhelming
        // probability for this (pool, n) — pinned for these constants).
        let other = Adversary::new(&c, 8, 8);
        assert_ne!(a.attacker_ids(), other.attacker_ids());
        // floor(0.3 * 4) = 1
        assert_eq!(Adversary::new(&cfg(AttackKind::NanBomb, 0.3, 1.0), 1, 4)
            .attacker_ids()
            .len(), 1);
    }

    #[test]
    fn honest_workers_pass_through_untouched() {
        let c = cfg(AttackKind::NanBomb, 0.25, 1.0);
        let mut adv = Adversary::new(&c, 3, 8);
        let honest = (0..8).find(|&w| !adv.is_attacker(w)).unwrap();
        let mut d = t(&[1.0, -2.0]);
        assert!(!adv.corrupt(0, honest, &mut d));
        assert_eq!(d, t(&[1.0, -2.0]));
    }

    #[test]
    fn flip_sign_scales_and_negates() {
        let c = cfg(AttackKind::FlipSign, 0.5, 2.0);
        let mut adv = Adversary::new(&c, 3, 2);
        let w = adv.attacker_ids()[0];
        let mut d = t(&[1.0, -2.0, 0.5]);
        assert!(adv.corrupt(0, w, &mut d));
        assert_eq!(d, t(&[-2.0, 4.0, -1.0]));
    }

    #[test]
    fn nan_bomb_poisons_every_element() {
        let c = cfg(AttackKind::NanBomb, 0.5, 1.0);
        let mut adv = Adversary::new(&c, 3, 2);
        let w = adv.attacker_ids()[0];
        let mut d = t(&[1.0, -2.0]);
        adv.corrupt(0, w, &mut d);
        assert!(d.iter_flat().all(|x| x.is_nan()));
    }

    #[test]
    fn scaled_noise_is_keyed_by_seed_round_worker() {
        let c = cfg(AttackKind::ScaledNoise, 0.5, 3.0);
        let mut a = Adversary::new(&c, 11, 4);
        let mut b = Adversary::new(&c, 11, 4);
        let w = a.attacker_ids()[0];
        let mut da = t(&[1.0, 2.0, 3.0]);
        let mut db = t(&[9.0, 9.0, 9.0]); // input-independent replacement
        a.corrupt(5, w, &mut da);
        b.corrupt(5, w, &mut db);
        assert_eq!(da, db, "same (seed, round, worker) must draw the same noise");
        let mut dc = t(&[1.0, 2.0, 3.0]);
        b.corrupt(6, w, &mut dc);
        assert_ne!(da, dc, "different rounds draw different noise");
        assert!(da.all_finite());
    }

    #[test]
    fn stale_replay_ships_previous_and_parks_current() {
        let c = cfg(AttackKind::StaleReplay, 0.5, 1.0);
        let mut adv = Adversary::new(&c, 3, 2);
        let w = adv.attacker_ids()[0];
        // Round 0: nothing parked — ships the honest delta, parks it.
        let mut d0 = t(&[1.0]);
        adv.corrupt(0, w, &mut d0);
        assert_eq!(d0, t(&[1.0]));
        // Round 1: ships round 0's delta, parks round 1's.
        let mut d1 = t(&[2.0]);
        adv.corrupt(1, w, &mut d1);
        assert_eq!(d1, t(&[1.0]));
        // Round 2: ships round 1's.
        let mut d2 = t(&[3.0]);
        adv.corrupt(2, w, &mut d2);
        assert_eq!(d2, t(&[2.0]));
    }

    #[test]
    fn stale_buffers_roundtrip_through_entries() {
        let c = cfg(AttackKind::StaleReplay, 0.5, 1.0);
        let mut adv = Adversary::new(&c, 3, 4);
        let ids: Vec<usize> = adv.attacker_ids().to_vec();
        for (k, &w) in ids.iter().enumerate() {
            let mut d = t(&[k as f32 + 1.0]);
            adv.corrupt(0, w, &mut d);
        }
        let entries = adv.stale_entries();
        assert_eq!(entries.len(), ids.len());
        assert!(entries.windows(2).all(|e| e[0].0 < e[1].0));
        // A fresh adversary restored from the entries replays the same
        // parked deltas.
        let mut resumed = Adversary::new(&c, 3, 4);
        resumed.restore_stale(entries);
        let w = ids[0];
        let mut a = t(&[42.0]);
        let mut b = t(&[42.0]);
        adv.corrupt(1, w, &mut a);
        resumed.corrupt(1, w, &mut b);
        assert_eq!(a, b);
    }
}
