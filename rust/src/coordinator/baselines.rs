//! The paper's baselines (Table 2 / Fig 2).
//!
//! * **Plain baseline** — single worker, batch B, N steps
//!   ([`Coordinator::plain_train`] drives this one).
//! * **8× batch, data parallelism** — k simulated DP replicas: each step,
//!   every replica computes gradients on its own batch (`grad_step`
//!   artifact), gradients are all-reduced (averaged — billed as k messages
//!   per step on the fabric), and one `apply_update` applies AdamW.
//!   Same wall-clock as the baseline (replicas run in parallel), k× the
//!   compute & data, k×N communication.
//! * **8× batch, microbatching** — numerically identical update (gradient
//!   accumulation over k microbatches on one island): zero communication
//!   but k× the wall-clock. Table 2 rows 2–3 share one implementation
//!   here, differing only in how simulated time and bytes are billed.

use crate::comm::{Direction, SimNet};
use crate::coordinator::Coordinator;
use crate::engine::{InnerPhaseExecutor as _, IslandOutput, IslandTask};
use crate::metrics::{RunMetrics, Stopwatch};
use crate::runtime::{Runtime, Tensors, ValueView};
use crate::util::math;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BigBatchMode {
    /// k islands in parallel; gradients cross the fabric each step.
    DataParallel,
    /// One island accumulates k microbatches serially; no communication.
    Microbatch,
}

/// Train with an effective batch of `k × B` for `steps` optimizer updates.
pub fn run_big_batch(
    coord: &Coordinator,
    k: usize,
    steps: usize,
    mode: BigBatchMode,
    init: Tensors,
    start_step: f64,
) -> anyhow::Result<RunMetrics> {
    let rt = coord.runtime();
    let mcfg = &rt.manifest.config;
    let n_leaves = rt.manifest.params.len();
    let label = match mode {
        BigBatchMode::DataParallel => format!("dp_{k}x_batch"),
        BigBatchMode::Microbatch => format!("microbatch_{k}x_batch"),
    };
    let mut metrics = RunMetrics::new(&label);
    let cfg = &coord.cfg;

    // k independent data streams over the merged corpus (the big batch is
    // still i.i.d. data, only bigger).
    let merged = coord.merged_stream();
    let mut iters: Vec<crate::data::batch::BatchIter> = (0..k)
        .map(|i| {
            crate::data::batch::BatchIter::new(
                merged.clone(),
                mcfg.batch_size,
                mcfg.seq_len,
                cfg.rng().child(500 + i as u64),
            )
        })
        .collect();

    let mut net = SimNet::new(
        cfg.comm.bandwidth_bps,
        cfg.comm.latency_s,
        0.0,
        cfg.rng().child(8),
    );
    let payload = rt.manifest.param_bytes() as u64;

    let mut params = init;
    let mut m = Tensors::zeros(&rt.manifest);
    let mut v = Tensors::zeros(&rt.manifest);
    let mut step = start_step;

    let eval_interval = (cfg.inner_steps * cfg.eval_every_rounds.max(1)).max(1);
    for s in 0..steps {
        // Gradient phase across the k (simulated) replicas, dispatched
        // through the coordinator's engine: each replica is one island
        // task returning its gradients as the payload; the fold below
        // runs in replica order, so the averaged gradient is identical
        // under sequential and parallel execution.
        let params_ref = &params;
        let rt_ref: &Runtime = rt;
        let tasks: Vec<IslandTask<'_>> = iters
            .iter_mut()
            .map(|it| {
                Box::new(move || -> anyhow::Result<IslandOutput> {
                    // wall_s includes batch prep (same convention as the
                    // DiLoCo inner phase); compute_s is PJRT-only.
                    // detlint: allow(wall_clock, DESIGN.md §4 rule 3: local timing feeding reporting columns only, reduced in replica order)
                    let t0 = std::time::Instant::now();
                    let batch = it.next_batch();
                    let mut inputs = params_ref.to_views();
                    inputs.push(ValueView::I32(&batch.tokens));
                    inputs.push(ValueView::I32(&batch.targets));
                    // detlint: allow(wall_clock, PJRT-only compute timing — a reporting column, never model state)
                    let t_exec = std::time::Instant::now();
                    let mut out = rt_ref.execute_views("grad_step", &inputs)?;
                    let dt = t_exec.elapsed().as_secs_f64();
                    let loss = out.pop().unwrap().scalar_f32()?;
                    let grads = Tensors::from_values(&rt_ref.manifest, out)?;
                    Ok(IslandOutput {
                        losses: vec![loss],
                        compute_s: dt,
                        wall_s: t0.elapsed().as_secs_f64(),
                        payload: Some(grads),
                    })
                }) as IslandTask<'_>
            })
            .collect();
        let outs = coord.engine().run_islands(tasks)?;

        let mut grad_sum: Option<Tensors> = None;
        let mut losses = Vec::with_capacity(k);
        let mut slowest = 0.0f64;
        let mut serial = 0.0f64;
        for (replica, out) in outs.into_iter().enumerate() {
            slowest = slowest.max(out.compute_s);
            serial += out.compute_s;
            metrics.phases.inner_compute_s += out.wall_s;
            losses.push(out.losses[0] as f64);
            let grads = out.payload.expect("grad task returns gradients");
            match &mut grad_sum {
                None => grad_sum = Some(grads),
                Some(acc) => acc.axpy(1.0, &grads),
            }
            if mode == BigBatchMode::DataParallel && k > 1 {
                net.try_send(payload, Direction::Up, s, replica);
            }
        }
        let mut grads = grad_sum.expect("k >= 1");
        grads.scale(1.0 / k as f32);
        metrics.loss_curve.push(math::mean(&losses) as f32);
        metrics.sim_compute_seconds += match mode {
            BigBatchMode::DataParallel => slowest,
            BigBatchMode::Microbatch => serial,
        };
        if mode == BigBatchMode::DataParallel {
            net.end_round();
        }

        // One fused AdamW application on the averaged gradient.
        let step_scalar = [step as f32];
        let mut inputs = Vec::with_capacity(4 * n_leaves + 1);
        params.append_views(&mut inputs);
        m.append_views(&mut inputs);
        v.append_views(&mut inputs);
        grads.append_views(&mut inputs);
        inputs.push(ValueView::F32(&step_scalar));
        let mut out = {
            let _t = Stopwatch::new(&mut metrics.phases.outer_opt_s);
            rt.execute_views("apply_update", &inputs)?
        };
        drop(inputs);
        let v_vals = out.split_off(2 * n_leaves);
        let m_vals = out.split_off(n_leaves);
        params = Tensors::from_values(&rt.manifest, out)?;
        m = Tensors::from_values(&rt.manifest, m_vals)?;
        v = Tensors::from_values(&rt.manifest, v_vals)?;
        step += 1.0;

        if (s + 1) % eval_interval == 0 || s + 1 == steps {
            let _t = Stopwatch::new(&mut metrics.phases.eval_s);
            let mut p = coord.evaluate(&params)?;
            p.step = start_step as usize + s + 1;
            metrics.eval_curve.push(p);
        }
    }

    let cs = net.stats();
    metrics.comm_bytes = cs.total_bytes();
    metrics.comm_bytes_up = cs.bytes_up;
    metrics.comm_messages = cs.messages;
    metrics.sim_comm_seconds = cs.sim_comm_seconds;
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::runtime::Runtime;
    use std::sync::Arc;

    fn setup() -> Option<(Coordinator, Tensors)> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if !std::path::Path::new(dir).join("nano.manifest.json").exists() {
            return None;
        }
        let rt = Arc::new(Runtime::load(dir, "nano").unwrap());
        let mut cfg = ExperimentConfig::paper_default(dir, "nano");
        cfg.data.n_docs = 60;
        cfg.data.doc_len = 120;
        cfg.eval_batches = 1;
        cfg.inner_steps = 4;
        let init = rt.init_params().unwrap();
        Some((Coordinator::new(cfg, rt).unwrap(), init))
    }

    #[test]
    fn dp_and_microbatch_produce_identical_models() {
        // Table 2 rows 2–3: same math, different cost model.
        let Some((coord, init)) = setup() else { return };
        let a = run_big_batch(
            &coord, 2, 3, BigBatchMode::DataParallel, init.clone(), 0.0,
        )
        .unwrap();
        let b =
            run_big_batch(&coord, 2, 3, BigBatchMode::Microbatch, init, 0.0)
                .unwrap();
        assert_eq!(a.loss_curve, b.loss_curve);
        assert!((a.final_ppl() - b.final_ppl()).abs() < 1e-9);
        // …but DP communicates and microbatching does not.
        assert!(a.comm_bytes > 0);
        assert_eq!(b.comm_bytes, 0);
    }

    #[test]
    fn dp_comm_scales_with_k_times_steps() {
        let Some((coord, init)) = setup() else { return };
        let m =
            run_big_batch(&coord, 2, 3, BigBatchMode::DataParallel, init, 0.0)
                .unwrap();
        let payload = coord.runtime().manifest.param_bytes() as u64;
        assert_eq!(m.comm_bytes, 2 * 3 * payload);
        assert_eq!(m.comm_messages, 6);
    }

    #[test]
    fn k1_big_batch_matches_plain_training_loss() {
        // k=1 DP is exactly the plain baseline (grad_step + apply_update
        // ≡ the fused train_step) — cross-checks the two artifact paths.
        let Some((coord, init)) = setup() else { return };
        let dp = run_big_batch(
            &coord, 1, 4, BigBatchMode::DataParallel, init.clone(), 0.0,
        )
        .unwrap();
        let mut plain = RunMetrics::new("plain");
        coord.plain_train(init, 0.0, 4, &mut plain, 0).unwrap();
        // Same update math; different data streams ⇒ compare magnitudes.
        assert!(dp.loss_curve.iter().all(|l| l.is_finite()));
        assert!(plain.loss_curve.iter().all(|l| l.is_finite()));
        let d = (dp.loss_curve[0] - plain.loss_curve[0]).abs();
        assert!(d < 1.0, "first-step losses far apart: {d}");
    }
}
