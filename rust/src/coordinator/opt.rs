//! Outer optimizers (paper Fig 6): SGD, SGD-momentum, Nesterov, Adam.
//!
//! These are the rust-native implementation of Algorithm 1 line 14 —
//! `θ(t) ← OuterOpt(θ(t-1), Δ(t))` — operating on host `Tensors`. The
//! averaged outer gradient Δ is treated as a gradient (it points from the
//! workers' average back toward the previous parameters).
//!
//! Nesterov with lr 0.7 / μ 0.9 is the paper's choice; SGD(lr) reduces to
//! classical FedAvg when lr=1, and Adam is FedOpt (with ε raised to ~0.1
//! for stability, as the paper found necessary). The `outer_step` HLO
//! artifact implements the same Nesterov recurrence and is cross-checked
//! against this module in the integration tests.

use crate::config::OuterOptConfig;
use crate::runtime::Tensors;

pub enum OuterOpt {
    Sgd {
        lr: f32,
    },
    SgdM {
        lr: f32,
        mu: f32,
        mom: Tensors,
    },
    Nesterov {
        lr: f32,
        mu: f32,
        mom: Tensors,
    },
    Adam {
        lr: f32,
        b1: f32,
        b2: f32,
        eps: f32,
        t: u64,
        m: Tensors,
        v: Tensors,
    },
}

impl OuterOpt {
    /// Build from config; `zeros` supplies the state shape.
    pub fn new(cfg: &OuterOptConfig, zeros: &Tensors) -> OuterOpt {
        match *cfg {
            OuterOptConfig::Sgd { lr } => OuterOpt::Sgd { lr },
            OuterOptConfig::SgdM { lr, mu } => {
                OuterOpt::SgdM { lr, mu, mom: zeros.clone() }
            }
            OuterOptConfig::Nesterov { lr, mu } => {
                OuterOpt::Nesterov { lr, mu, mom: zeros.clone() }
            }
            OuterOptConfig::Adam { lr, b1, b2, eps } => OuterOpt::Adam {
                lr,
                b1,
                b2,
                eps,
                t: 0,
                m: zeros.clone(),
                v: zeros.clone(),
            },
        }
    }

    /// Apply one outer update in place: `params ← params - update(delta)`.
    pub fn step(&mut self, params: &mut Tensors, delta: &Tensors) {
        match self {
            OuterOpt::Sgd { lr } => {
                params.axpy(-*lr, delta);
            }
            OuterOpt::SgdM { lr, mu, mom } => {
                // Heavy ball: mom ← μ·mom + Δ; θ ← θ - lr·mom
                mom.scale(*mu);
                mom.axpy(1.0, delta);
                params.axpy(-*lr, mom);
            }
            OuterOpt::Nesterov { lr, mu, mom } => {
                // PyTorch convention (matches kernels/ref.py):
                // mom ← μ·mom + Δ; θ ← θ - lr·(Δ + μ·mom)
                mom.scale(*mu);
                mom.axpy(1.0, delta);
                params.axpy(-*lr, delta);
                params.axpy(-*lr * *mu, mom);
            }
            OuterOpt::Adam { lr, b1, b2, eps, t, m, v } => {
                *t += 1;
                let bc1 = 1.0 - (*b1 as f64).powi(*t as i32);
                let bc2 = 1.0 - (*b2 as f64).powi(*t as i32);
                for ((p_leaf, m_leaf), (v_leaf, d_leaf)) in params
                    .leaves_mut()
                    .iter_mut()
                    .zip(m.leaves_mut())
                    .zip(v.leaves_mut().iter_mut().zip(delta.leaves()))
                {
                    for i in 0..p_leaf.len() {
                        let g = d_leaf[i];
                        m_leaf[i] = *b1 * m_leaf[i] + (1.0 - *b1) * g;
                        v_leaf[i] = *b2 * v_leaf[i] + (1.0 - *b2) * g * g;
                        let m_hat = m_leaf[i] as f64 / bc1;
                        let v_hat = v_leaf[i] as f64 / bc2;
                        p_leaf[i] -=
                            (*lr as f64 * m_hat / (v_hat.sqrt() + *eps as f64)) as f32;
                    }
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OuterOpt::Sgd { .. } => "sgd",
            OuterOpt::SgdM { .. } => "sgdm",
            OuterOpt::Nesterov { .. } => "nesterov",
            OuterOpt::Adam { .. } => "adam",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn toy(vals: &[f32]) -> Tensors {
        tensors_from(vals)
    }

    /// Split into two leaves to exercise multi-leaf paths.
    fn tensors_from(vals: &[f32]) -> Tensors {
        let mid = vals.len() / 2;
        Tensors::from_raw(vec![vals[..mid].to_vec(), vals[mid..].to_vec()])
    }

    #[test]
    fn sgd_is_plain_descent() {
        let mut p = toy(&[1.0, 2.0, 3.0, 4.0]);
        let d = toy(&[0.5, 0.5, 0.5, 0.5]);
        let mut opt = OuterOpt::new(&OuterOptConfig::Sgd { lr: 1.0 }, &{
            let mut z = p.clone();
            z.scale(0.0);
            z
        });
        opt.step(&mut p, &d);
        let got: Vec<f32> = p.iter_flat().collect();
        assert_eq!(got, vec![0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn nesterov_mu_zero_equals_sgd() {
        check("nesterov(mu=0) == sgd", 40, |g| {
            let vals = g.f32_vec(2..40, 2.0);
            let dvals = g.f32_vec(2..40, 1.0);
            let n = vals.len().min(dvals.len()).max(2);
            let p0 = tensors_from(&vals[..n]);
            let d = tensors_from(&dvals[..n]);
            let mut z = p0.clone();
            z.scale(0.0);
            let lr = g.f64_in(0.01..1.0) as f32;
            let mut p_sgd = p0.clone();
            let mut p_nes = p0.clone();
            OuterOpt::new(&OuterOptConfig::Sgd { lr }, &z).step(&mut p_sgd, &d);
            OuterOpt::new(&OuterOptConfig::Nesterov { lr, mu: 0.0 }, &z)
                .step(&mut p_nes, &d);
            for (a, b) in p_sgd.iter_flat().zip(p_nes.iter_flat()) {
                assert!((a - b).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn nesterov_matches_reference_recurrence() {
        // Scalar trace mirroring kernels/ref.py nesterov_update.
        let mut p = tensors_from(&[1.0, 1.0]);
        let d = tensors_from(&[0.1, 0.1]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(&OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 }, &z);
        // Step 1: mom=0.1, p = 1 - 0.7*(0.1 + 0.09) = 0.867
        opt.step(&mut p, &d);
        for x in p.iter_flat() {
            assert!((x - 0.867).abs() < 1e-5, "{x}");
        }
        // Step 2: mom = 0.09+0.1 = 0.19; p = 0.867 - 0.7*(0.1 + 0.171)
        opt.step(&mut p, &d);
        for x in p.iter_flat() {
            assert!((x - (0.867 - 0.7 * 0.271)).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn sgdm_accumulates_momentum() {
        let mut p = tensors_from(&[0.0, 0.0]);
        let d = tensors_from(&[1.0, 1.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(&OuterOptConfig::SgdM { lr: 1.0, mu: 0.5 }, &z);
        opt.step(&mut p, &d); // mom=1, p=-1
        opt.step(&mut p, &d); // mom=1.5, p=-2.5
        for x in p.iter_flat() {
            assert!((x + 2.5).abs() < 1e-6, "{x}");
        }
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // With b1=b2=0.9/0.999, step 1: m_hat = g, v_hat = g², so the
        // update is lr·g/(|g|+ε) ≈ lr·sign(g).
        let mut p = tensors_from(&[0.0, 0.0, 0.0, 0.0]);
        let d = tensors_from(&[0.5, -0.5, 2.0, -2.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(
            &OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.999, eps: 1e-8 },
            &z,
        );
        opt.step(&mut p, &d);
        for (x, g) in p.iter_flat().zip([0.5f32, -0.5, 2.0, -2.0]) {
            assert!((x + 0.3 * g.signum()).abs() < 1e-4, "{x} vs {}", g.signum());
        }
    }

    #[test]
    fn zero_delta_sgd_and_adam_are_stationary() {
        let mut p = tensors_from(&[1.0, -1.0]);
        let zero = {
            let mut z = p.clone();
            z.scale(0.0);
            z
        };
        let mut sgd = OuterOpt::new(&OuterOptConfig::Sgd { lr: 0.7 }, &zero);
        let before: Vec<f32> = p.iter_flat().collect();
        sgd.step(&mut p, &zero);
        assert_eq!(before, p.iter_flat().collect::<Vec<f32>>());
        let mut adam = OuterOpt::new(
            &OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
            &zero,
        );
        adam.step(&mut p, &zero);
        assert_eq!(before, p.iter_flat().collect::<Vec<f32>>());
    }
}
