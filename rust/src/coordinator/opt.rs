//! Outer optimizers (paper Fig 6): SGD, SGD-momentum, Nesterov, Adam.
//!
//! These are the rust-native implementation of Algorithm 1 line 14 —
//! `θ(t) ← OuterOpt(θ(t-1), Δ(t))` — operating on host `Tensors`. The
//! averaged outer gradient Δ is treated as a gradient (it points from the
//! workers' average back toward the previous parameters).
//!
//! Nesterov with lr 0.7 / μ 0.9 is the paper's choice; SGD(lr) reduces to
//! classical FedAvg when lr=1, and Adam is FedOpt (with ε raised to ~0.1
//! for stability, as the paper found necessary). The `outer_step` HLO
//! artifact implements the same Nesterov recurrence and is cross-checked
//! against this module in the integration tests.

//! **Streaming fragments.** Under the streaming fabric every fragment is
//! its own outer-optimization problem: [`OuterOpt::step_fragment`]
//! applies the update to one fragment's slice of the parameter space
//! only, touching only that slice of the momentum / Adam state, with a
//! per-fragment step counter for Adam bias correction (fragments sync at
//! different cadences under the staggered schedule). The monolithic
//! [`OuterOpt::step`] is fragment 0 covering everything, and performs
//! bit-identical arithmetic to the pre-streaming implementation.
//!
//! **Robust aggregation.** The outer optimizer is downstream of the
//! [`crate::coordinator::aggregate::Aggregator`] seam: Δ here is
//! whatever estimator the `[aggregate]` section selected (weighted
//! mean by default; trimmed mean / coordinate median / Krum under
//! Byzantine workers). The optimizer never sees individual
//! contributions, so swapping the estimator changes only the Δ bytes
//! it is handed — the recurrence itself stays bitwise.

use crate::comm::fragment::{FragmentPlan, LeafSlice};
use crate::config::OuterOptConfig;
use crate::runtime::Tensors;

pub enum OuterOpt {
    Sgd {
        lr: f32,
    },
    SgdM {
        lr: f32,
        mu: f32,
        mom: Tensors,
    },
    Nesterov {
        lr: f32,
        mu: f32,
        mom: Tensors,
    },
    Adam {
        lr: f32,
        b1: f32,
        b2: f32,
        eps: f32,
        /// Per-fragment step counts (index = fragment id) for bias
        /// correction; grown on demand.
        t: Vec<u64>,
        m: Tensors,
        v: Tensors,
    },
}

impl OuterOpt {
    /// One independent optimizer state per model replica — decentralized
    /// sync topologies (ring, gossip; see [`crate::comm::topology`])
    /// keep one model *and one outer momentum / Adam state* per worker,
    /// so each replica's trajectory is self-consistent even when the
    /// replicas disagree.
    ///
    /// ```
    /// use diloco::config::OuterOptConfig;
    /// use diloco::coordinator::opt::OuterOpt;
    /// use diloco::runtime::Tensors;
    ///
    /// let zeros = Tensors::from_raw(vec![vec![0.0; 4]]);
    /// let opts = OuterOpt::replicated(&OuterOptConfig::paper_default(), &zeros, 3);
    /// assert_eq!(opts.len(), 3);
    /// assert!(opts.iter().all(|o| o.name() == "nesterov"));
    /// ```
    pub fn replicated(cfg: &OuterOptConfig, zeros: &Tensors, n: usize) -> Vec<OuterOpt> {
        (0..n).map(|_| OuterOpt::new(cfg, zeros)).collect()
    }

    /// Build from config; `zeros` supplies the state shape.
    pub fn new(cfg: &OuterOptConfig, zeros: &Tensors) -> OuterOpt {
        match *cfg {
            OuterOptConfig::Sgd { lr } => OuterOpt::Sgd { lr },
            OuterOptConfig::SgdM { lr, mu } => {
                OuterOpt::SgdM { lr, mu, mom: zeros.clone() }
            }
            OuterOptConfig::Nesterov { lr, mu } => {
                OuterOpt::Nesterov { lr, mu, mom: zeros.clone() }
            }
            OuterOptConfig::Adam { lr, b1, b2, eps } => OuterOpt::Adam {
                lr,
                b1,
                b2,
                eps,
                t: Vec::new(),
                m: zeros.clone(),
                v: zeros.clone(),
            },
        }
    }

    /// Apply one outer update in place: `params ← params - update(delta)`.
    /// The monolithic path — fragment 0 spanning every parameter leaf.
    pub fn step(&mut self, params: &mut Tensors, delta: &Tensors) {
        let slices: Vec<LeafSlice> = params
            .leaves()
            .iter()
            .enumerate()
            .map(|(leaf, l)| LeafSlice { leaf, start: 0, end: l.len() })
            .collect();
        let flat: Vec<f32> = delta.iter_flat().collect();
        self.step_fragment(params, &flat, &slices, 0);
    }

    /// Apply one outer update to the parameter slices of a single
    /// fragment, using that fragment's slice of the optimizer state.
    /// `avg` is the fragment's averaged outer gradient, flattened in
    /// slice order. Elementwise arithmetic matches the pre-streaming
    /// whole-tensor implementation exactly (same scalar ops, same
    /// per-element order), so a full-coverage fragment is bitwise
    /// identical to the legacy `step`.
    pub fn step_fragment(
        &mut self,
        params: &mut Tensors,
        avg: &[f32],
        slices: &[LeafSlice],
        fragment: usize,
    ) {
        debug_assert_eq!(
            avg.len(),
            slices.iter().map(|s| s.len()).sum::<usize>(),
            "payload does not tile the fragment"
        );
        match self {
            OuterOpt::Sgd { lr } => {
                let c = -*lr;
                let mut off = 0usize;
                for s in slices {
                    let n = s.len();
                    let p = &mut params.leaves_mut()[s.leaf][s.start..s.end];
                    k_sgd(p, &avg[off..off + n], c);
                    off += n;
                }
            }
            OuterOpt::SgdM { lr, mu, mom } => {
                // Heavy ball: mom ← μ·mom + Δ; θ ← θ - lr·mom
                let (mu, c) = (*mu, -*lr);
                let mut off = 0usize;
                for s in slices {
                    let n = s.len();
                    let p = &mut params.leaves_mut()[s.leaf][s.start..s.end];
                    let m = &mut mom.leaves_mut()[s.leaf][s.start..s.end];
                    k_sgdm(p, m, &avg[off..off + n], mu, c);
                    off += n;
                }
            }
            OuterOpt::Nesterov { lr, mu, mom } => {
                // PyTorch convention (matches kernels/ref.py):
                // mom ← μ·mom + Δ; θ ← θ - lr·(Δ + μ·mom)
                let (mu, c1, c2) = (*mu, -*lr, -*lr * *mu);
                let mut off = 0usize;
                for s in slices {
                    let n = s.len();
                    let p = &mut params.leaves_mut()[s.leaf][s.start..s.end];
                    let m = &mut mom.leaves_mut()[s.leaf][s.start..s.end];
                    k_nesterov(p, m, &avg[off..off + n], mu, c1, c2);
                    off += n;
                }
            }
            OuterOpt::Adam { lr, b1, b2, eps, t, m, v } => {
                if t.len() <= fragment {
                    t.resize(fragment + 1, 0);
                }
                t[fragment] += 1;
                let steps = t[fragment];
                let bc1 = 1.0 - (*b1 as f64).powi(steps as i32);
                let bc2 = 1.0 - (*b2 as f64).powi(steps as i32);
                let (lr, b1, b2, eps) = (*lr, *b1, *b2, *eps);
                let mut off = 0usize;
                for s in slices {
                    let n = s.len();
                    let p = &mut params.leaves_mut()[s.leaf][s.start..s.end];
                    let mm = &mut m.leaves_mut()[s.leaf][s.start..s.end];
                    let vv = &mut v.leaves_mut()[s.leaf][s.start..s.end];
                    k_adam(p, mm, vv, &avg[off..off + n], lr, b1, b2, eps, bc1, bc2);
                    off += n;
                }
            }
        }
    }

    /// Apply a whole upload round's worth of fragment updates, fanning
    /// the per-fragment steps across `threads` pooled workers
    /// ([`crate::engine::run_tasks`]). `batch` pairs each fragment id
    /// with its averaged payload, **in ascending fragment order**.
    ///
    /// Fragments are disjoint slices of the parameter space (and of the
    /// momentum / Adam state), so the concurrent steps touch
    /// non-overlapping memory — [`partition_mut`] hands each task its own
    /// `&mut` pieces via `split_at_mut`, and Adam's per-fragment step
    /// counters / bias corrections are advanced sequentially up front.
    /// No float op crosses a fragment boundary, so the result is bitwise
    /// identical to looping [`OuterOpt::step_fragment`] in batch order at
    /// any thread count (property-tested below).
    pub fn step_fragments(
        &mut self,
        params: &mut Tensors,
        batch: &[(usize, &[f32])],
        plan: &FragmentPlan,
        threads: usize,
    ) {
        if batch.is_empty() {
            return;
        }
        if threads <= 1 || batch.len() == 1 {
            for &(f, avg) in batch {
                self.step_fragment(params, avg, plan.slices(f), f);
            }
            return;
        }
        assert!(
            batch.windows(2).all(|w| w[0].0 < w[1].0),
            "step_fragments batch must ascend by fragment id"
        );
        for &(f, avg) in batch {
            debug_assert_eq!(
                avg.len(),
                plan.elements(f),
                "payload does not tile fragment {f}"
            );
        }
        type Task<'a> = Box<dyn FnOnce() + Send + 'a>;
        match self {
            OuterOpt::Sgd { lr } => {
                let c = -*lr;
                let p_parts = partition_mut(params, batch, plan);
                let tasks: Vec<Task<'_>> = p_parts
                    .into_iter()
                    .zip(batch)
                    .map(|(pp, &(_f, avg))| {
                        Box::new(move || {
                            let mut off = 0usize;
                            for p in pp {
                                let n = p.len();
                                k_sgd(p, &avg[off..off + n], c);
                                off += n;
                            }
                        }) as Task<'_>
                    })
                    .collect();
                crate::engine::run_tasks(threads, tasks);
            }
            OuterOpt::SgdM { lr, mu, mom } => {
                let (mu, c) = (*mu, -*lr);
                let p_parts = partition_mut(params, batch, plan);
                let m_parts = partition_mut(mom, batch, plan);
                let tasks: Vec<Task<'_>> = p_parts
                    .into_iter()
                    .zip(m_parts)
                    .zip(batch)
                    .map(|((pp, mp), &(_f, avg))| {
                        Box::new(move || {
                            let mut off = 0usize;
                            for (p, m) in pp.into_iter().zip(mp) {
                                let n = p.len();
                                k_sgdm(p, m, &avg[off..off + n], mu, c);
                                off += n;
                            }
                        }) as Task<'_>
                    })
                    .collect();
                crate::engine::run_tasks(threads, tasks);
            }
            OuterOpt::Nesterov { lr, mu, mom } => {
                let (mu, c1, c2) = (*mu, -*lr, -*lr * *mu);
                let p_parts = partition_mut(params, batch, plan);
                let m_parts = partition_mut(mom, batch, plan);
                let tasks: Vec<Task<'_>> = p_parts
                    .into_iter()
                    .zip(m_parts)
                    .zip(batch)
                    .map(|((pp, mp), &(_f, avg))| {
                        Box::new(move || {
                            let mut off = 0usize;
                            for (p, m) in pp.into_iter().zip(mp) {
                                let n = p.len();
                                k_nesterov(p, m, &avg[off..off + n], mu, c1, c2);
                                off += n;
                            }
                        }) as Task<'_>
                    })
                    .collect();
                crate::engine::run_tasks(threads, tasks);
            }
            OuterOpt::Adam { lr, b1, b2, eps, t, m, v } => {
                let (lr, b1, b2, eps) = (*lr, *b1, *b2, *eps);
                // Step counters and bias corrections advance sequentially
                // in batch order, exactly as the sequential loop would.
                let mut bcs = Vec::with_capacity(batch.len());
                for &(f, _) in batch {
                    if t.len() <= f {
                        t.resize(f + 1, 0);
                    }
                    t[f] += 1;
                    let steps = t[f];
                    bcs.push((
                        1.0 - (b1 as f64).powi(steps as i32),
                        1.0 - (b2 as f64).powi(steps as i32),
                    ));
                }
                let p_parts = partition_mut(params, batch, plan);
                let m_parts = partition_mut(m, batch, plan);
                let v_parts = partition_mut(v, batch, plan);
                let tasks: Vec<Task<'_>> = p_parts
                    .into_iter()
                    .zip(m_parts)
                    .zip(v_parts)
                    .zip(batch)
                    .zip(bcs)
                    .map(|((((pp, mp), vp), &(_f, avg)), (bc1, bc2))| {
                        Box::new(move || {
                            let mut off = 0usize;
                            for ((p, mm), vv) in
                                pp.into_iter().zip(mp).zip(vp)
                            {
                                let n = p.len();
                                k_adam(
                                    p, mm, vv, &avg[off..off + n],
                                    lr, b1, b2, eps, bc1, bc2,
                                );
                                off += n;
                            }
                        }) as Task<'_>
                    })
                    .collect();
                crate::engine::run_tasks(threads, tasks);
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OuterOpt::Sgd { .. } => "sgd",
            OuterOpt::SgdM { .. } => "sgdm",
            OuterOpt::Nesterov { .. } => "nesterov",
            OuterOpt::Adam { .. } => "adam",
        }
    }

    /// Snapshot of the mutable optimizer state, for
    /// [`crate::checkpoint::TrainState`] saves. Hyperparameters are not
    /// recorded — they are reconstructed from the experiment config on
    /// resume, and [`OuterOpt::restore`] checks the kinds agree.
    pub fn snapshot(&self) -> OuterOptSnapshot {
        match self {
            OuterOpt::Sgd { .. } => OuterOptSnapshot {
                kind: "sgd".into(),
                t: Vec::new(),
                tensors: Vec::new(),
            },
            OuterOpt::SgdM { mom, .. } => OuterOptSnapshot {
                kind: "sgdm".into(),
                t: Vec::new(),
                tensors: vec![mom.clone()],
            },
            OuterOpt::Nesterov { mom, .. } => OuterOptSnapshot {
                kind: "nesterov".into(),
                t: Vec::new(),
                tensors: vec![mom.clone()],
            },
            OuterOpt::Adam { t, m, v, .. } => OuterOptSnapshot {
                kind: "adam".into(),
                t: t.clone(),
                tensors: vec![m.clone(), v.clone()],
            },
        }
    }

    /// Rebuild an optimizer from config hyperparameters plus a state
    /// snapshot. Bitwise: stepping the restored optimizer continues the
    /// saved trajectory exactly (the resume integration tests pin this).
    /// `max_fragments` bounds the Adam per-fragment step vector (the
    /// run's fragment count): a longer or absurd-valued `t` from a
    /// corrupted checkpoint is rejected here instead of silently
    /// skewing bias correction.
    pub fn restore(
        cfg: &OuterOptConfig,
        zeros: &Tensors,
        snap: OuterOptSnapshot,
        max_fragments: usize,
    ) -> anyhow::Result<OuterOpt> {
        let mut opt = OuterOpt::new(cfg, zeros);
        anyhow::ensure!(
            opt.name() == snap.kind,
            "checkpoint outer optimizer is {:?}, config wants {:?}",
            snap.kind,
            opt.name()
        );
        anyhow::ensure!(
            snap.t.len() <= max_fragments,
            "outer optimizer snapshot has {} per-fragment step counters, \
             the run has {max_fragments} fragments",
            snap.t.len()
        );
        anyhow::ensure!(
            snap.t.iter().all(|&s| s <= u32::MAX as u64),
            "outer optimizer snapshot has an implausible step counter"
        );
        anyhow::ensure!(
            matches!(cfg, OuterOptConfig::Adam { .. }) || snap.t.is_empty(),
            "non-Adam outer optimizer snapshot carries step counters"
        );
        let want = match &opt {
            OuterOpt::Sgd { .. } => 0,
            OuterOpt::SgdM { .. } | OuterOpt::Nesterov { .. } => 1,
            OuterOpt::Adam { .. } => 2,
        };
        anyhow::ensure!(
            snap.tensors.len() == want,
            "outer optimizer snapshot has {} state tensors, {:?} wants {want}",
            snap.tensors.len(),
            snap.kind
        );
        let mut it = snap.tensors.into_iter();
        match &mut opt {
            OuterOpt::Sgd { .. } => {}
            OuterOpt::SgdM { mom, .. } | OuterOpt::Nesterov { mom, .. } => {
                *mom = it.next().unwrap();
            }
            OuterOpt::Adam { t, m, v, .. } => {
                *t = snap.t;
                *m = it.next().unwrap();
                *v = it.next().unwrap();
            }
        }
        Ok(opt)
    }
}

/// Serializable mutable state of an [`OuterOpt`] (see
/// [`OuterOpt::snapshot`] / [`OuterOpt::restore`]).
#[derive(Clone, Debug, PartialEq)]
pub struct OuterOptSnapshot {
    /// Optimizer kind name, checked against the config on restore.
    pub kind: String,
    /// Adam's per-fragment step counters (empty for other kinds).
    pub t: Vec<u64>,
    /// Manifest-shaped state tensors: `[mom]` for momentum kinds,
    /// `[m, v]` for Adam, empty for plain SGD.
    pub tensors: Vec<Tensors>,
}

// ---- per-element kernels ----------------------------------------------
//
// Shared by the sequential `step_fragment` arms and the parallel
// `step_fragments` tasks, so "parallel == sequential bitwise" holds by
// construction: both paths run the *same* function over the same
// contiguous subslices in the same per-element order. Zipped contiguous
// slices carry no bounds checks, so the autovectorizer can lift these.

/// θ ← θ - lr·Δ
#[inline]
fn k_sgd(p: &mut [f32], avg: &[f32], c: f32) {
    for (pi, &d) in p.iter_mut().zip(avg) {
        *pi += c * d;
    }
}

/// mom ← μ·mom + Δ; θ ← θ - lr·mom. (`*m += d` is the simplified form
/// of the historical `*m += 1.0 * d` — `1.0 * x == x` bitwise for every
/// f32, pinned by `simplified_sgdm_matches_legacy_expression_bitwise`.)
#[inline]
fn k_sgdm(p: &mut [f32], m: &mut [f32], avg: &[f32], mu: f32, c: f32) {
    for ((pi, mi), &d) in p.iter_mut().zip(m.iter_mut()).zip(avg) {
        *mi *= mu;
        *mi += d;
        *pi += c * *mi;
    }
}

/// mom ← μ·mom + Δ; θ ← θ - lr·(Δ + μ·mom)
#[inline]
fn k_nesterov(p: &mut [f32], m: &mut [f32], avg: &[f32], mu: f32, c1: f32, c2: f32) {
    for ((pi, mi), &d) in p.iter_mut().zip(m.iter_mut()).zip(avg) {
        *mi *= mu;
        *mi += d;
        *pi += c1 * d;
        *pi += c2 * *mi;
    }
}

/// Adam with the bias corrections precomputed per fragment.
#[allow(clippy::too_many_arguments)]
#[inline]
fn k_adam(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    avg: &[f32],
    lr: f32,
    b1: f32,
    b2: f32,
    eps: f32,
    bc1: f64,
    bc2: f64,
) {
    for (((pi, mi), vi), &g) in
        p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(avg)
    {
        *mi = b1 * *mi + (1.0 - b1) * g;
        *vi = b2 * *vi + (1.0 - b2) * g * g;
        let m_hat = *mi as f64 / bc1;
        let v_hat = *vi as f64 / bc2;
        *pi -= (lr as f64 * m_hat / (v_hat.sqrt() + eps as f64)) as f32;
    }
}

/// Split a tensor tree into per-batch-entry bundles of disjoint `&mut`
/// slice pieces, one bundle per `(fragment, payload)` pair, in slice
/// order within each bundle. Fragments are consecutive flat ranges and
/// the batch ascends by fragment id, so each leaf's cut points ascend
/// and progressive `split_at_mut` distributes the pieces without any
/// unsafe aliasing.
fn partition_mut<'a>(
    t: &'a mut Tensors,
    batch: &[(usize, &[f32])],
    plan: &FragmentPlan,
) -> Vec<Vec<&'a mut [f32]>> {
    let n_leaves = t.n_leaves();
    let mut cuts: Vec<Vec<(usize, usize, usize)>> = vec![Vec::new(); n_leaves];
    for (bi, &(f, _)) in batch.iter().enumerate() {
        for s in plan.slices(f) {
            cuts[s.leaf].push((bi, s.start, s.end));
        }
    }
    for leaf_cuts in &mut cuts {
        leaf_cuts.sort_by_key(|&(_, start, _)| start);
    }
    let mut buckets: Vec<Vec<&'a mut [f32]>> =
        (0..batch.len()).map(|_| Vec::new()).collect();
    for (leaf, leaf_cuts) in t.leaves_mut().iter_mut().zip(&cuts) {
        let mut rest: &'a mut [f32] = leaf.as_mut_slice();
        let mut consumed = 0usize;
        for &(bi, start, end) in leaf_cuts {
            let (_gap, tail) = rest.split_at_mut(start - consumed);
            let (piece, tail) = tail.split_at_mut(end - start);
            buckets[bi].push(piece);
            rest = tail;
            consumed = end;
        }
    }
    buckets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn toy(vals: &[f32]) -> Tensors {
        tensors_from(vals)
    }

    /// Split into two leaves to exercise multi-leaf paths.
    fn tensors_from(vals: &[f32]) -> Tensors {
        let mid = vals.len() / 2;
        Tensors::from_raw(vec![vals[..mid].to_vec(), vals[mid..].to_vec()])
    }

    #[test]
    fn sgd_is_plain_descent() {
        let mut p = toy(&[1.0, 2.0, 3.0, 4.0]);
        let d = toy(&[0.5, 0.5, 0.5, 0.5]);
        let mut opt = OuterOpt::new(&OuterOptConfig::Sgd { lr: 1.0 }, &{
            let mut z = p.clone();
            z.scale(0.0);
            z
        });
        opt.step(&mut p, &d);
        let got: Vec<f32> = p.iter_flat().collect();
        assert_eq!(got, vec![0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn nesterov_mu_zero_equals_sgd() {
        check("nesterov(mu=0) == sgd", 40, |g| {
            let vals = g.f32_vec(2..40, 2.0);
            let dvals = g.f32_vec(2..40, 1.0);
            let n = vals.len().min(dvals.len()).max(2);
            let p0 = tensors_from(&vals[..n]);
            let d = tensors_from(&dvals[..n]);
            let mut z = p0.clone();
            z.scale(0.0);
            let lr = g.f64_in(0.01..1.0) as f32;
            let mut p_sgd = p0.clone();
            let mut p_nes = p0.clone();
            OuterOpt::new(&OuterOptConfig::Sgd { lr }, &z).step(&mut p_sgd, &d);
            OuterOpt::new(&OuterOptConfig::Nesterov { lr, mu: 0.0 }, &z)
                .step(&mut p_nes, &d);
            for (a, b) in p_sgd.iter_flat().zip(p_nes.iter_flat()) {
                assert!((a - b).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn nesterov_matches_reference_recurrence() {
        // Scalar trace mirroring kernels/ref.py nesterov_update.
        let mut p = tensors_from(&[1.0, 1.0]);
        let d = tensors_from(&[0.1, 0.1]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(&OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 }, &z);
        // Step 1: mom=0.1, p = 1 - 0.7*(0.1 + 0.09) = 0.867
        opt.step(&mut p, &d);
        for x in p.iter_flat() {
            assert!((x - 0.867).abs() < 1e-5, "{x}");
        }
        // Step 2: mom = 0.09+0.1 = 0.19; p = 0.867 - 0.7*(0.1 + 0.171)
        opt.step(&mut p, &d);
        for x in p.iter_flat() {
            assert!((x - (0.867 - 0.7 * 0.271)).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn sgdm_accumulates_momentum() {
        let mut p = tensors_from(&[0.0, 0.0]);
        let d = tensors_from(&[1.0, 1.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(&OuterOptConfig::SgdM { lr: 1.0, mu: 0.5 }, &z);
        opt.step(&mut p, &d); // mom=1, p=-1
        opt.step(&mut p, &d); // mom=1.5, p=-2.5
        for x in p.iter_flat() {
            assert!((x + 2.5).abs() < 1e-6, "{x}");
        }
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // With b1=b2=0.9/0.999, step 1: m_hat = g, v_hat = g², so the
        // update is lr·g/(|g|+ε) ≈ lr·sign(g).
        let mut p = tensors_from(&[0.0, 0.0, 0.0, 0.0]);
        let d = tensors_from(&[0.5, -0.5, 2.0, -2.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(
            &OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.999, eps: 1e-8 },
            &z,
        );
        opt.step(&mut p, &d);
        for (x, g) in p.iter_flat().zip([0.5f32, -0.5, 2.0, -2.0]) {
            assert!((x + 0.3 * g.signum()).abs() < 1e-4, "{x} vs {}", g.signum());
        }
    }

    #[test]
    fn prop_fragment_steps_assemble_to_monolithic_bitwise() {
        // Applying each fragment's slice of the averaged delta through
        // step_fragment must equal one monolithic step bitwise, for
        // every optimizer, over several rounds (momentum state carries).
        use crate::comm::fragment::FragmentPlan;
        check("Σ fragment steps == monolithic step", 30, |g| {
            let len = g.usize_in(2..40);
            let n = if len % 2 == 1 { len + 1 } else { len };
            let init: Vec<f32> = g.f32_vec(n..n + 1, 2.0);
            let mut init = init;
            init.resize(n, 0.0);
            let p = g.usize_in(1..6);
            for cfg in [
                OuterOptConfig::Sgd { lr: 0.5 },
                OuterOptConfig::SgdM { lr: 0.5, mu: 0.8 },
                OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
                OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
            ] {
                let mut mono = tensors_from(&init);
                let mut frag = mono.clone();
                let mut z = mono.clone();
                z.scale(0.0);
                let mut opt_mono = OuterOpt::new(&cfg, &z);
                let mut opt_frag = OuterOpt::new(&cfg, &z);
                let plan = FragmentPlan::for_tensors(&mono, p);
                for _round in 0..3 {
                    let mut d = g.f32_vec(n..n + 1, 1.0);
                    d.resize(n, 0.0);
                    let delta = tensors_from(&d);
                    opt_mono.step(&mut mono, &delta);
                    // Every fragment steps once per round, so each
                    // per-fragment Adam counter matches the monolithic t.
                    for f in 0..plan.n_fragments() {
                        let payload = plan.extract(&delta, f);
                        opt_frag.step_fragment(&mut frag, &payload, plan.slices(f), f);
                    }
                }
                for (a, b) in mono.iter_flat().zip(frag.iter_flat()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{}: {a} != {b}",
                        opt_mono.name()
                    );
                }
            }
        });
    }

    #[test]
    fn adam_bias_correction_is_per_fragment() {
        // Fragment 1 stepping for the first time must get first-step
        // bias correction even after fragment 0 has stepped many times
        // (staggered schedules sync fragments at different cadences).
        use crate::comm::fragment::LeafSlice;
        let mut p = tensors_from(&[0.0, 0.0, 0.0, 0.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let cfg = OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.999, eps: 1e-8 };
        let mut opt = OuterOpt::new(&cfg, &z);
        // p has two leaves of 2; fragment 0 = leaf 0, fragment 1 = leaf 1.
        let f0 = [LeafSlice { leaf: 0, start: 0, end: 2 }];
        let f1 = [LeafSlice { leaf: 1, start: 0, end: 2 }];
        for _ in 0..5 {
            opt.step_fragment(&mut p, &[0.5, 0.5], &f0, 0);
        }
        opt.step_fragment(&mut p, &[0.5, 0.5], &f1, 1);
        // First Adam step ⇒ update ≈ lr·sign(g) on fragment 1.
        let got: Vec<f32> = p.iter_flat().collect();
        assert!((got[2] + 0.3).abs() < 1e-4, "{}", got[2]);
        assert!((got[3] + 0.3).abs() < 1e-4, "{}", got[3]);
        // Fragment 0 advanced 5 steps and moved further.
        assert!(got[0] < got[2], "{} vs {}", got[0], got[2]);
    }

    #[test]
    fn simplified_sgdm_matches_legacy_expression_bitwise() {
        // Regression pin for dropping the redundant multiply: the SgdM /
        // Nesterov arms historically computed `*m += 1.0 * d`; the
        // kernels now use `*m += d`. IEEE 754 guarantees `1.0 * x == x`
        // bitwise for every f32 (including ±0, subnormals, ±inf), so the
        // trajectories must agree bit for bit. The reference below
        // retains the legacy expression verbatim.
        fn legacy_sgdm(p: &mut [f32], m: &mut [f32], d: &[f32], mu: f32, c: f32) {
            for ((pi, mi), &dv) in p.iter_mut().zip(m.iter_mut()).zip(d) {
                *mi *= mu;
                #[allow(clippy::identity_op)]
                {
                    *mi += 1.0 * dv;
                }
                *pi += c * *mi;
            }
        }
        fn legacy_nesterov(
            p: &mut [f32], m: &mut [f32], d: &[f32], mu: f32, c1: f32, c2: f32,
        ) {
            for ((pi, mi), &dv) in p.iter_mut().zip(m.iter_mut()).zip(d) {
                *mi *= mu;
                #[allow(clippy::identity_op)]
                {
                    *mi += 1.0 * dv;
                }
                *pi += c1 * dv;
                *pi += c2 * *mi;
            }
        }
        check("kernels without 1.0* == legacy with 1.0* bitwise", 40, |g| {
            let n = g.usize_in(1..50);
            let mut d = g.f32_vec(n..n + 1, 3.0);
            d.resize(n, 0.0);
            // Include the edge values the identity must hold for.
            if n >= 4 {
                d[0] = -0.0;
                d[1] = f32::MIN_POSITIVE / 4.0; // subnormal after /4
                d[2] = 0.0;
            }
            let p0 = g.f32_vec(n..n + 1, 2.0);
            let mut p0 = p0;
            p0.resize(n, 0.0);
            let (mu, c) = (0.9f32, -0.7f32);

            let (mut p_new, mut m_new) = (p0.clone(), vec![0.0f32; n]);
            let (mut p_old, mut m_old) = (p0.clone(), vec![0.0f32; n]);
            for _ in 0..3 {
                super::k_sgdm(&mut p_new, &mut m_new, &d, mu, c);
                legacy_sgdm(&mut p_old, &mut m_old, &d, mu, c);
            }
            for (a, b) in p_new.iter().zip(&p_old).chain(m_new.iter().zip(&m_old)) {
                assert_eq!(a.to_bits(), b.to_bits(), "sgdm {a} != {b}");
            }

            let (mut p_new, mut m_new) = (p0.clone(), vec![0.0f32; n]);
            let (mut p_old, mut m_old) = (p0.clone(), vec![0.0f32; n]);
            for _ in 0..3 {
                super::k_nesterov(&mut p_new, &mut m_new, &d, mu, c, c * mu);
                legacy_nesterov(&mut p_old, &mut m_old, &d, mu, c, c * mu);
            }
            for (a, b) in p_new.iter().zip(&p_old).chain(m_new.iter().zip(&m_old)) {
                assert_eq!(a.to_bits(), b.to_bits(), "nesterov {a} != {b}");
            }
        });
    }

    #[test]
    fn prop_parallel_step_fragments_matches_sequential_bitwise() {
        // Fanning an upload round's fragment steps across the pool must
        // be indistinguishable from looping step_fragment in batch order
        // — for every optimizer kind, at several thread counts, across
        // rounds (momentum/Adam state carries between rounds).
        use crate::comm::fragment::FragmentPlan;
        check("step_fragments(pool) == step_fragment loop", 20, |g| {
            let len = g.usize_in(4..60);
            let n = if len % 2 == 1 { len + 1 } else { len };
            let mut init = g.f32_vec(n..n + 1, 2.0);
            init.resize(n, 0.0);
            let p = g.usize_in(2..8);
            let threads = [2usize, 3, 16][g.usize_in(0..3)];
            for cfg in [
                OuterOptConfig::Sgd { lr: 0.5 },
                OuterOptConfig::SgdM { lr: 0.5, mu: 0.8 },
                OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
                OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
            ] {
                let mut seq = tensors_from(&init);
                let mut par = seq.clone();
                let mut z = seq.clone();
                z.scale(0.0);
                let mut opt_seq = OuterOpt::new(&cfg, &z);
                let mut opt_par = OuterOpt::new(&cfg, &z);
                let plan = FragmentPlan::for_tensors(&seq, p);
                for _round in 0..2 {
                    let mut d = g.f32_vec(n..n + 1, 1.0);
                    d.resize(n, 0.0);
                    let delta = tensors_from(&d);
                    let payloads: Vec<Vec<f32>> = (0..plan.n_fragments())
                        .map(|f| plan.extract(&delta, f))
                        .collect();
                    // Sometimes step only a subset of fragments (a
                    // partial upload round), still ascending.
                    let due: Vec<usize> = (0..plan.n_fragments())
                        .filter(|&f| f == 0 || g.bool())
                        .collect();
                    for &f in &due {
                        opt_seq.step_fragment(
                            &mut seq, &payloads[f], plan.slices(f), f,
                        );
                    }
                    let batch: Vec<(usize, &[f32])> = due
                        .iter()
                        .map(|&f| (f, payloads[f].as_slice()))
                        .collect();
                    opt_par.step_fragments(&mut par, &batch, &plan, threads);
                }
                for (a, b) in seq.iter_flat().zip(par.iter_flat()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{}: {a} != {b}",
                        opt_seq.name()
                    );
                }
            }
        });
    }

    #[test]
    fn step_fragments_sequential_fallback_and_empty_batch() {
        let mut p = tensors_from(&[1.0, 2.0, 3.0, 4.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let plan = FragmentPlan::for_tensors(&p, 2);
        let mut opt = OuterOpt::new(&OuterOptConfig::Sgd { lr: 1.0 }, &z);
        opt.step_fragments(&mut p, &[], &plan, 8); // no-op
        assert_eq!(p.iter_flat().collect::<Vec<f32>>(), vec![1.0, 2.0, 3.0, 4.0]);
        let payload = [0.5f32, 0.5];
        // threads=1 and single-entry batches both take the inline loop.
        opt.step_fragments(&mut p, &[(0, &payload)], &plan, 1);
        opt.step_fragments(&mut p, &[(1, &payload)], &plan, 8);
        assert_eq!(p.iter_flat().collect::<Vec<f32>>(), vec![0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn snapshot_restore_continues_trajectory_bitwise() {
        // For every optimizer kind: step twice straight vs step once,
        // snapshot, restore into a fresh optimizer, step again — the
        // parameters must agree bit for bit (the resume contract at the
        // optimizer layer).
        for cfg in [
            OuterOptConfig::Sgd { lr: 0.5 },
            OuterOptConfig::SgdM { lr: 0.5, mu: 0.8 },
            OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
            OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
        ] {
            let init = tensors_from(&[1.0, -2.0, 0.5, 3.0]);
            let d1 = tensors_from(&[0.1, 0.2, -0.3, 0.4]);
            let d2 = tensors_from(&[-0.2, 0.1, 0.5, -0.1]);
            let mut z = init.clone();
            z.scale(0.0);

            let mut straight = init.clone();
            let mut opt = OuterOpt::new(&cfg, &z);
            opt.step(&mut straight, &d1);
            opt.step(&mut straight, &d2);

            let mut resumed = init.clone();
            let mut opt_a = OuterOpt::new(&cfg, &z);
            opt_a.step(&mut resumed, &d1);
            let snap = opt_a.snapshot();
            let mut opt_b = OuterOpt::restore(&cfg, &z, snap, 1).unwrap();
            opt_b.step(&mut resumed, &d2);

            for (a, b) in straight.iter_flat().zip(resumed.iter_flat()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", opt_b.name());
            }
        }
    }

    #[test]
    fn restore_rejects_kind_mismatch() {
        let z = {
            let mut z = tensors_from(&[0.0, 0.0]);
            z.scale(0.0);
            z
        };
        let snap = OuterOpt::new(&OuterOptConfig::Sgd { lr: 1.0 }, &z).snapshot();
        assert!(OuterOpt::restore(
            &OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
            &z,
            snap,
            1
        )
        .is_err());
        // An Adam snapshot whose step vector outruns the run's fragment
        // count (a corrupted checkpoint) is rejected, not resized away.
        let adam = OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 };
        let mut opt = OuterOpt::new(&adam, &z);
        let mut p = tensors_from(&[0.0, 0.0]);
        opt.step(&mut p, &z);
        let snap = opt.snapshot();
        assert!(OuterOpt::restore(&adam, &z, snap.clone(), 0).is_err());
        assert!(OuterOpt::restore(&adam, &z, snap, 1).is_ok());
    }

    #[test]
    fn zero_delta_sgd_and_adam_are_stationary() {
        let mut p = tensors_from(&[1.0, -1.0]);
        let zero = {
            let mut z = p.clone();
            z.scale(0.0);
            z
        };
        let mut sgd = OuterOpt::new(&OuterOptConfig::Sgd { lr: 0.7 }, &zero);
        let before: Vec<f32> = p.iter_flat().collect();
        sgd.step(&mut p, &zero);
        assert_eq!(before, p.iter_flat().collect::<Vec<f32>>());
        let mut adam = OuterOpt::new(
            &OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
            &zero,
        );
        adam.step(&mut p, &zero);
        assert_eq!(before, p.iter_flat().collect::<Vec<f32>>());
    }
}
