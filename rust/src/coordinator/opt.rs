//! Outer optimizers (paper Fig 6): SGD, SGD-momentum, Nesterov, Adam.
//!
//! These are the rust-native implementation of Algorithm 1 line 14 —
//! `θ(t) ← OuterOpt(θ(t-1), Δ(t))` — operating on host `Tensors`. The
//! averaged outer gradient Δ is treated as a gradient (it points from the
//! workers' average back toward the previous parameters).
//!
//! Nesterov with lr 0.7 / μ 0.9 is the paper's choice; SGD(lr) reduces to
//! classical FedAvg when lr=1, and Adam is FedOpt (with ε raised to ~0.1
//! for stability, as the paper found necessary). The `outer_step` HLO
//! artifact implements the same Nesterov recurrence and is cross-checked
//! against this module in the integration tests.

//! **Streaming fragments.** Under the streaming fabric every fragment is
//! its own outer-optimization problem: [`OuterOpt::step_fragment`]
//! applies the update to one fragment's slice of the parameter space
//! only, touching only that slice of the momentum / Adam state, with a
//! per-fragment step counter for Adam bias correction (fragments sync at
//! different cadences under the staggered schedule). The monolithic
//! [`OuterOpt::step`] is fragment 0 covering everything, and performs
//! bit-identical arithmetic to the pre-streaming implementation.

use crate::comm::fragment::LeafSlice;
use crate::config::OuterOptConfig;
use crate::runtime::Tensors;

pub enum OuterOpt {
    Sgd {
        lr: f32,
    },
    SgdM {
        lr: f32,
        mu: f32,
        mom: Tensors,
    },
    Nesterov {
        lr: f32,
        mu: f32,
        mom: Tensors,
    },
    Adam {
        lr: f32,
        b1: f32,
        b2: f32,
        eps: f32,
        /// Per-fragment step counts (index = fragment id) for bias
        /// correction; grown on demand.
        t: Vec<u64>,
        m: Tensors,
        v: Tensors,
    },
}

impl OuterOpt {
    /// One independent optimizer state per model replica — decentralized
    /// sync topologies (ring, gossip; see [`crate::comm::topology`])
    /// keep one model *and one outer momentum / Adam state* per worker,
    /// so each replica's trajectory is self-consistent even when the
    /// replicas disagree.
    ///
    /// ```
    /// use diloco::config::OuterOptConfig;
    /// use diloco::coordinator::opt::OuterOpt;
    /// use diloco::runtime::Tensors;
    ///
    /// let zeros = Tensors::from_raw(vec![vec![0.0; 4]]);
    /// let opts = OuterOpt::replicated(&OuterOptConfig::paper_default(), &zeros, 3);
    /// assert_eq!(opts.len(), 3);
    /// assert!(opts.iter().all(|o| o.name() == "nesterov"));
    /// ```
    pub fn replicated(cfg: &OuterOptConfig, zeros: &Tensors, n: usize) -> Vec<OuterOpt> {
        (0..n).map(|_| OuterOpt::new(cfg, zeros)).collect()
    }

    /// Build from config; `zeros` supplies the state shape.
    pub fn new(cfg: &OuterOptConfig, zeros: &Tensors) -> OuterOpt {
        match *cfg {
            OuterOptConfig::Sgd { lr } => OuterOpt::Sgd { lr },
            OuterOptConfig::SgdM { lr, mu } => {
                OuterOpt::SgdM { lr, mu, mom: zeros.clone() }
            }
            OuterOptConfig::Nesterov { lr, mu } => {
                OuterOpt::Nesterov { lr, mu, mom: zeros.clone() }
            }
            OuterOptConfig::Adam { lr, b1, b2, eps } => OuterOpt::Adam {
                lr,
                b1,
                b2,
                eps,
                t: Vec::new(),
                m: zeros.clone(),
                v: zeros.clone(),
            },
        }
    }

    /// Apply one outer update in place: `params ← params - update(delta)`.
    /// The monolithic path — fragment 0 spanning every parameter leaf.
    pub fn step(&mut self, params: &mut Tensors, delta: &Tensors) {
        let slices: Vec<LeafSlice> = params
            .leaves()
            .iter()
            .enumerate()
            .map(|(leaf, l)| LeafSlice { leaf, start: 0, end: l.len() })
            .collect();
        let flat: Vec<f32> = delta.iter_flat().collect();
        self.step_fragment(params, &flat, &slices, 0);
    }

    /// Apply one outer update to the parameter slices of a single
    /// fragment, using that fragment's slice of the optimizer state.
    /// `avg` is the fragment's averaged outer gradient, flattened in
    /// slice order. Elementwise arithmetic matches the pre-streaming
    /// whole-tensor implementation exactly (same scalar ops, same
    /// per-element order), so a full-coverage fragment is bitwise
    /// identical to the legacy `step`.
    pub fn step_fragment(
        &mut self,
        params: &mut Tensors,
        avg: &[f32],
        slices: &[LeafSlice],
        fragment: usize,
    ) {
        debug_assert_eq!(
            avg.len(),
            slices.iter().map(|s| s.len()).sum::<usize>(),
            "payload does not tile the fragment"
        );
        match self {
            OuterOpt::Sgd { lr } => {
                let c = -*lr;
                for_slices(params, slices, avg, |p, d| *p += c * d);
            }
            OuterOpt::SgdM { lr, mu, mom } => {
                // Heavy ball: mom ← μ·mom + Δ; θ ← θ - lr·mom
                let (mu, c) = (*mu, -*lr);
                for_slices2(params, mom, slices, avg, |p, m, d| {
                    *m *= mu;
                    *m += 1.0 * d;
                    *p += c * *m;
                });
            }
            OuterOpt::Nesterov { lr, mu, mom } => {
                // PyTorch convention (matches kernels/ref.py):
                // mom ← μ·mom + Δ; θ ← θ - lr·(Δ + μ·mom)
                let (mu, c1, c2) = (*mu, -*lr, -*lr * *mu);
                for_slices2(params, mom, slices, avg, |p, m, d| {
                    *m *= mu;
                    *m += 1.0 * d;
                    *p += c1 * d;
                    *p += c2 * *m;
                });
            }
            OuterOpt::Adam { lr, b1, b2, eps, t, m, v } => {
                if t.len() <= fragment {
                    t.resize(fragment + 1, 0);
                }
                t[fragment] += 1;
                let steps = t[fragment];
                let bc1 = 1.0 - (*b1 as f64).powi(steps as i32);
                let bc2 = 1.0 - (*b2 as f64).powi(steps as i32);
                let (lr, b1, b2, eps) = (*lr, *b1, *b2, *eps);
                let mut off = 0usize;
                for s in slices {
                    let p_leaf = &mut params.leaves_mut()[s.leaf];
                    let m_leaf = &mut m.leaves_mut()[s.leaf];
                    let v_leaf = &mut v.leaves_mut()[s.leaf];
                    for (j, i) in (s.start..s.end).enumerate() {
                        let g = avg[off + j];
                        m_leaf[i] = b1 * m_leaf[i] + (1.0 - b1) * g;
                        v_leaf[i] = b2 * v_leaf[i] + (1.0 - b2) * g * g;
                        let m_hat = m_leaf[i] as f64 / bc1;
                        let v_hat = v_leaf[i] as f64 / bc2;
                        p_leaf[i] -=
                            (lr as f64 * m_hat / (v_hat.sqrt() + eps as f64)) as f32;
                    }
                    off += s.len();
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OuterOpt::Sgd { .. } => "sgd",
            OuterOpt::SgdM { .. } => "sgdm",
            OuterOpt::Nesterov { .. } => "nesterov",
            OuterOpt::Adam { .. } => "adam",
        }
    }

    /// Snapshot of the mutable optimizer state, for
    /// [`crate::checkpoint::TrainState`] saves. Hyperparameters are not
    /// recorded — they are reconstructed from the experiment config on
    /// resume, and [`OuterOpt::restore`] checks the kinds agree.
    pub fn snapshot(&self) -> OuterOptSnapshot {
        match self {
            OuterOpt::Sgd { .. } => OuterOptSnapshot {
                kind: "sgd".into(),
                t: Vec::new(),
                tensors: Vec::new(),
            },
            OuterOpt::SgdM { mom, .. } => OuterOptSnapshot {
                kind: "sgdm".into(),
                t: Vec::new(),
                tensors: vec![mom.clone()],
            },
            OuterOpt::Nesterov { mom, .. } => OuterOptSnapshot {
                kind: "nesterov".into(),
                t: Vec::new(),
                tensors: vec![mom.clone()],
            },
            OuterOpt::Adam { t, m, v, .. } => OuterOptSnapshot {
                kind: "adam".into(),
                t: t.clone(),
                tensors: vec![m.clone(), v.clone()],
            },
        }
    }

    /// Rebuild an optimizer from config hyperparameters plus a state
    /// snapshot. Bitwise: stepping the restored optimizer continues the
    /// saved trajectory exactly (the resume integration tests pin this).
    /// `max_fragments` bounds the Adam per-fragment step vector (the
    /// run's fragment count): a longer or absurd-valued `t` from a
    /// corrupted checkpoint is rejected here instead of silently
    /// skewing bias correction.
    pub fn restore(
        cfg: &OuterOptConfig,
        zeros: &Tensors,
        snap: OuterOptSnapshot,
        max_fragments: usize,
    ) -> anyhow::Result<OuterOpt> {
        let mut opt = OuterOpt::new(cfg, zeros);
        anyhow::ensure!(
            opt.name() == snap.kind,
            "checkpoint outer optimizer is {:?}, config wants {:?}",
            snap.kind,
            opt.name()
        );
        anyhow::ensure!(
            snap.t.len() <= max_fragments,
            "outer optimizer snapshot has {} per-fragment step counters, \
             the run has {max_fragments} fragments",
            snap.t.len()
        );
        anyhow::ensure!(
            snap.t.iter().all(|&s| s <= u32::MAX as u64),
            "outer optimizer snapshot has an implausible step counter"
        );
        anyhow::ensure!(
            matches!(cfg, OuterOptConfig::Adam { .. }) || snap.t.is_empty(),
            "non-Adam outer optimizer snapshot carries step counters"
        );
        let want = match &opt {
            OuterOpt::Sgd { .. } => 0,
            OuterOpt::SgdM { .. } | OuterOpt::Nesterov { .. } => 1,
            OuterOpt::Adam { .. } => 2,
        };
        anyhow::ensure!(
            snap.tensors.len() == want,
            "outer optimizer snapshot has {} state tensors, {:?} wants {want}",
            snap.tensors.len(),
            snap.kind
        );
        let mut it = snap.tensors.into_iter();
        match &mut opt {
            OuterOpt::Sgd { .. } => {}
            OuterOpt::SgdM { mom, .. } | OuterOpt::Nesterov { mom, .. } => {
                *mom = it.next().unwrap();
            }
            OuterOpt::Adam { t, m, v, .. } => {
                *t = snap.t;
                *m = it.next().unwrap();
                *v = it.next().unwrap();
            }
        }
        Ok(opt)
    }
}

/// Serializable mutable state of an [`OuterOpt`] (see
/// [`OuterOpt::snapshot`] / [`OuterOpt::restore`]).
#[derive(Clone, Debug, PartialEq)]
pub struct OuterOptSnapshot {
    /// Optimizer kind name, checked against the config on restore.
    pub kind: String,
    /// Adam's per-fragment step counters (empty for other kinds).
    pub t: Vec<u64>,
    /// Manifest-shaped state tensors: `[mom]` for momentum kinds,
    /// `[m, v]` for Adam, empty for plain SGD.
    pub tensors: Vec<Tensors>,
}

/// Visit `f(param, avg)` over every fragment element, in slice order.
fn for_slices(
    params: &mut Tensors,
    slices: &[LeafSlice],
    avg: &[f32],
    mut f: impl FnMut(&mut f32, f32),
) {
    let mut off = 0usize;
    for s in slices {
        let p = &mut params.leaves_mut()[s.leaf][s.start..s.end];
        for (pi, &d) in p.iter_mut().zip(&avg[off..off + s.len()]) {
            f(pi, d);
        }
        off += s.len();
    }
}

/// As [`for_slices`], with a second tensor tree (optimizer state).
fn for_slices2(
    params: &mut Tensors,
    state: &mut Tensors,
    slices: &[LeafSlice],
    avg: &[f32],
    mut f: impl FnMut(&mut f32, &mut f32, f32),
) {
    let mut off = 0usize;
    for s in slices {
        let n = s.len();
        let p_leaf = &mut params.leaves_mut()[s.leaf];
        let s_leaf = &mut state.leaves_mut()[s.leaf];
        for (j, i) in (s.start..s.end).enumerate() {
            f(&mut p_leaf[i], &mut s_leaf[i], avg[off + j]);
        }
        off += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn toy(vals: &[f32]) -> Tensors {
        tensors_from(vals)
    }

    /// Split into two leaves to exercise multi-leaf paths.
    fn tensors_from(vals: &[f32]) -> Tensors {
        let mid = vals.len() / 2;
        Tensors::from_raw(vec![vals[..mid].to_vec(), vals[mid..].to_vec()])
    }

    #[test]
    fn sgd_is_plain_descent() {
        let mut p = toy(&[1.0, 2.0, 3.0, 4.0]);
        let d = toy(&[0.5, 0.5, 0.5, 0.5]);
        let mut opt = OuterOpt::new(&OuterOptConfig::Sgd { lr: 1.0 }, &{
            let mut z = p.clone();
            z.scale(0.0);
            z
        });
        opt.step(&mut p, &d);
        let got: Vec<f32> = p.iter_flat().collect();
        assert_eq!(got, vec![0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn nesterov_mu_zero_equals_sgd() {
        check("nesterov(mu=0) == sgd", 40, |g| {
            let vals = g.f32_vec(2..40, 2.0);
            let dvals = g.f32_vec(2..40, 1.0);
            let n = vals.len().min(dvals.len()).max(2);
            let p0 = tensors_from(&vals[..n]);
            let d = tensors_from(&dvals[..n]);
            let mut z = p0.clone();
            z.scale(0.0);
            let lr = g.f64_in(0.01..1.0) as f32;
            let mut p_sgd = p0.clone();
            let mut p_nes = p0.clone();
            OuterOpt::new(&OuterOptConfig::Sgd { lr }, &z).step(&mut p_sgd, &d);
            OuterOpt::new(&OuterOptConfig::Nesterov { lr, mu: 0.0 }, &z)
                .step(&mut p_nes, &d);
            for (a, b) in p_sgd.iter_flat().zip(p_nes.iter_flat()) {
                assert!((a - b).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn nesterov_matches_reference_recurrence() {
        // Scalar trace mirroring kernels/ref.py nesterov_update.
        let mut p = tensors_from(&[1.0, 1.0]);
        let d = tensors_from(&[0.1, 0.1]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(&OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 }, &z);
        // Step 1: mom=0.1, p = 1 - 0.7*(0.1 + 0.09) = 0.867
        opt.step(&mut p, &d);
        for x in p.iter_flat() {
            assert!((x - 0.867).abs() < 1e-5, "{x}");
        }
        // Step 2: mom = 0.09+0.1 = 0.19; p = 0.867 - 0.7*(0.1 + 0.171)
        opt.step(&mut p, &d);
        for x in p.iter_flat() {
            assert!((x - (0.867 - 0.7 * 0.271)).abs() < 1e-5, "{x}");
        }
    }

    #[test]
    fn sgdm_accumulates_momentum() {
        let mut p = tensors_from(&[0.0, 0.0]);
        let d = tensors_from(&[1.0, 1.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(&OuterOptConfig::SgdM { lr: 1.0, mu: 0.5 }, &z);
        opt.step(&mut p, &d); // mom=1, p=-1
        opt.step(&mut p, &d); // mom=1.5, p=-2.5
        for x in p.iter_flat() {
            assert!((x + 2.5).abs() < 1e-6, "{x}");
        }
    }

    #[test]
    fn adam_bias_correction_first_step() {
        // With b1=b2=0.9/0.999, step 1: m_hat = g, v_hat = g², so the
        // update is lr·g/(|g|+ε) ≈ lr·sign(g).
        let mut p = tensors_from(&[0.0, 0.0, 0.0, 0.0]);
        let d = tensors_from(&[0.5, -0.5, 2.0, -2.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let mut opt = OuterOpt::new(
            &OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.999, eps: 1e-8 },
            &z,
        );
        opt.step(&mut p, &d);
        for (x, g) in p.iter_flat().zip([0.5f32, -0.5, 2.0, -2.0]) {
            assert!((x + 0.3 * g.signum()).abs() < 1e-4, "{x} vs {}", g.signum());
        }
    }

    #[test]
    fn prop_fragment_steps_assemble_to_monolithic_bitwise() {
        // Applying each fragment's slice of the averaged delta through
        // step_fragment must equal one monolithic step bitwise, for
        // every optimizer, over several rounds (momentum state carries).
        use crate::comm::fragment::FragmentPlan;
        check("Σ fragment steps == monolithic step", 30, |g| {
            let len = g.usize_in(2..40);
            let n = if len % 2 == 1 { len + 1 } else { len };
            let init: Vec<f32> = g.f32_vec(n..n + 1, 2.0);
            let mut init = init;
            init.resize(n, 0.0);
            let p = g.usize_in(1..6);
            for cfg in [
                OuterOptConfig::Sgd { lr: 0.5 },
                OuterOptConfig::SgdM { lr: 0.5, mu: 0.8 },
                OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
                OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
            ] {
                let mut mono = tensors_from(&init);
                let mut frag = mono.clone();
                let mut z = mono.clone();
                z.scale(0.0);
                let mut opt_mono = OuterOpt::new(&cfg, &z);
                let mut opt_frag = OuterOpt::new(&cfg, &z);
                let plan = FragmentPlan::for_tensors(&mono, p);
                for _round in 0..3 {
                    let mut d = g.f32_vec(n..n + 1, 1.0);
                    d.resize(n, 0.0);
                    let delta = tensors_from(&d);
                    opt_mono.step(&mut mono, &delta);
                    // Every fragment steps once per round, so each
                    // per-fragment Adam counter matches the monolithic t.
                    for f in 0..plan.n_fragments() {
                        let payload = plan.extract(&delta, f);
                        opt_frag.step_fragment(&mut frag, &payload, plan.slices(f), f);
                    }
                }
                for (a, b) in mono.iter_flat().zip(frag.iter_flat()) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{}: {a} != {b}",
                        opt_mono.name()
                    );
                }
            }
        });
    }

    #[test]
    fn adam_bias_correction_is_per_fragment() {
        // Fragment 1 stepping for the first time must get first-step
        // bias correction even after fragment 0 has stepped many times
        // (staggered schedules sync fragments at different cadences).
        use crate::comm::fragment::LeafSlice;
        let mut p = tensors_from(&[0.0, 0.0, 0.0, 0.0]);
        let mut z = p.clone();
        z.scale(0.0);
        let cfg = OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.999, eps: 1e-8 };
        let mut opt = OuterOpt::new(&cfg, &z);
        // p has two leaves of 2; fragment 0 = leaf 0, fragment 1 = leaf 1.
        let f0 = [LeafSlice { leaf: 0, start: 0, end: 2 }];
        let f1 = [LeafSlice { leaf: 1, start: 0, end: 2 }];
        for _ in 0..5 {
            opt.step_fragment(&mut p, &[0.5, 0.5], &f0, 0);
        }
        opt.step_fragment(&mut p, &[0.5, 0.5], &f1, 1);
        // First Adam step ⇒ update ≈ lr·sign(g) on fragment 1.
        let got: Vec<f32> = p.iter_flat().collect();
        assert!((got[2] + 0.3).abs() < 1e-4, "{}", got[2]);
        assert!((got[3] + 0.3).abs() < 1e-4, "{}", got[3]);
        // Fragment 0 advanced 5 steps and moved further.
        assert!(got[0] < got[2], "{} vs {}", got[0], got[2]);
    }

    #[test]
    fn snapshot_restore_continues_trajectory_bitwise() {
        // For every optimizer kind: step twice straight vs step once,
        // snapshot, restore into a fresh optimizer, step again — the
        // parameters must agree bit for bit (the resume contract at the
        // optimizer layer).
        for cfg in [
            OuterOptConfig::Sgd { lr: 0.5 },
            OuterOptConfig::SgdM { lr: 0.5, mu: 0.8 },
            OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
            OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
        ] {
            let init = tensors_from(&[1.0, -2.0, 0.5, 3.0]);
            let d1 = tensors_from(&[0.1, 0.2, -0.3, 0.4]);
            let d2 = tensors_from(&[-0.2, 0.1, 0.5, -0.1]);
            let mut z = init.clone();
            z.scale(0.0);

            let mut straight = init.clone();
            let mut opt = OuterOpt::new(&cfg, &z);
            opt.step(&mut straight, &d1);
            opt.step(&mut straight, &d2);

            let mut resumed = init.clone();
            let mut opt_a = OuterOpt::new(&cfg, &z);
            opt_a.step(&mut resumed, &d1);
            let snap = opt_a.snapshot();
            let mut opt_b = OuterOpt::restore(&cfg, &z, snap, 1).unwrap();
            opt_b.step(&mut resumed, &d2);

            for (a, b) in straight.iter_flat().zip(resumed.iter_flat()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", opt_b.name());
            }
        }
    }

    #[test]
    fn restore_rejects_kind_mismatch() {
        let z = {
            let mut z = tensors_from(&[0.0, 0.0]);
            z.scale(0.0);
            z
        };
        let snap = OuterOpt::new(&OuterOptConfig::Sgd { lr: 1.0 }, &z).snapshot();
        assert!(OuterOpt::restore(
            &OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 },
            &z,
            snap,
            1
        )
        .is_err());
        // An Adam snapshot whose step vector outruns the run's fragment
        // count (a corrupted checkpoint) is rejected, not resized away.
        let adam = OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 };
        let mut opt = OuterOpt::new(&adam, &z);
        let mut p = tensors_from(&[0.0, 0.0]);
        opt.step(&mut p, &z);
        let snap = opt.snapshot();
        assert!(OuterOpt::restore(&adam, &z, snap.clone(), 0).is_err());
        assert!(OuterOpt::restore(&adam, &z, snap, 1).is_ok());
    }

    #[test]
    fn zero_delta_sgd_and_adam_are_stationary() {
        let mut p = tensors_from(&[1.0, -1.0]);
        let zero = {
            let mut z = p.clone();
            z.scale(0.0);
            z
        };
        let mut sgd = OuterOpt::new(&OuterOptConfig::Sgd { lr: 0.7 }, &zero);
        let before: Vec<f32> = p.iter_flat().collect();
        sgd.step(&mut p, &zero);
        assert_eq!(before, p.iter_flat().collect::<Vec<f32>>());
        let mut adam = OuterOpt::new(
            &OuterOptConfig::Adam { lr: 0.3, b1: 0.9, b2: 0.95, eps: 0.1 },
            &zero,
        );
        adam.step(&mut p, &zero);
        assert_eq!(before, p.iter_flat().collect::<Vec<f32>>());
    }
}
