//! Pluggable outer aggregation — the `Aggregator` trait (ROADMAP item 4).
//!
//! Every outer step used to be hard-wired to the flat weighted mean in
//! [`super::average`]; one NaN-bombing island poisoned the global model
//! in a single round. This module makes the per-fragment reduction a
//! first-class seam: [`WeightedMean`] is the bitwise-default
//! implementation (delegating to the same audited fused kernel the
//! legacy trio used), and [`TrimmedMean`], [`CoordinateMedian`], and
//! [`Krum`] are Byzantine-robust alternatives selected via the
//! `[aggregate]` TOML section or `--aggregate` on the CLI.
//!
//! # Determinism contract (DESIGN.md §16)
//!
//! Every estimator performs a *fixed scalar-op order* that depends only
//! on the payload order and values:
//!
//! - [`WeightedMean`] delegates to
//!   [`average::fused_weighted_mean_into`], whose per-element sequence
//!   is pinned by the PR-6 property tests.
//! - [`TrimmedMean`] / [`CoordinateMedian`] sort each coordinate's
//!   column with a *stable* insertion sort under strict `<` (no NaN can
//!   reach the sort: non-finite contributions are rejected up front), so
//!   equal values keep payload order and the surviving-value fold is the
//!   left-to-right [`math::sum_f64`] kernel.
//! - [`Krum`]'s pairwise distance matrix routes through the audited
//!   [`math::sq_dist`] kernel, neighbor distances are sorted with
//!   `f64::total_cmp`, scores are summed left-to-right, and argmin
//!   tie-breaks to the lowest payload index — the whole selection is a
//!   pure function of the payloads, which is why it stays inside the
//!   deterministic zone.
//!
//! Float *folds* (totals, score sums) route through the audited
//! `util::math` kernels; everything else is per-element arithmetic,
//! which D4 does not constrain.
//!
//! # Rejection semantics
//!
//! The robust estimators treat a contribution with *any* non-finite
//! element as wholly compromised and drop it before estimating.
//! [`WeightedMean`] performs **no** filtering — it is the bitwise legacy
//! path, and a NaN there propagates to the global model where the
//! coordinator's `all_finite` ensure fails the run loudly. If no finite
//! contribution survives, the robust estimators emit an all-zero
//! fragment (the outer step becomes a no-op) and report everything
//! rejected.
//!
//! ```
//! use diloco::coordinator::aggregate::{Aggregator, TrimmedMean};
//! use diloco::coordinator::scratch::RoundScratch;
//!
//! // One colluding outlier among three workers: trimming one value from
//! // each end of every coordinate leaves the honest middle.
//! let a = [1.0f32, 1.0];
//! let b = [1.0f32, 3.0];
//! let c = [100.0f32, -100.0];
//! let mut scratch = RoundScratch::new();
//! let mut out = Vec::new();
//! let agg = TrimmedMean { trim: 1 };
//! let outcome =
//!     agg.aggregate_into(&[&a, &b, &c], &[1.0, 1.0, 1.0], &mut scratch, &mut out);
//! assert_eq!(out, vec![1.0, 1.0]);
//! assert_eq!(outcome.rejected, 0);
//! assert!((outcome.trimmed_mass - 2.0 / 3.0).abs() < 1e-12);
//! ```

use crate::config::AggregateConfig;
use crate::coordinator::average;
use crate::coordinator::scratch::RoundScratch;
use crate::util::math;

/// What an aggregation call filtered out, for [`super::RoundStats`]'
/// per-round `rejected` / `trimmed_mass` columns.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AggregateOutcome {
    /// Contributions excluded from the estimate entirely: non-finite
    /// payloads under every robust estimator, plus (for [`Krum`]) the
    /// finite payloads that were not selected.
    pub rejected: usize,
    /// Fraction of the total contributor weight that did not enter the
    /// final estimate (rejected weight plus, for the coordinate-wise
    /// estimators, the count-normalized share trimmed per coordinate).
    /// 0.0 on the mean path, 1.0 when nothing survived.
    pub trimmed_mass: f64,
}

/// A per-fragment reduction strategy over flat wire payloads.
///
/// `payloads` are the contributors' fragment slices (equal length),
/// `weights` their unnormalized averaging weights (shard sizes ×
/// staleness discounts — exactly what the mean path always received).
/// `out` is cleared and filled with the aggregated fragment; column
/// buffers are leased from `scratch`, so steady-state rounds allocate
/// nothing.
///
/// ```
/// use diloco::coordinator::aggregate::{Aggregator, CoordinateMedian, WeightedMean};
/// use diloco::coordinator::scratch::RoundScratch;
///
/// let mut scratch = RoundScratch::new();
/// let mut out = Vec::new();
/// let p = [2.0f32, 4.0];
/// let q = [4.0f32, 8.0];
/// WeightedMean.aggregate_into(&[&p, &q], &[1.0, 1.0], &mut scratch, &mut out);
/// assert_eq!(out, vec![3.0, 6.0]);
/// // The median of an even column is the midpoint of the two middles.
/// CoordinateMedian.aggregate_into(&[&p, &q], &[1.0, 1.0], &mut scratch, &mut out);
/// assert_eq!(out, vec![3.0, 6.0]);
/// ```
pub trait Aggregator: Send + Sync {
    /// Reduce `payloads` into `out`, returning what was filtered.
    fn aggregate_into(
        &self,
        payloads: &[&[f32]],
        weights: &[f64],
        scratch: &mut RoundScratch,
        out: &mut Vec<f32>,
    ) -> AggregateOutcome;

    /// Stable identifier (`mean`, `trimmed`, `median`, `krum`) for
    /// logs and bench rows.
    fn name(&self) -> &'static str;

    /// True only for [`WeightedMean`]: the coordinator keeps the
    /// parallel per-fragment reduction (and the opt-in `fast_math`
    /// pairwise tree) on the mean path, and runs robust estimators
    /// serially against the shared scratch arena.
    fn is_mean(&self) -> bool {
        false
    }
}

/// The bitwise-default aggregator: the exact legacy weighted mean,
/// delegating to the audited fused kernel
/// ([`average::fused_weighted_mean_into`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct WeightedMean;

impl WeightedMean {
    /// Allocation-free weighted mean over any payload representation —
    /// the generic entry point the coordinator's parallel reduction and
    /// the benches call directly (trait objects cannot be generic).
    /// Bitwise-identical to the deprecated `weighted_average_into`.
    pub fn mean_into<P: AsRef<[f32]>>(
        &self,
        payloads: &[P],
        weights: &[f64],
        norm: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        average::fused_weighted_mean_into(payloads, weights, norm, out);
    }

    /// Allocating convenience over [`mean_into`](Self::mean_into) —
    /// the migration target for `weighted_average_flat` /
    /// `weighted_average_refs` call sites off the hot path.
    ///
    /// ```
    /// use diloco::coordinator::aggregate::WeightedMean;
    ///
    /// let a = [0.0f32, 2.0];
    /// let b = [4.0f32, 6.0];
    /// assert_eq!(WeightedMean.mean(&[&a, &b], &[1.0, 1.0]), vec![2.0, 4.0]);
    /// ```
    pub fn mean<P: AsRef<[f32]>>(&self, payloads: &[P], weights: &[f64]) -> Vec<f32> {
        let mut norm = Vec::new();
        let mut out = Vec::new();
        self.mean_into(payloads, weights, &mut norm, &mut out);
        out
    }
}

impl Aggregator for WeightedMean {
    fn aggregate_into(
        &self,
        payloads: &[&[f32]],
        weights: &[f64],
        scratch: &mut RoundScratch,
        out: &mut Vec<f32>,
    ) -> AggregateOutcome {
        let mut norm = scratch.lease();
        self.mean_into(payloads, weights, &mut norm, out);
        scratch.recycle(norm);
        AggregateOutcome::default()
    }

    fn name(&self) -> &'static str {
        "mean"
    }

    fn is_mean(&self) -> bool {
        true
    }
}

/// Coordinate-wise trimmed weighted mean: per coordinate, sort the
/// surviving contributions by value and drop the `trim` lowest and
/// `trim` highest before the weighted mean of the remainder.
///
/// `trim = 0` with no non-finite contribution **delegates to the
/// [`WeightedMean`] kernel**, so that configuration is bitwise equal to
/// the mean path by construction (an acceptance criterion, pinned by
/// integration tests on star, ring, and gossip). When churn shrinks the
/// roster below `2·trim + 1` contributors the effective trim shrinks to
/// `(m − 1) / 2` so at least one value always survives.
#[derive(Clone, Copy, Debug)]
pub struct TrimmedMean {
    /// Values dropped from *each* end of every coordinate's column.
    pub trim: usize,
}

/// Coordinate-wise median (weights are ignored in the estimate — the
/// median of an even-sized column is the midpoint of the two middle
/// values, computed in f64). The classic high-breakdown estimator: up
/// to ⌊(m−1)/2⌋ colluding workers cannot move any coordinate outside
/// the honest value range.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinateMedian;

/// Krum (Blanchard et al., NeurIPS 2017): select the single
/// contribution whose summed squared distance to its `m − f − 2`
/// nearest neighbors is smallest — the payload most surrounded by
/// agreeing peers. Needs `m ≥ 2f + 3` for its Byzantine guarantee
/// (config-validated); when churn shrinks the roster mid-run the
/// effective `f` shrinks to keep the score well-defined rather than
/// failing the round.
#[derive(Clone, Copy, Debug)]
pub struct Krum {
    /// Number of Byzantine contributors the selection must tolerate.
    pub f: usize,
}

/// Shared preamble for the robust estimators: partition contributor
/// indices into finite survivors and non-finite rejects, in payload
/// order.
fn finite_survivors(payloads: &[&[f32]], survivors: &mut Vec<usize>) -> usize {
    survivors.clear();
    let mut rejected = 0usize;
    for (i, p) in payloads.iter().enumerate() {
        if p.iter().all(|x| x.is_finite()) {
            survivors.push(i);
        } else {
            rejected += 1;
        }
    }
    rejected
}

/// Everything-rejected fallback: a zero fragment (the outer step
/// becomes a no-op for this fragment) and full trimmed mass.
fn all_rejected(n: usize, m: usize, out: &mut Vec<f32>) -> AggregateOutcome {
    out.clear();
    out.resize(n, 0.0);
    AggregateOutcome { rejected: m, trimmed_mass: 1.0 }
}

fn check_arity(payloads: &[&[f32]], weights: &[f64]) -> usize {
    assert!(!payloads.is_empty(), "no fragment payloads to aggregate");
    assert_eq!(payloads.len(), weights.len());
    let n = payloads[0].len();
    for p in payloads {
        assert_eq!(p.len(), n, "payload arity");
    }
    n
}

/// Stable ascending insertion co-sort of `vals` with `wts` carried
/// along. Strict `>` comparison keeps equal values in payload order;
/// callers guarantee no NaN reaches this point.
fn co_sort(vals: &mut [f64], wts: &mut [f64]) {
    for i in 1..vals.len() {
        let mut j = i;
        while j > 0 && vals[j - 1] > vals[j] {
            vals.swap(j - 1, j);
            wts.swap(j - 1, j);
            j -= 1;
        }
    }
}

impl Aggregator for TrimmedMean {
    fn aggregate_into(
        &self,
        payloads: &[&[f32]],
        weights: &[f64],
        scratch: &mut RoundScratch,
        out: &mut Vec<f32>,
    ) -> AggregateOutcome {
        let n = check_arity(payloads, weights);
        let mut survivors: Vec<usize> = Vec::with_capacity(payloads.len());
        let rejected = finite_survivors(payloads, &mut survivors);
        let m = survivors.len();
        if m == 0 {
            return all_rejected(n, payloads.len(), out);
        }
        if self.trim == 0 && rejected == 0 {
            // Bitwise fast path: exactly the mean kernel.
            let mut norm = scratch.lease();
            WeightedMean.mean_into(payloads, weights, &mut norm, out);
            scratch.recycle(norm);
            return AggregateOutcome::default();
        }
        let e = self.trim.min((m - 1) / 2);
        let w_total = math::sum_f64(weights);
        assert!(w_total > 0.0, "all-zero averaging weights");
        let mut vals = scratch.lease_f64();
        let mut wts = scratch.lease_f64();
        let mut prod = scratch.lease_f64();
        // Surviving weight is coordinate-independent: sum it once.
        wts.clear();
        for &i in &survivors {
            wts.push(weights[i]);
        }
        let w_surv = math::sum_f64(&wts);
        assert!(w_surv > 0.0, "all-zero surviving weights");
        out.clear();
        out.reserve(n);
        for c in 0..n {
            vals.clear();
            wts.clear();
            for &i in &survivors {
                vals.push(payloads[i][c] as f64);
                wts.push(weights[i]);
            }
            co_sort(&mut vals, &mut wts);
            let keep = e..m - e;
            prod.clear();
            for j in keep.clone() {
                prod.push(vals[j] * wts[j]);
            }
            let num = math::sum_f64(&prod);
            let den = math::sum_f64(&wts[keep]);
            out.push((num / den) as f32);
        }
        scratch.recycle_f64(vals);
        scratch.recycle_f64(wts);
        scratch.recycle_f64(prod);
        let trimmed =
            (w_total - w_surv + (2 * e) as f64 / m as f64 * w_surv) / w_total;
        AggregateOutcome { rejected, trimmed_mass: trimmed }
    }

    fn name(&self) -> &'static str {
        "trimmed"
    }
}

impl Aggregator for CoordinateMedian {
    fn aggregate_into(
        &self,
        payloads: &[&[f32]],
        weights: &[f64],
        scratch: &mut RoundScratch,
        out: &mut Vec<f32>,
    ) -> AggregateOutcome {
        let n = check_arity(payloads, weights);
        let mut survivors: Vec<usize> = Vec::with_capacity(payloads.len());
        let rejected = finite_survivors(payloads, &mut survivors);
        let m = survivors.len();
        if m == 0 {
            return all_rejected(n, payloads.len(), out);
        }
        let w_total = math::sum_f64(weights);
        assert!(w_total > 0.0, "all-zero averaging weights");
        let mut wts = scratch.lease_f64();
        wts.clear();
        for &i in &survivors {
            wts.push(weights[i]);
        }
        let w_surv = math::sum_f64(&wts);
        let mut vals = scratch.lease_f64();
        out.clear();
        out.reserve(n);
        for c in 0..n {
            vals.clear();
            for &i in &survivors {
                vals.push(payloads[i][c] as f64);
            }
            // Stable insertion sort, same discipline as the trimmed mean.
            for i in 1..vals.len() {
                let mut j = i;
                while j > 0 && vals[j - 1] > vals[j] {
                    vals.swap(j - 1, j);
                    j -= 1;
                }
            }
            let est = if m % 2 == 1 {
                vals[m / 2]
            } else {
                (vals[m / 2 - 1] + vals[m / 2]) * 0.5
            };
            out.push(est as f32);
        }
        scratch.recycle_f64(vals);
        scratch.recycle_f64(wts);
        let used = if m % 2 == 1 { 1 } else { 2 };
        let trimmed =
            (w_total - w_surv + (m - used) as f64 / m as f64 * w_surv) / w_total;
        AggregateOutcome { rejected, trimmed_mass: trimmed }
    }

    fn name(&self) -> &'static str {
        "median"
    }
}

impl Aggregator for Krum {
    fn aggregate_into(
        &self,
        payloads: &[&[f32]],
        weights: &[f64],
        scratch: &mut RoundScratch,
        out: &mut Vec<f32>,
    ) -> AggregateOutcome {
        let n = check_arity(payloads, weights);
        let mut survivors: Vec<usize> = Vec::with_capacity(payloads.len());
        let _nonfinite = finite_survivors(payloads, &mut survivors);
        let m = survivors.len();
        if m == 0 {
            return all_rejected(n, payloads.len(), out);
        }
        let w_total = math::sum_f64(weights);
        assert!(w_total > 0.0, "all-zero averaging weights");
        let selected = if m == 1 {
            survivors[0]
        } else {
            // Effective f shrinks with the live roster so the neighbor
            // count m − f − 2 stays ≥ 1 whenever m ≥ 3 (validate()
            // guarantees m ≥ 2f + 3 at full roster).
            let ef = self.f.min(m.saturating_sub(3) / 2);
            let q = m.saturating_sub(ef + 2).max(1).min(m - 1);
            // Pairwise squared distances through the audited kernel;
            // symmetric, so each pair is computed once and mirrored.
            let mut mat = scratch.lease_f64();
            mat.clear();
            mat.resize(m * m, 0.0);
            for a in 0..m {
                for b in a + 1..m {
                    let d =
                        math::sq_dist(payloads[survivors[a]], payloads[survivors[b]]);
                    mat[a * m + b] = d;
                    mat[b * m + a] = d;
                }
            }
            let mut row = scratch.lease_f64();
            let mut best: Option<(f64, usize)> = None;
            for a in 0..m {
                row.clear();
                for b in 0..m {
                    if b != a {
                        row.push(mat[a * m + b]);
                    }
                }
                row.sort_by(f64::total_cmp);
                let score = math::sum_f64(&row[..q]);
                // Strict < keeps the lowest payload index on ties.
                let better = match best {
                    None => true,
                    Some((s, _)) => score < s,
                };
                if better {
                    best = Some((score, survivors[a]));
                }
            }
            scratch.recycle_f64(mat);
            scratch.recycle_f64(row);
            best.expect("non-empty survivor set").1
        };
        out.clear();
        out.extend_from_slice(payloads[selected]);
        AggregateOutcome {
            rejected: payloads.len() - 1,
            trimmed_mass: (w_total - weights[selected]) / w_total,
        }
    }

    fn name(&self) -> &'static str {
        "krum"
    }
}

/// Instantiate the configured aggregator (`[aggregate]` TOML /
/// `--aggregate` CLI). Lives here rather than in `config` because the
/// config crate layer cannot depend on the coordinator.
pub fn build(cfg: &AggregateConfig) -> Box<dyn Aggregator> {
    match *cfg {
        AggregateConfig::WeightedMean => Box::new(WeightedMean),
        AggregateConfig::TrimmedMean { trim } => Box::new(TrimmedMean { trim }),
        AggregateConfig::CoordinateMedian => Box::new(CoordinateMedian),
        AggregateConfig::Krum { f } => Box::new(Krum { f }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn run(
        agg: &dyn Aggregator,
        payloads: &[&[f32]],
        weights: &[f64],
    ) -> (Vec<f32>, AggregateOutcome) {
        let mut scratch = RoundScratch::new();
        let mut out = vec![f32::NAN; 3]; // dirty scratch
        let outcome = agg.aggregate_into(payloads, weights, &mut scratch, &mut out);
        (out, outcome)
    }

    #[test]
    fn build_maps_every_config_variant() {
        use crate::config::AggregateConfig as C;
        assert_eq!(build(&C::WeightedMean).name(), "mean");
        assert_eq!(build(&C::TrimmedMean { trim: 1 }).name(), "trimmed");
        assert_eq!(build(&C::CoordinateMedian).name(), "median");
        assert_eq!(build(&C::Krum { f: 1 }).name(), "krum");
        assert!(build(&C::WeightedMean).is_mean());
        assert!(!build(&C::TrimmedMean { trim: 0 }).is_mean());
    }

    #[test]
    fn prop_trim_zero_no_attackers_is_bitwise_mean() {
        // The acceptance-criterion identity at the unit level: with all
        // contributions finite and trim = 0 the robust path IS the mean
        // kernel (structural delegation), for every length and weight.
        check("TrimmedMean{0} == WeightedMean bitwise", 60, |g| {
            let k = g.usize_in(1..7);
            let n = g.usize_in(1..50);
            let payloads: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    let mut v = g.f32_vec(n..n + 1, 3.0);
                    v.resize(n, 0.0);
                    v
                })
                .collect();
            let weights: Vec<f64> = (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
            let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
            let (mean, om) = run(&WeightedMean, &refs, &weights);
            let (trim, ot) = run(&TrimmedMean { trim: 0 }, &refs, &weights);
            assert_eq!(om, ot);
            assert_eq!(mean.len(), trim.len());
            for (a, b) in mean.iter().zip(&trim) {
                assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
            }
        });
    }

    #[test]
    fn trimmed_mean_drops_outliers_and_accounts_mass() {
        let a = [1.0f32, 1.0];
        let b = [1.0f32, 3.0];
        let c = [100.0f32, -100.0];
        let (out, o) =
            run(&TrimmedMean { trim: 1 }, &[&a, &b, &c], &[1.0, 1.0, 1.0]);
        assert_eq!(out, vec![1.0, 1.0]);
        assert_eq!(o.rejected, 0);
        assert!((o.trimmed_mass - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn trimmed_mean_weights_survivors() {
        // Columns sorted: [0, 6, 100] with weights [1, 3, 1]; trim=1
        // keeps the middle value only — its weight cancels out.
        let (out, _) = run(
            &TrimmedMean { trim: 1 },
            &[&[0.0f32], &[6.0f32], &[100.0f32]],
            &[1.0, 3.0, 1.0],
        );
        assert_eq!(out, vec![6.0]);
        // trim=1 over 5 values keeps the middle 3, weighted.
        let (out, _) = run(
            &TrimmedMean { trim: 1 },
            &[&[0.0f32], &[2.0f32], &[4.0f32], &[6.0f32], &[100.0f32]],
            &[1.0, 1.0, 3.0, 1.0, 1.0],
        );
        // survivors 2,4,6 with weights 1,3,1 → (2 + 12 + 6)/5 = 4.0
        assert_eq!(out, vec![4.0]);
    }

    #[test]
    fn trimmed_mean_rejects_nonfinite_then_trims_what_is_left() {
        let nan = [f32::NAN, 1.0];
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let c = [5.0f32, 6.0];
        let (out, o) = run(
            &TrimmedMean { trim: 1 },
            &[&nan, &a, &b, &c],
            &[1.0, 1.0, 1.0, 1.0],
        );
        assert_eq!(o.rejected, 1);
        assert_eq!(out, vec![3.0, 4.0]); // middle of the 3 finite rows
        assert!(o.trimmed_mass > 0.0 && o.trimmed_mass < 1.0);
    }

    #[test]
    fn trimmed_mean_effective_trim_shrinks_with_roster() {
        // trim=2 over m=3 would drop everything; effective trim is 1.
        let (out, _) = run(
            &TrimmedMean { trim: 2 },
            &[&[1.0f32], &[2.0f32], &[9.0f32]],
            &[1.0, 1.0, 1.0],
        );
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn median_odd_even_and_nan_rejection() {
        let (out, _) = run(
            &CoordinateMedian,
            &[&[1.0f32], &[9.0f32], &[2.0f32]],
            &[1.0, 1.0, 1.0],
        );
        assert_eq!(out, vec![2.0]);
        let (out, _) =
            run(&CoordinateMedian, &[&[1.0f32], &[3.0f32]], &[1.0, 1.0]);
        assert_eq!(out, vec![2.0]);
        let nan = [f32::NAN];
        let (out, o) = run(
            &CoordinateMedian,
            &[&nan, &[1.0f32], &[5.0f32], &[2.0f32]],
            &[1.0, 1.0, 1.0, 1.0],
        );
        assert_eq!(o.rejected, 1);
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn median_ignores_weights_in_the_estimate() {
        let (out, _) = run(
            &CoordinateMedian,
            &[&[1.0f32], &[2.0f32], &[100.0f32]],
            &[0.1, 0.1, 100.0],
        );
        assert_eq!(out, vec![2.0]);
    }

    #[test]
    fn krum_selects_the_most_surrounded_payload() {
        // Three near-identical honest rows and one far outlier: the
        // outlier's neighbor distances are huge, any honest row wins;
        // tie-break selects the lowest index among equal scores.
        let h0 = [1.0f32, 1.0];
        let h1 = [1.1f32, 1.0];
        let h2 = [0.9f32, 1.0];
        let bad = [50.0f32, -50.0];
        let (out, o) =
            run(&Krum { f: 1 }, &[&bad, &h0, &h1, &h2], &[1.0; 4]);
        assert_eq!(out, vec![1.0, 1.0]); // h0: lowest index among the cluster
        assert_eq!(o.rejected, 3);
        assert!((o.trimmed_mass - 0.75).abs() < 1e-12);
    }

    #[test]
    fn krum_rejects_nonfinite_and_survives_tiny_rosters() {
        let nan = [f32::NAN];
        let (out, _) =
            run(&Krum { f: 1 }, &[&nan, &[2.0f32]], &[1.0, 1.0]);
        assert_eq!(out, vec![2.0]);
        // Two finite rows, f too large for the roster: effective f
        // shrinks, scores tie, lowest index wins.
        let (out, _) =
            run(&Krum { f: 5 }, &[&[3.0f32], &[4.0f32]], &[1.0, 1.0]);
        assert_eq!(out, vec![3.0]);
    }

    #[test]
    fn all_nonfinite_contributions_yield_zero_fragment() {
        let nan = [f32::NAN, f32::INFINITY];
        for agg in [
            &TrimmedMean { trim: 1 } as &dyn Aggregator,
            &CoordinateMedian,
            &Krum { f: 0 },
        ] {
            let (out, o) = run(agg, &[&nan, &nan], &[1.0, 1.0]);
            assert_eq!(out, vec![0.0, 0.0], "{}", agg.name());
            assert_eq!(o.rejected, 2);
            assert_eq!(o.trimmed_mass, 1.0);
        }
    }

    #[test]
    fn prop_robust_estimates_stay_within_honest_bounds() {
        // With any minority of arbitrarily corrupted rows, trimmed mean
        // (trim ≥ #bad) and median stay within the elementwise honest
        // min/max envelope.
        check("robust estimators bounded by honest envelope", 40, |g| {
            let honest = g.usize_in(3..6);
            let n = g.usize_in(1..20);
            let mut payloads: Vec<Vec<f32>> = (0..honest)
                .map(|_| {
                    let mut v = g.f32_vec(n..n + 1, 2.0);
                    v.resize(n, 0.0);
                    v
                })
                .collect();
            let mut bad = vec![0.0f32; n];
            for x in bad.iter_mut() {
                *x = 1.0e6;
            }
            payloads.push(bad);
            let weights = vec![1.0f64; payloads.len()];
            let refs: Vec<&[f32]> = payloads.iter().map(|p| p.as_slice()).collect();
            for agg in
                [&TrimmedMean { trim: 1 } as &dyn Aggregator, &CoordinateMedian]
            {
                let (out, _) = run(agg, &refs, &weights);
                for c in 0..n {
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for p in payloads[..honest].iter() {
                        lo = lo.min(p[c]);
                        hi = hi.max(p[c]);
                    }
                    assert!(
                        out[c] >= lo - 1e-4 && out[c] <= hi + 1e-4,
                        "{}: coord {c} value {} outside honest [{lo}, {hi}]",
                        agg.name(),
                        out[c]
                    );
                }
            }
        });
    }

    #[test]
    fn estimators_are_deterministic_across_repeated_calls() {
        // Same inputs, fresh vs reused scratch: identical bits and
        // outcomes — the Aggregator determinism contract at unit scale.
        let a = [1.5f32, -2.0, 3.0];
        let b = [0.5f32, 2.0, -1.0];
        let c = [9.0f32, -9.0, 9.0];
        let refs: [&[f32]; 3] = [&a, &b, &c];
        let w = [1.0, 2.0, 0.5];
        for agg in [
            &WeightedMean as &dyn Aggregator,
            &TrimmedMean { trim: 1 },
            &CoordinateMedian,
            &Krum { f: 0 },
        ] {
            let (x, ox) = run(agg, &refs, &w);
            let mut scratch = RoundScratch::new();
            let mut out = Vec::new();
            // Warm the arena with a throwaway call, then re-run.
            agg.aggregate_into(&refs, &w, &mut scratch, &mut out);
            let oy = agg.aggregate_into(&refs, &w, &mut scratch, &mut out);
            assert_eq!(ox, oy, "{}", agg.name());
            assert_eq!(x.len(), out.len());
            for (p, q) in x.iter().zip(&out) {
                assert_eq!(p.to_bits(), q.to_bits(), "{}", agg.name());
            }
        }
    }
}
