//! Sign-based outer-gradient pruning (paper Table 6, after Yadav et al.
//! 2023 "TIES").
//!
//! Each replica prunes its own outer gradient before sending: per leaf,
//! (1) *elect* the dominant sign by magnitude-weighted vote, then
//! (2) zero a `frac` fraction of entries, discarding sign-disagreeing
//! entries first (smallest magnitude first within each class). The paper
//! reports ≤50% pruning costs ≈nothing (+0.39% PPL) while proportionally
//! cutting the already-infrequent communication.

use crate::runtime::Tensors;
use crate::util::math;

/// Prune `frac ∈ [0,1]` of each leaf's entries in place; returns the
/// number of zeroed entries (for communication accounting: only non-zero
/// values + a bitmap need to cross the wire — see
/// [`crate::comm::wire::sparse_payload_bytes`]).
///
/// Edge cases, all defined and tested: `frac == 0.0` is the identity;
/// `frac == 1.0` zeroes **every** entry of every leaf (`k == n`, so the
/// selection is skipped entirely and the payload ships as an empty
/// sparse fragment — bitmap only); a `NaN` entry always counts as
/// sign-disagreeing (`NaN.signum()` matches no elected sign) and ranks
/// via `f32::total_cmp`, so it is pruned ahead of agreeing values and
/// the selection stays a total order instead of silently arbitrary.
pub fn prune_sign(delta: &mut Tensors, frac: f64) -> usize {
    assert!((0.0..=1.0).contains(&frac), "frac in [0,1]");
    if frac == 0.0 {
        return 0;
    }
    let mut zeroed = 0usize;
    for leaf in delta.leaves_mut() {
        let n = leaf.len();
        let k = ((n as f64) * frac).floor() as usize;
        if k == 0 {
            continue;
        }
        // (1) elect sign by magnitude-weighted vote. The vote decides
        // which entries survive, so the sum goes through the audited
        // order-pinned kernel (D4) — same left-to-right fold, bitwise.
        let vote = math::sum_as_f64(leaf);
        let elected = if vote >= 0.0 { 1.0f32 } else { -1.0f32 };
        // (2) priority: disagreeing entries first, then by |value| asc.
        // O(n) selection instead of a full sort (§Perf: 18.0 → 1.9 ms on
        // the nano parameter set): rank by (agrees-with-elected, |value|)
        // lexicographically, then select_nth. (Not a single float key —
        // adding a large offset for the agreement class absorbs the
        // magnitude bits.)
        let key = |x: f32| -> (u8, f32) {
            let disagree = x.signum() != elected && x != 0.0;
            (u8::from(!disagree), x.abs()) // disagreeing rank lowest
        };
        let mut order: Vec<usize> = (0..n).collect();
        if k < n {
            order.select_nth_unstable_by(k, |&a, &b| {
                let (ca, ma) = key(leaf[a]);
                let (cb, mb) = key(leaf[b]);
                // total_cmp, not partial_cmp: a NaN magnitude under
                // partial_cmp yields Equal against everything, which is
                // not a total order — select_nth's result would be
                // arbitrary (same fix as bench::median's NaN regression).
                ca.cmp(&cb).then_with(|| ma.total_cmp(&mb))
            });
        }
        for &i in order.iter().take(k) {
            if leaf[i] != 0.0 {
                zeroed += 1;
            }
            leaf[i] = 0.0;
        }
    }
    zeroed
}

/// Bytes to transmit a pruned delta: non-zeros as f32 + 1 bit/position.
pub fn pruned_payload_bytes(total_elements: usize, zeroed: usize) -> u64 {
    let nonzero = total_elements - zeroed;
    (nonzero * 4) as u64 + (total_elements as u64).div_ceil(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn t(vals: &[f32]) -> Tensors {
        Tensors::from_raw(vec![vals.to_vec()])
    }

    #[test]
    fn zero_frac_is_identity() {
        let mut d = t(&[1.0, -2.0, 3.0]);
        let before = d.clone();
        assert_eq!(prune_sign(&mut d, 0.0), 0);
        assert_eq!(d, before);
    }

    #[test]
    fn prunes_exact_fraction() {
        let mut d = t(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        prune_sign(&mut d, 0.5);
        let zeros = d.iter_flat().filter(|&x| x == 0.0).count();
        assert_eq!(zeros, 4);
    }

    #[test]
    fn disagreeing_signs_pruned_first() {
        // Positive-dominated leaf: the negative entry must be zeroed even
        // though its magnitude is largest among the pruned count.
        let mut d = t(&[5.0, 4.0, 3.0, -2.0]);
        prune_sign(&mut d, 0.25); // prune 1 of 4
        let got: Vec<f32> = d.iter_flat().collect();
        assert_eq!(got, vec![5.0, 4.0, 3.0, 0.0]);
    }

    #[test]
    fn survivors_are_largest_magnitude_agreeing() {
        let mut d = t(&[0.1, 0.9, 0.5, 0.7, 0.3, 0.2, 0.8, 0.4]);
        prune_sign(&mut d, 0.75); // keep 2
        let survivors: Vec<f32> =
            d.iter_flat().filter(|&x| x != 0.0).collect();
        assert_eq!(survivors, vec![0.9, 0.8]);
    }

    #[test]
    fn frac_one_zeroes_everything() {
        // frac == 1.0 takes the k == n path (the k < n selection guard is
        // skipped): every entry is zeroed, and the return value counts
        // only the previously-non-zero entries.
        let mut d = t(&[1.0, -2.0, 0.0, 4.0, 0.0]);
        assert_eq!(prune_sign(&mut d, 1.0), 3);
        assert!(d.iter_flat().all(|x| x == 0.0));
        // The resulting sparse payload is bitmap-only.
        assert_eq!(pruned_payload_bytes(5, 5), 1);
    }

    #[test]
    fn nan_entries_prune_first_and_deterministically() {
        // Regression: the comparator used partial_cmp(..).unwrap_or(Equal),
        // so a NaN magnitude compared Equal to everything — an inconsistent
        // (non-total) order with arbitrary selection. Under total_cmp a NaN
        // ranks as sign-disagreeing (NaN.signum() matches no elected sign)
        // with the largest magnitude key, so selection is deterministic.
        let mut d = t(&[1.0, f32::NAN, 3.0, 0.5]);
        // vote = NaN → NaN >= 0 is false → elected sign is negative, so
        // every finite positive AND the NaN count as disagreeing; within
        // that class |0.5| < |1.0| < |3.0| < |NaN| under total_cmp.
        prune_sign(&mut d, 0.5); // k = 2 → zero 0.5 and 1.0
        let got: Vec<f32> = d.iter_flat().collect();
        assert_eq!(got[0], 0.0);
        assert!(got[1].is_nan());
        assert_eq!(got[2], 3.0);
        assert_eq!(got[3], 0.0);
        // Determinism: a second identical payload prunes identically.
        let mut d2 = t(&[1.0, f32::NAN, 3.0, 0.5]);
        prune_sign(&mut d2, 0.5);
        let got2: Vec<f32> = d2.iter_flat().collect();
        assert_eq!(got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                   got2.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
    }

    #[test]
    fn payload_accounting() {
        // 100 elements, 60 zeroed → 40 f32 + 13 bitmap bytes.
        assert_eq!(pruned_payload_bytes(100, 60), 40 * 4 + 13);
        // No pruning → full payload + bitmap.
        assert_eq!(pruned_payload_bytes(8, 0), 33);
    }

    #[test]
    fn prop_prune_never_increases_norm() {
        check("pruning never increases the L2 norm", 50, |g| {
            let v = g.f32_vec(1..100, 3.0);
            let mut d = t(&v);
            let before = d.l2_norm();
            prune_sign(&mut d, g.f64_in(0.0..0.9));
            assert!(d.l2_norm() <= before + 1e-6);
        });
    }

    #[test]
    fn prop_unpruned_entries_unchanged() {
        check("surviving entries keep their values", 30, |g| {
            let v = g.f32_vec(2..60, 2.0);
            let orig = t(&v);
            let mut d = orig.clone();
            prune_sign(&mut d, 0.5);
            for (a, b) in d.iter_flat().zip(orig.iter_flat()) {
                assert!(a == 0.0 || a == b);
            }
        });
    }
}
