//! `RoundScratch` — a per-coordinator free-list of reusable `Vec<f32>`
//! buffers, so steady-state rounds allocate nothing on the hot path.
//!
//! Every per-round temporary (extracted fragment payloads, averaged
//! fragments, normalized-weight tables, discount-scaled copies) is
//! leased from the arena and recycled when its round-local lifetime
//! ends. A leased buffer is an **owned** `Vec<f32>`: the leasing site
//! has exclusive access for as long as it holds the value, so there is
//! no aliasing to reason about — the arena is just capacity recycling.
//!
//! **Staleness rule:** [`RoundScratch::lease`] always returns a buffer
//! of length 0 (capacity retained from previous rounds). Writers must
//! grow it themselves (`extend_from_slice`, `resize`, `push`), so a
//! fresh lease can never expose a previous round's values — the
//! scratch-reuse property tests pin bitwise equality against
//! fresh-allocation runs (DESIGN.md §12).

/// Free-list arena of `Vec<f32>` (and, for the robust aggregators'
/// per-coordinate column views, `Vec<f64>`) buffers (see module docs).
#[derive(Default)]
pub struct RoundScratch {
    free: Vec<Vec<f32>>,
    free_f64: Vec<Vec<f64>>,
}

impl RoundScratch {
    pub fn new() -> RoundScratch {
        RoundScratch { free: Vec::new(), free_f64: Vec::new() }
    }

    /// Take a buffer from the free list (or create one on first use).
    /// Always empty; capacity carries over from whatever it held last.
    pub fn lease(&mut self) -> Vec<f32> {
        self.free.pop().unwrap_or_default()
    }

    /// Return a buffer to the free list. Contents are cleared now so a
    /// future lease starts from length 0 no matter who recycled it.
    pub fn recycle(&mut self, mut v: Vec<f32>) {
        v.clear();
        self.free.push(v);
    }

    /// As [`lease`](Self::lease), for the f64 side pool. The robust
    /// aggregators ([`crate::coordinator::aggregate`]) lease their
    /// per-coordinate value/weight columns here once per call and sweep
    /// them across every coordinate, so trimming and medians stay
    /// allocation-free in the steady state.
    pub fn lease_f64(&mut self) -> Vec<f64> {
        self.free_f64.pop().unwrap_or_default()
    }

    /// As [`recycle`](Self::recycle), for the f64 side pool.
    pub fn recycle_f64(&mut self, mut v: Vec<f64>) {
        v.clear();
        self.free_f64.push(v);
    }

    /// Buffers currently parked in the free lists (test/bench hook: a
    /// steady-state round leases and recycles the same buffers, so this
    /// stabilizes after the first round).
    pub fn pooled(&self) -> usize {
        self.free.len() + self.free_f64.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_is_always_empty_and_retains_capacity() {
        let mut s = RoundScratch::new();
        let mut a = s.lease();
        assert!(a.is_empty());
        a.extend_from_slice(&[1.0, 2.0, 3.0]);
        let cap = a.capacity();
        s.recycle(a);
        assert_eq!(s.pooled(), 1);
        let b = s.lease();
        assert!(b.is_empty(), "recycled buffer leaked stale length");
        assert!(b.capacity() >= cap, "capacity was not retained");
        assert_eq!(s.pooled(), 0);
    }

    #[test]
    fn f64_pool_is_independent_and_starts_empty() {
        let mut s = RoundScratch::new();
        let mut a = s.lease_f64();
        assert!(a.is_empty());
        a.extend_from_slice(&[1.0f64, 2.0]);
        let cap = a.capacity();
        s.recycle_f64(a);
        // The two pools never exchange buffers.
        let f32_buf = s.lease();
        assert!(f32_buf.is_empty() && f32_buf.capacity() == 0);
        s.recycle(f32_buf);
        let b = s.lease_f64();
        assert!(b.is_empty(), "recycled f64 buffer leaked stale length");
        assert!(b.capacity() >= cap, "f64 capacity was not retained");
    }

    #[test]
    fn steady_state_reuses_instead_of_growing_the_pool() {
        let mut s = RoundScratch::new();
        for round in 0..5 {
            let mut bufs: Vec<Vec<f32>> = (0..3).map(|_| s.lease()).collect();
            for (i, b) in bufs.iter_mut().enumerate() {
                b.resize(16 * (i + 1) + round, i as f32);
            }
            for b in bufs {
                s.recycle(b);
            }
            assert_eq!(s.pooled(), 3, "pool grew past the working set");
        }
    }

    #[test]
    fn prop_scratch_reuse_never_leaks_stale_values() {
        use crate::comm::fragment::FragmentPlan;
        use crate::coordinator::average;
        use crate::runtime::Tensors;
        use crate::util::prop::check;
        // Two simulated rounds of *different* payload sizes through the
        // extract → average pipeline with a reused arena must match a
        // fresh-allocation pipeline bitwise — the round-2 buffers start
        // dirty with round-1 data of a different length.
        check("scratch-reused rounds == fresh-alloc rounds bitwise", 40, |g| {
            let mut scratch = RoundScratch::new();
            for _round in 0..2 {
                let len = g.usize_in(2..40);
                let k = g.usize_in(1..5);
                let p = g.usize_in(1..6);
                let deltas: Vec<Tensors> = (0..k)
                    .map(|_| {
                        let mut v = g.f32_vec(len..len + 1, 2.0);
                        v.resize(len, 0.0);
                        Tensors::from_raw(vec![v])
                    })
                    .collect();
                let weights: Vec<f64> =
                    (0..k).map(|_| g.f64_in(0.1..5.0)).collect();
                let plan = FragmentPlan::for_tensors(&deltas[0], p);
                for f in 0..plan.n_fragments() {
                    // Reused path: leased payload buffers + leased out/norm.
                    let mut payloads: Vec<Vec<f32>> = Vec::new();
                    for d in &deltas {
                        let mut buf = scratch.lease();
                        plan.extract_into(d, f, &mut buf);
                        payloads.push(buf);
                    }
                    let mut norm = scratch.lease();
                    let mut out = scratch.lease();
                    average::fused_weighted_mean_into(
                        &payloads, &weights, &mut norm, &mut out,
                    );
                    // Fresh path: plain allocations, same arithmetic.
                    let fresh_payloads: Vec<Vec<f32>> =
                        deltas.iter().map(|d| plan.extract(d, f)).collect();
                    let mut fresh_norm = Vec::new();
                    let mut fresh = Vec::new();
                    average::fused_weighted_mean_into(
                        &fresh_payloads, &weights, &mut fresh_norm, &mut fresh,
                    );
                    assert_eq!(out.len(), fresh.len());
                    for (x, y) in out.iter().zip(&fresh) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{x} != {y}");
                    }
                    scratch.recycle(norm);
                    scratch.recycle(out);
                    for b in payloads {
                        scratch.recycle(b);
                    }
                }
            }
        });
    }
}
