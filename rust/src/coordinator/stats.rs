//! Outer-gradient statistics (paper Fig 10/11): pairwise cosine
//! similarity among the k replicas' deltas, plus norm tracking.

use crate::runtime::Tensors;
use crate::util::math;

/// Mean ± stddev of cosine similarity over all worker pairs, and the
/// norm of the averaged delta — one record per round. Under the
/// streaming fabric the deltas cover only the round's synced fragments
/// (zero elsewhere), and the codec fields account for lossy encoding.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundStats {
    pub round: usize,
    pub cos_mean: f64,
    pub cos_std: f64,
    pub avg_delta_norm: f64,
    pub per_worker_norm_mean: f64,
    /// How many fragments completed an outer step this round (1 for the
    /// monolithic default; < P under drops or a staggered schedule).
    pub fragments_synced: usize,
    /// Deterministic L2 norm of the dequantization error introduced by
    /// the outer-gradient codec across every payload received this
    /// round; exactly 0.0 for the f32 codec.
    pub codec_err_l2: f64,
    /// Mean L2 distance of the per-worker model replicas from their
    /// uniform consensus after the round's outer steps — the agreement
    /// metric of decentralized topologies (ring, gossip). Exactly 0.0
    /// for centralized topologies, whose single replica *is* the
    /// consensus, and stays ~0 for the ring (every replica applies the
    /// same full average).
    pub consensus_dist: f64,
    /// Size of the round's active roster (elastic membership: departed
    /// workers neither compute nor bill, so this can change round to
    /// round under a `[churn]` schedule).
    pub active_workers: usize,
    /// Rounds between this contribution's compute and its application
    /// (the async scheduling layer's delay; DESIGN.md §11). 0 on the
    /// synchronous path, `sync.delay_rounds` in the steady state of a
    /// delayed run, and less for the tail batches flushed at run end.
    pub staleness: usize,
    /// Simulated seconds the round's islands spent waiting for its
    /// straggler (Σ over active workers of critical-path − own scaled
    /// compute). 0.0 only when every island finishes simultaneously;
    /// grows with `[speed]` heterogeneity.
    pub idle_s: f64,
    /// Contributions the round's robust aggregator rejected outright
    /// (non-finite payloads, Krum's non-selected rows), summed over the
    /// round's aggregations. Always 0 under the default
    /// `coordinator::aggregate::WeightedMean`, which averages everything
    /// it is handed.
    pub rejected: usize,
    /// Mean (over the round's aggregations) of the weight-mass share
    /// each robust estimator discarded — rejected weight plus the
    /// trimmed/unused share of the surviving weight, normalized by total
    /// weight (see `coordinator::aggregate::AggregateOutcome`). 0.0
    /// under the plain weighted mean.
    pub trimmed_mass: f64,
}

/// Mean L2 distance of `replicas` from `consensus` (their uniform mean).
///
/// ```
/// use diloco::coordinator::stats::consensus_distance;
/// use diloco::runtime::Tensors;
///
/// let a = Tensors::from_raw(vec![vec![1.0, 0.0]]);
/// let b = Tensors::from_raw(vec![vec![-1.0, 0.0]]);
/// let mid = Tensors::from_raw(vec![vec![0.0, 0.0]]);
/// let d = consensus_distance(&[a, b], &mid);
/// assert!((d - 1.0).abs() < 1e-9); // each replica sits 1.0 from the mean
/// ```
pub fn consensus_distance(replicas: &[Tensors], consensus: &Tensors) -> f64 {
    let refs: Vec<&Tensors> = replicas.iter().collect();
    consensus_distance_refs(&refs, consensus)
}

/// As [`consensus_distance`], over borrowed replicas (a roster-selected,
/// possibly non-contiguous subset under elastic membership). Same
/// arithmetic, same fold order.
pub fn consensus_distance_refs(replicas: &[&Tensors], consensus: &Tensors) -> f64 {
    if replicas.is_empty() {
        return 0.0;
    }
    // detlint: allow(float_fold, slice-order fold over `replicas` — the caller fixes the order (roster ids), and per-norm values come from the audited dot kernel)
    let sum: f64 = replicas
        .iter()
        .map(|r| r.delta(consensus).l2_norm())
        .sum();
    sum / replicas.len() as f64
}

/// Pairwise cosine similarities among deltas (k·(k-1)/2 values).
pub fn pairwise_cosines(deltas: &[Tensors]) -> Vec<f64> {
    let k = deltas.len();
    let mut out = Vec::with_capacity(k * (k.saturating_sub(1)) / 2);
    for i in 0..k {
        for j in (i + 1)..k {
            out.push(deltas[i].cosine(&deltas[j]));
        }
    }
    out
}

pub fn round_stats(round: usize, deltas: &[Tensors], avg: &Tensors) -> RoundStats {
    let cosines = pairwise_cosines(deltas);
    let norms: Vec<f64> = deltas.iter().map(|d| d.l2_norm()).collect();
    RoundStats {
        round,
        cos_mean: math::mean(&cosines),
        cos_std: math::stddev(&cosines),
        avg_delta_norm: avg.l2_norm(),
        per_worker_norm_mean: math::mean(&norms),
        // The coordinator overwrites these with the round's streaming /
        // topology / roster outcome; defaults describe a lossless
        // centralized sync where every contributor is active.
        fragments_synced: 1,
        codec_err_l2: 0.0,
        consensus_dist: 0.0,
        active_workers: deltas.len(),
        staleness: 0,
        idle_s: 0.0,
        rejected: 0,
        trimmed_mass: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(vals: &[f32]) -> Tensors {
        Tensors::from_raw(vec![vals.to_vec()])
    }

    #[test]
    fn identical_deltas_have_cos_one() {
        let d = t(&[1.0, 2.0, 3.0]);
        let cs = pairwise_cosines(&[d.clone(), d.clone(), d]);
        assert_eq!(cs.len(), 3);
        assert!(cs.iter().all(|c| (c - 1.0).abs() < 1e-9));
    }

    #[test]
    fn orthogonal_deltas_have_cos_zero() {
        let cs = pairwise_cosines(&[t(&[1.0, 0.0]), t(&[0.0, 1.0])]);
        assert_eq!(cs.len(), 1);
        assert!(cs[0].abs() < 1e-9);
    }

    #[test]
    fn single_worker_has_no_pairs() {
        assert!(pairwise_cosines(&[t(&[1.0])]).is_empty());
        let s = round_stats(0, &[t(&[1.0])], &t(&[1.0]));
        assert_eq!(s.cos_mean, 0.0); // mean of empty = 0 by convention
    }

    #[test]
    fn consensus_distance_basics() {
        let a = t(&[2.0, 0.0]);
        let b = t(&[0.0, 2.0]);
        let mid = crate::coordinator::average::average(&[a.clone(), b.clone()]);
        // mid = (1,1); each replica is √2 away.
        let d = consensus_distance(&[a.clone(), b], &mid);
        assert!((d - 2f64.sqrt()).abs() < 1e-6, "{d}");
        // Identical replicas agree exactly; empty input is 0 by convention.
        assert_eq!(consensus_distance(&[a.clone(), a.clone()], &a), 0.0);
        assert_eq!(consensus_distance(&[], &mid), 0.0);
    }

    #[test]
    fn averaging_orthogonal_deltas_shrinks_norm() {
        // Fig 11 intuition: more-orthogonal deltas ⇒ smaller averaged norm.
        // ‖avg of k orthogonal unit vectors‖ = 1/√k.
        let deltas = vec![t(&[1.0, 0.0]), t(&[0.0, 1.0])];
        let avg = crate::coordinator::average::average(&deltas);
        let s = round_stats(3, &deltas, &avg);
        assert_eq!(s.round, 3);
        assert!((s.avg_delta_norm - (0.5f64).sqrt()).abs() < 1e-6);
        assert!((s.per_worker_norm_mean - 1.0).abs() < 1e-9);
    }
}
