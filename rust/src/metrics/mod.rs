//! Run metrics: loss/PPL curves, phase timers, CSV/JSONL sinks.
//!
//! Every experiment produces a [`RunMetrics`]: the inner-loss trace (one
//! point per inner step, averaged across active workers), the eval-PPL
//! curve (per evaluation point), wall/simulated time per phase, and the
//! communication bill. Benches read these to print the paper's rows;
//! the CLI writes them to `csv`/`jsonl` files.

use crate::util::math;
use std::fmt::Write as _;
use std::time::Instant;

/// One point on the evaluation curve.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    /// Global inner-step index (pretrain steps + rounds×H so far).
    pub step: usize,
    pub mean_nll: f64,
    pub ppl: f64,
}

/// Wall-clock phase accounting (real seconds on this host).
///
/// Single-threaded phases (outer opt, eval) are timed with [`Stopwatch`]
/// on the coordinator thread. The inner phase is different: under the
/// parallel engine every island accumulates its own seconds locally and
/// the engine reduces them deterministically in worker order —
/// `inner_compute_s` is the *sum* across islands (total CPU-seconds of
/// useful work; exceeds elapsed time when islands overlap), while the
/// per-round *max* feeds `RunMetrics::sim_compute_seconds` (islands run
/// concurrently, so simulated wall-clock is the slowest island).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimes {
    pub inner_compute_s: f64,
    pub outer_opt_s: f64,
    pub eval_s: f64,
    pub data_s: f64,
    pub other_s: f64,
}

impl PhaseTimes {
    pub fn total(&self) -> f64 {
        self.inner_compute_s + self.outer_opt_s + self.eval_s + self.data_s + self.other_s
    }

    /// Coordinator overhead fraction = everything except inner compute.
    pub fn overhead_fraction(&self) -> f64 {
        let t = self.total();
        if t == 0.0 {
            0.0
        } else {
            (t - self.inner_compute_s) / t
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub label: String,
    /// Mean inner loss per global step (averaged over active workers).
    pub loss_curve: Vec<f32>,
    pub eval_curve: Vec<EvalPoint>,
    pub phases: PhaseTimes,
    /// Copied from the comm fabric at run end.
    pub comm_bytes: u64,
    /// Worker → coordinator payloads only (outer gradients / DP grads) —
    /// the direction the paper's "communicate 500× less" claim counts.
    pub comm_bytes_up: u64,
    pub comm_messages: u64,
    pub comm_dropped: u64,
    pub sim_comm_seconds: f64,
    /// Simulated compute seconds (steps × per-step cost on the islands).
    /// Under the streaming `overlapped` schedule this also absorbs
    /// transfer time that hid behind compute. With a `[speed]` model the
    /// per-round contribution is the *critical path* — the slowest
    /// island's scaled compute time.
    pub sim_compute_seconds: f64,
    /// Simulated seconds islands spent idle at round barriers waiting
    /// for stragglers (Σ per round of critical-path − each island's
    /// scaled compute) — the cost of speed heterogeneity the async
    /// delayed loop exists to expose (DESIGN.md §11).
    pub sim_idle_seconds: f64,
    /// Upload bytes a monolithic full-precision every-round sync would
    /// have billed for the same run — the denominator of the streaming /
    /// codec savings factor.
    pub comm_bytes_up_baseline: u64,
    /// Total L2 dequantization error introduced by the outer-gradient
    /// codec across the run (0.0 for f32).
    pub codec_err_l2: f64,
}

impl RunMetrics {
    pub fn new(label: &str) -> RunMetrics {
        RunMetrics { label: label.to_string(), ..Default::default() }
    }

    pub fn final_ppl(&self) -> f64 {
        self.eval_curve.last().map(|p| p.ppl).unwrap_or(f64::NAN)
    }

    pub fn final_nll(&self) -> f64 {
        self.eval_curve.last().map(|p| p.mean_nll).unwrap_or(f64::NAN)
    }

    /// Simulated wall-clock: compute + communication barriers.
    pub fn sim_wall_seconds(&self) -> f64 {
        self.sim_compute_seconds + self.sim_comm_seconds
    }

    /// Upload-byte reduction vs a monolithic full-precision every-round
    /// sync (>1 = streaming/codec saved communication); NaN when no
    /// baseline was recorded.
    pub fn up_savings_factor(&self) -> f64 {
        if self.comm_bytes_up_baseline == 0 || self.comm_bytes_up == 0 {
            return f64::NAN;
        }
        self.comm_bytes_up_baseline as f64 / self.comm_bytes_up as f64
    }

    /// Mean of the last `n` inner losses (smoothed terminal loss).
    pub fn tail_loss(&self, n: usize) -> f64 {
        if self.loss_curve.is_empty() {
            return f64::NAN;
        }
        let tail = &self.loss_curve[self.loss_curve.len().saturating_sub(n)..];
        math::mean(&tail.iter().map(|&x| x as f64).collect::<Vec<_>>())
    }

    /// CSV of the eval curve: step,mean_nll,ppl.
    pub fn eval_csv(&self) -> String {
        let mut s = String::from("step,mean_nll,ppl\n");
        for p in &self.eval_curve {
            let _ = writeln!(s, "{},{:.6},{:.4}", p.step, p.mean_nll, p.ppl);
        }
        s
    }

    /// CSV of the loss curve: step,loss.
    pub fn loss_csv(&self) -> String {
        let mut s = String::from("step,loss\n");
        for (i, l) in self.loss_curve.iter().enumerate() {
            let _ = writeln!(s, "{i},{l:.6}");
        }
        s
    }

    /// One-line JSON summary (run ledger entry).
    pub fn summary_json(&self) -> String {
        use crate::util::json::Json;
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("final_ppl".into(), Json::Num(self.final_ppl()));
        m.insert("final_nll".into(), Json::Num(self.final_nll()));
        m.insert("steps".into(), Json::Num(self.loss_curve.len() as f64));
        m.insert("comm_bytes".into(), Json::Num(self.comm_bytes as f64));
        m.insert("comm_bytes_up".into(), Json::Num(self.comm_bytes_up as f64));
        m.insert("comm_messages".into(), Json::Num(self.comm_messages as f64));
        m.insert("comm_dropped".into(), Json::Num(self.comm_dropped as f64));
        m.insert("codec_err_l2".into(), Json::Num(self.codec_err_l2));
        m.insert("sim_wall_s".into(), Json::Num(self.sim_wall_seconds()));
        m.insert("sim_idle_s".into(), Json::Num(self.sim_idle_seconds));
        m.insert(
            "overhead_frac".into(),
            Json::Num(self.phases.overhead_fraction()),
        );
        Json::Obj(m).dump()
    }

    pub fn write_curves(&self, dir: &str) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        let base = self.label.replace([' ', '/'], "_");
        std::fs::write(format!("{dir}/{base}.eval.csv"), self.eval_csv())?;
        std::fs::write(format!("{dir}/{base}.loss.csv"), self.loss_csv())?;
        Ok(())
    }
}

/// Scoped wall-clock timer: `let _t = Stopwatch::new(&mut acc);`.
///
/// Borrows the accumulator `&mut`, so it is inherently single-threaded —
/// use it for coordinator-thread phases only. Island threads must not
/// share one accumulator; they time locally and the engine reduces
/// (see [`PhaseTimes`]).
pub struct Stopwatch<'a> {
    start: Instant,
    acc: &'a mut f64,
}

impl<'a> Stopwatch<'a> {
    pub fn new(acc: &'a mut f64) -> Stopwatch<'a> {
        Stopwatch { start: Instant::now(), acc }
    }
}

impl Drop for Stopwatch<'_> {
    fn drop(&mut self) {
        *self.acc += self.start.elapsed().as_secs_f64();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppl_summaries() {
        let mut m = RunMetrics::new("test");
        m.eval_curve.push(EvalPoint { step: 10, mean_nll: 2.0, ppl: 2.0f64.exp() });
        m.eval_curve.push(EvalPoint { step: 20, mean_nll: 1.0, ppl: 1.0f64.exp() });
        assert!((m.final_ppl() - std::f64::consts::E).abs() < 1e-9);
        assert_eq!(m.final_nll(), 1.0);
    }

    #[test]
    fn tail_loss_windows() {
        let mut m = RunMetrics::new("t");
        m.loss_curve = vec![5.0, 4.0, 3.0, 2.0, 1.0];
        assert!((m.tail_loss(2) - 1.5).abs() < 1e-9);
        assert!((m.tail_loss(100) - 3.0).abs() < 1e-9);
        assert!(RunMetrics::new("e").tail_loss(3).is_nan());
    }

    #[test]
    fn csv_shapes() {
        let mut m = RunMetrics::new("t");
        m.loss_curve = vec![1.0, 2.0];
        m.eval_curve.push(EvalPoint { step: 5, mean_nll: 0.5, ppl: 1.65 });
        assert_eq!(m.loss_csv().lines().count(), 3);
        assert_eq!(m.eval_csv().lines().count(), 2);
        assert!(m.eval_csv().starts_with("step,"));
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut acc = 0.0;
        {
            let _t = Stopwatch::new(&mut acc);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(acc >= 0.004, "acc {acc}");
    }

    #[test]
    fn overhead_fraction() {
        let p = PhaseTimes {
            inner_compute_s: 9.0,
            outer_opt_s: 0.5,
            eval_s: 0.25,
            data_s: 0.25,
            other_s: 0.0,
        };
        assert!((p.overhead_fraction() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn summary_json_parses() {
        let m = RunMetrics::new("x");
        let parsed = crate::util::json::Json::parse(&m.summary_json()).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str().unwrap(), "x");
    }
}
