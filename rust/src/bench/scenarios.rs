//! Shared bench workloads — the DESIGN.md §6 scale map in code.
//!
//! Every paper-reproduction bench starts from [`base_config`]: the `nano`
//! model, k=8 workers, H=20 inner steps, T=8 rounds, 60 pretrain steps,
//! non-i.i.d. topic shards — the scaled analogue of the paper's
//! 150M/k=8/H=500/T=128/24k-pretrain main setting. `SCALE=paper` swaps in
//! paper-parity numbers (documented as requiring a bigger machine).
//!
//! The scaled↔paper correspondences used throughout:
//!   H: 20 ↔ 500 (so the Fig-4 sweep {2,4,10,20,40,80} ↔ {50..2000})
//!   pretrain: 60 ↔ 24k (≈27% of the step budget)
//!   T×H after pretrain: 160 ↔ 64k

use super::Scale;
use crate::comm::codec::Codec;
use crate::config::{
    AdversaryConfig, AggregateConfig, ChurnConfig, ComputeSchedule, EngineConfig,
    ExperimentConfig, OuterOptConfig, SpeedConfig, StreamConfig, SyncConfig,
    SyncSchedule, TopologyConfig,
};
use crate::runtime::Runtime;
use std::sync::Arc;

pub fn artifacts_dir() -> String {
    std::env::var("ARTIFACTS_DIR")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string())
}

/// Load the runtime for a preset, or explain how to build artifacts.
pub fn load_runtime(model: &str) -> Arc<Runtime> {
    let dir = artifacts_dir();
    match Runtime::load(&dir, model) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!(
                "cannot load {model} artifacts from {dir}: {e}\n\
                 run `make artifacts` (or ARTIFACTS_DIR=...) first"
            );
            std::process::exit(1);
        }
    }
}

/// Engine override for benches: `ENGINE=sequential|parallel[:N]` swaps
/// the inner-phase executor without editing bench sources (default:
/// auto — parallel islands whenever k ≥ 2).
pub fn engine_from_env() -> EngineConfig {
    match EngineConfig::from_env_var(std::env::var("ENGINE").ok().as_deref()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("bad ENGINE env: {e}");
            std::process::exit(1);
        }
    }
}

/// The scaled main setting (paper: 150M, k=8, H=500, T=128, 24k pretrain).
pub fn base_config(scale: Scale) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_default(&artifacts_dir(), "nano");
    cfg.engine = engine_from_env();
    match scale {
        Scale::Scaled => {
            cfg.workers = 8;
            cfg.schedule = ComputeSchedule::Constant(8);
            cfg.inner_steps = 20;
            cfg.rounds = 8;
            cfg.pretrain_steps = 60;
            cfg.outer_opt = OuterOptConfig::Nesterov { lr: 0.7, mu: 0.9 };
            cfg.data.n_topics = 8;
            cfg.data.n_docs = 320;
            cfg.data.doc_len = 160;
            cfg.data.non_iid = true;
            cfg.eval_every_rounds = 2;
            cfg.eval_batches = 3;
        }
        Scale::Paper => {
            cfg.model = "150m".to_string();
            cfg.workers = 8;
            cfg.schedule = ComputeSchedule::Constant(8);
            cfg.inner_steps = 500;
            cfg.rounds = 128;
            cfg.pretrain_steps = 24_000;
            cfg.data.n_topics = 8;
            cfg.data.n_docs = 20_000;
            cfg.data.doc_len = 800;
            cfg.eval_every_rounds = 8;
            cfg.eval_batches = 8;
        }
    }
    // CI smoke mode: keep the variant grids and every deterministic
    // billing assert (they are invariant in T, H, and k — see the grid
    // tests below), shrink only the per-variant step budget. Numbers
    // from a smoke run are not paper-comparable.
    if crate::bench::smoke() {
        apply_smoke_budget(&mut cfg);
    }
    cfg
}

/// The `BENCH_SMOKE=1` workload shrink applied by [`base_config`]:
/// worker count, rounds, and H-dependent grids stay untouched (the
/// hard-asserted billing formulas depend on them), only the per-variant
/// step budget and data size drop. Public so the scenario tests
/// validate the exact shrunken configs the CI bench-smoke job runs.
pub fn apply_smoke_budget(cfg: &mut ExperimentConfig) {
    cfg.pretrain_steps = 8;
    cfg.inner_steps = 5;
    cfg.eval_batches = 1;
    cfg.data.n_docs = 160;
    cfg.data.doc_len = 100;
}

/// Streaming-sync scenario family: the schedule × codec grid the
/// `stream_sync` bench sweeps. Row 0 is the monolithic full-precision
/// baseline (bitwise-pinned by the golden-trace suite); the rest
/// exercise partial sync (Streaming DiLoCo), compression (DiLoCoX), and
/// compute-overlapped transfers — the per-round sync-byte reductions
/// land in `BENCH_engine.json`.
pub fn stream_grid() -> Vec<(&'static str, StreamConfig)> {
    let every = SyncSchedule::EveryRound;
    let stag = SyncSchedule::Staggered;
    let over = SyncSchedule::Overlapped;
    vec![
        ("baseline_f32", StreamConfig { fragments: 1, schedule: every, codec: Codec::F32, error_feedback: false }),
        ("every_f16", StreamConfig { fragments: 1, schedule: every, codec: Codec::F16, error_feedback: false }),
        ("every_q8", StreamConfig { fragments: 4, schedule: every, codec: Codec::Q8, error_feedback: false }),
        ("staggered4_f32", StreamConfig { fragments: 4, schedule: stag, codec: Codec::F32, error_feedback: false }),
        ("staggered4_q8", StreamConfig { fragments: 4, schedule: stag, codec: Codec::Q8, error_feedback: false }),
        ("overlapped4_f32", StreamConfig { fragments: 4, schedule: over, codec: Codec::F32, error_feedback: false }),
    ]
}

/// Sync-topology scenario family: the topology × codec grid the
/// `topology` bench sweeps. Row 0 is the star full-precision baseline
/// (the bitwise-pinned default); ring and gossip exercise the
/// decentralized per-replica modes (NoLoCo), hierarchical the two-level
/// DiLoCoX sync — per-round WAN-byte counts per topology follow the
/// DESIGN.md §9 cost table and are hard-asserted by the bench.
pub fn topology_grid() -> Vec<(&'static str, TopologyConfig, Codec)> {
    use TopologyConfig::{Gossip, Hierarchical, Ring, Star};
    vec![
        ("star_f32", Star, Codec::F32),
        ("star_q8", Star, Codec::Q8),
        ("ring_f32", Ring, Codec::F32),
        ("gossip_f32", Gossip, Codec::F32),
        ("gossip_q8", Gossip, Codec::Q8),
        ("hier2_f32", Hierarchical { groups: 2 }, Codec::F32),
        ("hier2_q8", Hierarchical { groups: 2 }, Codec::Q8),
    ]
}

/// Elastic-membership scenario family: the churn schedules the `churn`
/// bench sweeps against the base (k=8, T=8) setting — the paper's Fig-8
/// robustness claim extended from lost messages to lost *machines*.
/// Row 0 is the static roster baseline; the rest exercise permanent
/// departure, leave-then-rejoin (parked state restored), a growing ramp,
/// and late joiners beyond the initial pool ("resources that become
/// available during training"). Every schedule validates against the
/// base rounds/workers, and the bench hard-asserts per-round comm
/// billing: a departed worker bills nothing.
pub fn churn_grid() -> Vec<(&'static str, Option<ChurnConfig>)> {
    let parse = |s: &str| Some(ChurnConfig::parse(s).expect("churn grid DSL"));
    vec![
        ("static", None),
        ("leave2", parse("leave:w6@r3,leave:w7@r5")),
        ("leave_rejoin", parse("leave:w5@r2,join:w5@r5")),
        ("ramp_up", parse("ramp:4..8")),
        ("late_joiners", parse("join:w8@r4,join:w9@r4")),
    ]
}

/// Async-scheduling scenario family: the speed × delay grid the
/// `async_delay` bench sweeps against the base (k=8, T=8) setting —
/// the straggler/staleness axis of DESIGN.md §11. Row 0 is the
/// synchronous homogeneous baseline (the bitwise-pinned legacy loop);
/// the rest exercise a 2× straggler under the synchronous barrier
/// (idle time appears), one- and two-round delayed application
/// (DiLoCoX-style overlap — the bench hard-asserts the barrier
/// reduction), staleness discounting, and seeded per-round jitter.
pub fn async_grid() -> Vec<(&'static str, SpeedConfig, SyncConfig)> {
    let sp = |s: &str| SpeedConfig::parse(s).expect("speed grid DSL");
    let sync = |d: usize, g: f64| SyncConfig { delay_rounds: d, discount: g };
    vec![
        ("sync_uniform", SpeedConfig::default(), sync(0, 1.0)),
        ("sync_straggler2x", sp("w0=2.0"), sync(0, 1.0)),
        ("delay1_uniform", SpeedConfig::default(), sync(1, 1.0)),
        ("delay1_straggler2x", sp("w0=2.0"), sync(1, 1.0)),
        ("delay2_discount", SpeedConfig::default(), sync(2, 0.5)),
        ("delay1_jitter", sp("jitter:0.3"), sync(1, 1.0)),
    ]
}

/// One row of the Byzantine robustness grid: which estimator reduces
/// the outer step, which attack (if any) corrupts the compromised
/// workers' deltas, and the topology/churn/delay axes it composes with.
#[derive(Clone, Debug)]
pub struct ByzScenario {
    pub label: &'static str,
    pub aggregate: AggregateConfig,
    pub adversary: Option<AdversaryConfig>,
    pub topology: TopologyConfig,
    pub churn: Option<ChurnConfig>,
    pub sync: SyncConfig,
}

/// Byzantine scenario family: the aggregator × attack × fraction ×
/// topology grid the `byzantine` bench sweeps against the base
/// (k=8, T=8) setting — ROADMAP item 4. Row 0 is the honest plain-mean
/// baseline; row 1 is the `trimmed:0` honest run the bench hard-asserts
/// bitwise-equal to it (the API-redesign acceptance criterion). The
/// flip rows sweep the compromised fraction f ∈ {1, 2, 3} of 8 under a
/// fixed `trimmed:2` estimator (the PPL-vs-f curve), the remaining rows
/// pit each robust estimator against the attack it is shaped for, and
/// the tail rows compose the adversary with a decentralized topology, a
/// mid-run departure, and one round of delayed application. The one
/// fatal cell — NaN-bomb × plain mean — is deliberately absent: the
/// unfiltered mean propagates the NaN to the global model, where the
/// coordinator's `all_finite` ensure (correctly) kills the run.
pub fn byzantine_grid() -> Vec<ByzScenario> {
    let adv = |s: &str| Some(AdversaryConfig::parse(s).expect("adversary grid DSL"));
    let agg = |s: &str| AggregateConfig::parse(s).expect("aggregate grid DSL");
    let star = |label, a, b| ByzScenario {
        label,
        aggregate: a,
        adversary: b,
        topology: TopologyConfig::Star,
        churn: None,
        sync: SyncConfig::default(),
    };
    let mut grid = vec![
        star("mean_honest", agg("mean"), None),
        star("trimmed0_honest", agg("trimmed:0"), None),
        star("mean_flip_f2", agg("mean"), adv("flip:0.25")),
        star("trimmed2_flip_f1", agg("trimmed:2"), adv("flip:0.125")),
        star("trimmed2_flip_f2", agg("trimmed:2"), adv("flip:0.25")),
        star("trimmed2_flip_f3", agg("trimmed:2"), adv("flip:0.375")),
        star("median_nan_f2", agg("median"), adv("nan:0.25")),
        star("krum2_noise_f2", agg("krum:2"), adv("noise:0.25:10")),
        star("trimmed2_stale_f2", agg("trimmed:2"), adv("stale:0.25")),
    ];
    grid.push(ByzScenario {
        label: "gossip_trimmed2_flip_f2",
        aggregate: agg("trimmed:2"),
        adversary: adv("flip:0.25"),
        topology: TopologyConfig::Gossip,
        churn: None,
        sync: SyncConfig::default(),
    });
    grid.push(ByzScenario {
        label: "churn_trimmed2_flip_f2",
        aggregate: agg("trimmed:2"),
        adversary: adv("flip:0.25"),
        topology: TopologyConfig::Star,
        churn: Some(ChurnConfig::parse("leave:w6@r3").expect("churn grid DSL")),
        sync: SyncConfig::default(),
    });
    grid.push(ByzScenario {
        label: "delay1_median_noise_f2",
        aggregate: agg("median"),
        adversary: adv("noise:0.25:3"),
        topology: TopologyConfig::Star,
        churn: None,
        sync: SyncConfig { delay_rounds: 1, discount: 1.0 },
    });
    grid
}

/// Total inner steps after pretraining (T×H) for the base setting — kept
/// constant across H sweeps so variants are compute-matched.
pub fn step_budget(scale: Scale) -> usize {
    let cfg = base_config(scale);
    cfg.rounds * cfg.inner_steps
}

/// Format a PPL (or any f64) for table cells.
pub fn fmt(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.3}")
    } else {
        "n/a".to_string()
    }
}

/// Relative change in percent vs a reference.
pub fn rel_pct(x: f64, reference: f64) -> String {
    if x.is_finite() && reference.is_finite() && reference != 0.0 {
        format!("{:+.2}%", 100.0 * (x - reference) / reference)
    } else {
        "n/a".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_budget_matches_design_doc() {
        assert_eq!(step_budget(Scale::Scaled), 160);
        let cfg = base_config(Scale::Scaled);
        // pretrain ≈ 27% of total, as in the paper (24k of 88k).
        let frac = cfg.pretrain_steps as f64
            / (cfg.pretrain_steps + step_budget(Scale::Scaled)) as f64;
        assert!((frac - 24_000.0 / 88_000.0).abs() < 0.01, "frac {frac}");
    }

    #[test]
    fn paper_scale_uses_paper_numbers() {
        let cfg = base_config(Scale::Paper);
        assert_eq!(cfg.inner_steps, 500);
        assert_eq!(cfg.rounds, 128);
        assert_eq!(cfg.pretrain_steps, 24_000);
        assert_eq!(cfg.model, "150m");
    }

    #[test]
    fn stream_grid_covers_schedules_and_codecs() {
        let grid = stream_grid();
        assert_eq!(grid[0].1, StreamConfig::default(), "row 0 is the baseline");
        for sched in [
            SyncSchedule::EveryRound,
            SyncSchedule::Staggered,
            SyncSchedule::Overlapped,
        ] {
            assert!(grid.iter().any(|(_, s)| s.schedule == sched), "{sched:?}");
        }
        for codec in [Codec::F32, Codec::F16, Codec::Q8] {
            assert!(grid.iter().any(|(_, s)| s.codec == codec), "{codec:?}");
        }
        for (label, s) in &grid {
            s.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
        }
    }

    #[test]
    fn topology_grid_covers_all_topologies() {
        let grid = topology_grid();
        assert_eq!(
            (grid[0].1, grid[0].2),
            (TopologyConfig::Star, Codec::F32),
            "row 0 is the bitwise-pinned star baseline"
        );
        for name in ["star", "ring", "gossip", "hierarchical"] {
            assert!(grid.iter().any(|(_, t, _)| t.name() == name), "{name}");
        }
        for (label, t, codec) in &grid {
            t.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
            // Every variant must survive full config validation.
            let mut cfg = ExperimentConfig::paper_default("a", "nano");
            cfg.topology = *t;
            cfg.stream.codec = *codec;
            cfg.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
        }
    }

    #[test]
    fn churn_grid_validates_against_base_shape() {
        let grid = churn_grid();
        assert!(grid[0].1.is_none(), "row 0 is the static baseline");
        let base = base_config(Scale::Scaled);
        for (label, churn) in &grid {
            let mut cfg = base.clone();
            cfg.artifacts_dir = "a".into();
            cfg.churn = churn.clone();
            cfg.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
            if let Some(c) = churn {
                // Every schedule really changes the roster at some round.
                let static_roster: Vec<usize> = (0..cfg.workers).collect();
                assert!(
                    (0..cfg.rounds).any(|t| cfg.active_ids(t) != static_roster),
                    "{label}: churn schedule is a no-op"
                );
                c.validate(cfg.rounds, cfg.workers).unwrap();
            }
        }
    }

    #[test]
    fn async_grid_validates_and_covers_both_axes() {
        let grid = async_grid();
        assert_eq!(
            (grid[0].1.clone(), grid[0].2),
            (SpeedConfig::default(), SyncConfig::default()),
            "row 0 is the bitwise-pinned synchronous homogeneous baseline"
        );
        assert!(grid.iter().any(|(_, s, _)| !s.is_uniform()), "a straggler row");
        assert!(grid.iter().any(|(_, s, _)| s.jitter > 0.0), "a jitter row");
        assert!(
            grid.iter().any(|(_, _, y)| y.delay_rounds > 1),
            "a deeper-than-one delay row"
        );
        assert!(
            grid.iter().any(|(_, _, y)| y.discount < 1.0),
            "a discounted row"
        );
        let base = base_config(Scale::Scaled);
        for (label, speed, sync) in &grid {
            let mut cfg = base.clone();
            cfg.artifacts_dir = "a".into();
            cfg.speed = speed.clone();
            cfg.sync = *sync;
            cfg.validate().unwrap_or_else(|e| panic!("{label}: {e}"));
        }
    }

    #[test]
    fn byzantine_grid_validates_and_covers_the_axes() {
        use crate::config::AttackKind;
        let grid = byzantine_grid();
        let b = &grid[0];
        assert!(
            b.adversary.is_none()
                && b.aggregate.is_default()
                && b.topology == TopologyConfig::Star,
            "row 0 is the honest plain-mean star baseline"
        );
        // Every aggregator kind and every attack kind appears somewhere.
        for name in ["mean", "trimmed", "median", "krum"] {
            assert!(grid.iter().any(|r| r.aggregate.name() == name), "{name}");
        }
        for atk in ["flip", "noise", "nan", "stale"] {
            assert!(
                grid.iter()
                    .any(|r| r.adversary.is_some_and(|a| a.attack.name() == atk)),
                "{atk}"
            );
        }
        // Composition rows: a decentralized topology, a departure
        // schedule, and a delayed-application round all meet the
        // adversary somewhere in the grid.
        assert!(grid.iter().any(|r| r.topology.is_decentralized()));
        assert!(grid.iter().any(|r| r.churn.is_some() && r.adversary.is_some()));
        assert!(
            grid.iter()
                .any(|r| r.sync.delay_rounds > 0 && r.adversary.is_some())
        );
        // The PPL-vs-f sweep: at least three distinct compromised
        // fractions under one fixed (estimator, attack) pair.
        let fracs: std::collections::BTreeSet<u64> = grid
            .iter()
            .filter(|r| {
                r.aggregate.name() == "trimmed"
                    && r.adversary.is_some_and(|a| a.attack == AttackKind::FlipSign)
            })
            .map(|r| r.adversary.unwrap().fraction.to_bits())
            .collect();
        assert!(fracs.len() >= 3, "PPL-vs-f sweep needs ≥ 3 fractions");
        // The fatal cell stays out: NaN-bomb × plain mean would poison
        // the global model and trip the coordinator's all_finite ensure.
        assert!(!grid.iter().any(|r| r.aggregate.is_default()
            && r.adversary.is_some_and(|a| a.attack == AttackKind::NanBomb)));
        let base = base_config(Scale::Scaled);
        for r in &grid {
            let mut cfg = base.clone();
            cfg.artifacts_dir = "a".into();
            cfg.aggregate = r.aggregate;
            cfg.adversary = r.adversary;
            cfg.topology = r.topology;
            cfg.churn = r.churn.clone();
            cfg.sync = r.sync;
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", r.label));
            if let Some(a) = &r.adversary {
                // Every adversarial row names at least one attacker but
                // keeps an honest majority of the 8-worker pool.
                let n = a.n_attackers(cfg.pool_size());
                assert!(n >= 1 && 2 * n < cfg.pool_size(), "{}: f = {n}", r.label);
            }
        }
    }

    #[test]
    fn smoke_mode_is_env_gated_and_configs_stay_valid() {
        assert!(!crate::bench::smoke_from_env_var(None));
        assert!(crate::bench::smoke_from_env_var(Some("1")));
        assert!(crate::bench::smoke_from_env_var(Some("true")));
        assert!(!crate::bench::smoke_from_env_var(Some("0")));
        // Whatever smoke does to the budget, the base config must stay
        // valid for every scenario family (the CI bench-smoke job runs
        // them all). Apply the real shrink directly — the env var
        // itself is process-global and tests must not mutate it.
        let mut cfg = base_config(Scale::Scaled);
        cfg.artifacts_dir = "a".into();
        apply_smoke_budget(&mut cfg);
        for (label, churn) in churn_grid() {
            let mut c = cfg.clone();
            c.churn = churn;
            c.validate().unwrap_or_else(|e| panic!("smoke churn {label}: {e}"));
        }
        for (label, _, sync) in async_grid() {
            let mut c = cfg.clone();
            c.sync = sync;
            c.validate().unwrap_or_else(|e| panic!("smoke async {label}: {e}"));
        }
        for r in byzantine_grid() {
            let mut c = cfg.clone();
            c.aggregate = r.aggregate;
            c.adversary = r.adversary;
            c.topology = r.topology;
            c.churn = r.churn.clone();
            c.sync = r.sync;
            c.validate()
                .unwrap_or_else(|e| panic!("smoke byzantine {}: {e}", r.label));
        }
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt(15.0234), "15.023");
        assert_eq!(fmt(f64::NAN), "n/a");
        assert_eq!(rel_pct(110.0, 100.0), "+10.00%");
    }
}
