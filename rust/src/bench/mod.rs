//! Bench harness (criterion substitute — fixed crate universe).
//!
//! Every `rust/benches/*.rs` reproduces one paper table/figure: it builds
//! the experiment variants, runs them through the public API, prints the
//! paper's rows as an aligned table + CSV, and writes `bench_out/<id>.csv`.
//! `BenchCtx` provides shared plumbing: wall timers, table rendering, CSV
//! sink, and the scaled-vs-paper workload knob (`SCALE=paper` env).

pub mod scenarios;

use std::fmt::Write as _;
use std::time::Instant;

/// Scale selector: benches default to the DESIGN.md §6 scaled workload;
/// `SCALE=paper` requests paper-parity parameters (documented as not
/// runnable on the 1-core testbed, but wired for larger machines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Scaled,
    Paper,
}

impl Scale {
    pub fn from_env() -> Scale {
        Scale::from_env_var(std::env::var("SCALE").ok().as_deref())
    }

    /// Pure selector — injectable so tests never mutate process env
    /// (`cargo test` runs tests concurrently; `set_var`/`remove_var`
    /// race across threads).
    pub fn from_env_var(v: Option<&str>) -> Scale {
        match v {
            Some("paper") => Scale::Paper,
            _ => Scale::Scaled,
        }
    }
}

/// CI smoke mode (`BENCH_SMOKE=1`): benches keep their full variant
/// grids and every deterministic hard assert (byte formulas, barrier
/// structure, roster billing — all invariant in T, H, and k), but
/// [`scenarios::base_config`] shrinks the per-variant step budget so the
/// whole suite finishes in CI minutes. Wall-clock and PPL columns from a
/// smoke run are NOT paper-comparable — use the default scaled mode to
/// fill `BENCH_engine.json`.
pub fn smoke() -> bool {
    smoke_from_env_var(std::env::var("BENCH_SMOKE").ok().as_deref())
}

/// Pure selector behind [`smoke`] (injectable for tests).
pub fn smoke_from_env_var(v: Option<&str>) -> bool {
    matches!(v, Some("1") | Some("true"))
}

/// One table of results, printed to stdout and persisted as CSV.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .zip(w)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.columns, &widths));
        let _ = writeln!(
            out,
            "{}",
            widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  ")
        );
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        out
    }

    pub fn csv(&self) -> String {
        let mut out = self.columns.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Shared bench context: timing, output dir, scale.
pub struct BenchCtx {
    pub id: String,
    pub scale: Scale,
    start: Instant,
    out_dir: String,
}

impl BenchCtx {
    pub fn new(id: &str) -> BenchCtx {
        let out_dir =
            std::env::var("BENCH_OUT").unwrap_or_else(|_| "bench_out".to_string());
        println!("[{id}] start (scale={:?})", Scale::from_env());
        BenchCtx {
            id: id.to_string(),
            scale: Scale::from_env(),
            start: Instant::now(),
            out_dir,
        }
    }

    /// Print + persist a finished table.
    pub fn emit(&self, table: &Table) {
        print!("{}", table.render());
        if let Err(e) = std::fs::create_dir_all(&self.out_dir) {
            eprintln!("[{}] cannot create {}: {e}", self.id, self.out_dir);
            return;
        }
        let path = format!("{}/{}.csv", self.out_dir, self.id);
        if let Err(e) = std::fs::write(&path, table.csv()) {
            eprintln!("[{}] cannot write {path}: {e}", self.id);
        } else {
            println!("[{}] wrote {path}", self.id);
        }
    }

    /// Persist an extra CSV artifact (e.g. a curve) next to the table.
    pub fn emit_csv(&self, suffix: &str, content: &str) {
        if std::fs::create_dir_all(&self.out_dir).is_ok() {
            let path = format!("{}/{}.{suffix}.csv", self.out_dir, self.id);
            if std::fs::write(&path, content).is_ok() {
                println!("[{}] wrote {path}", self.id);
            }
        }
    }

    pub fn finish(&self) {
        println!(
            "[{}] done in {:.1}s",
            self.id,
            self.start.elapsed().as_secs_f64()
        );
    }
}

/// Median of a non-empty sample set. Uses [`f64::total_cmp`], so NaN
/// samples (a zero-duration division upstream, a corrupted CSV replay)
/// sort to the end instead of panicking mid-bench.
pub fn median(samples: &mut [f64]) -> f64 {
    assert!(!samples.is_empty(), "median of no samples");
    samples.sort_by(f64::total_cmp);
    samples[samples.len() / 2]
}

/// Median-of-runs micro timing (for the hot-path microbench).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    assert!(reps > 0);
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    median(&mut samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("T", &["name", "ppl"]);
        t.row(vec!["baseline".into(), "16.23".into()]);
        t.row(vec!["diloco".into(), "15.02".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("baseline"));
        assert_eq!(t.csv().lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn median_timing_positive() {
        let d = time_median(5, || std::thread::sleep(std::time::Duration::from_micros(100)));
        assert!(d > 0.0);
    }

    #[test]
    fn median_survives_nan_samples() {
        // Regression: `partial_cmp(..).unwrap()` panicked on NaN; total_cmp
        // must sort NaN to the end and keep the finite median.
        let mut s = vec![3.0, f64::NAN, 1.0, 2.0, f64::NAN];
        let m = median(&mut s);
        assert_eq!(m, 3.0); // [1, 2, 3, NaN, NaN] → index 2
        let mut finite = vec![5.0, 1.0, 3.0];
        assert_eq!(median(&mut finite), 3.0);
        let mut all_nan = vec![f64::NAN, f64::NAN];
        assert!(median(&mut all_nan).is_nan()); // no panic
    }

    #[test]
    fn scale_default_is_scaled() {
        assert_eq!(Scale::from_env_var(None), Scale::Scaled);
        assert_eq!(Scale::from_env_var(Some("paper")), Scale::Paper);
        assert_eq!(Scale::from_env_var(Some("anything-else")), Scale::Scaled);
    }
}
