//! Inner-loop worker: one model replica training on its own shard.
//!
//! A worker owns host-side parameter + AdamW state tensors, a seeded
//! batch iterator over its shard, and a global step counter (drives the
//! baked-in lr schedule). `run_inner_steps(H)` executes H fused AdamW
//! steps through the AOT `train_chunk_*` artifacts, greedily composing
//! the largest available scan lengths (… 25, 5, 1) so dispatch + host
//! round-trip overhead amortizes to ~1/C per step.
//!
//! Per the paper, the AdamW state is *worker-local*: DiLoCo synchronizes
//! parameters only (syncing m/v costs 3× communication for no quality
//! gain — appendix "Inner Optimizer States").
//!
//! A `Worker` owns all of its state (params, m/v, batch stream, timers)
//! and is therefore `Send`: the [`crate::engine::ParallelIslands`]
//! executor moves `&mut Worker`s onto island threads. `compute_seconds`
//! accumulates locally on the worker, never through shared metrics —
//! the engine reduces per-worker times deterministically afterwards.

use crate::data::batch::BatchIter;
use crate::runtime::{Runtime, Tensors, Value, ValueView};

pub struct Worker {
    pub id: usize,
    pub params: Tensors,
    pub opt_m: Tensors,
    pub opt_v: Tensors,
    /// Global inner-step counter (pretraining + all rounds so far).
    pub step: f64,
    pub iter: BatchIter,
    /// Real seconds spent inside PJRT executions (per-island compute).
    pub compute_seconds: f64,
}

impl Worker {
    pub fn new(id: usize, init: Tensors, zeros: Tensors, iter: BatchIter) -> Worker {
        Worker {
            id,
            params: init,
            opt_m: zeros.clone(),
            opt_v: zeros,
            step: 0.0,
            iter,
            compute_seconds: 0.0,
        }
    }

    /// Adopt fresh global parameters (round boundary re-dispatch).
    pub fn set_params(&mut self, params: Tensors) {
        self.params = params;
    }

    /// Run `h` inner steps; appends each step's loss to `losses`.
    pub fn run_inner_steps(
        &mut self,
        rt: &Runtime,
        h: usize,
        losses: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let mut remaining = h;
        let mut sizes = rt.chunk_sizes();
        sizes.sort_unstable_by(|a, b| b.cmp(a)); // largest first
        while remaining > 0 {
            let chunk = sizes
                .iter()
                .copied()
                .find(|&c| c <= remaining)
                .unwrap_or(1);
            if chunk == 1 {
                self.one_step(rt, losses)?;
            } else {
                self.chunk_steps(rt, chunk, losses)?;
            }
            remaining -= chunk;
        }
        Ok(())
    }

    fn one_step(&mut self, rt: &Runtime, losses: &mut Vec<f32>) -> anyhow::Result<()> {
        let batch = self.iter.next_batch();
        let step_scalar = [self.step as f32];
        let mut inputs = Vec::with_capacity(3 * self.params.n_leaves() + 3);
        self.params.append_views(&mut inputs);
        self.opt_m.append_views(&mut inputs);
        self.opt_v.append_views(&mut inputs);
        inputs.push(ValueView::F32(&step_scalar));
        inputs.push(ValueView::I32(&batch.tokens));
        inputs.push(ValueView::I32(&batch.targets));
        let t0 = std::time::Instant::now();
        let out = rt.execute_views("train_step", &inputs)?;
        self.compute_seconds += t0.elapsed().as_secs_f64();
        drop(inputs);
        self.absorb_state(rt, out, 1, losses)
    }

    fn chunk_steps(
        &mut self,
        rt: &Runtime,
        chunk: usize,
        losses: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let cfg = &rt.manifest.config;
        let per = cfg.batch_size * cfg.seq_len;
        let mut tokens = Vec::with_capacity(chunk * per);
        let mut targets = Vec::with_capacity(chunk * per);
        for _ in 0..chunk {
            let b = self.iter.next_batch();
            tokens.extend(b.tokens);
            targets.extend(b.targets);
        }
        let step_scalar = [self.step as f32];
        let mut inputs = Vec::with_capacity(3 * self.params.n_leaves() + 3);
        self.params.append_views(&mut inputs);
        self.opt_m.append_views(&mut inputs);
        self.opt_v.append_views(&mut inputs);
        inputs.push(ValueView::F32(&step_scalar));
        inputs.push(ValueView::I32(&tokens));
        inputs.push(ValueView::I32(&targets));
        let key = format!("train_chunk_{chunk}");
        let t0 = std::time::Instant::now();
        let out = rt.execute_views(&key, &inputs)?;
        self.compute_seconds += t0.elapsed().as_secs_f64();
        drop(inputs);
        self.absorb_state(rt, out, chunk, losses)
    }

    /// Split (params', m', v', loss[es]) back into worker state.
    fn absorb_state(
        &mut self,
        rt: &Runtime,
        mut out: Vec<Value>,
        steps: usize,
        losses: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let n = rt.manifest.params.len();
        anyhow::ensure!(out.len() == 3 * n + 1, "train output arity");
        let loss_v = out.pop().unwrap();
        let loss_slice = loss_v.as_f32()?;
        anyhow::ensure!(loss_slice.len() == steps, "loss arity");
        losses.extend_from_slice(loss_slice);

        let v_vals = out.split_off(2 * n);
        let m_vals = out.split_off(n);
        self.params = Tensors::from_values(&rt.manifest, out)?;
        self.opt_m = Tensors::from_values(&rt.manifest, m_vals)?;
        self.opt_v = Tensors::from_values(&rt.manifest, v_vals)?;
        self.step += steps as f64;

        if let Some(&l) = loss_slice.last() {
            anyhow::ensure!(
                l.is_finite(),
                "worker {}: loss diverged (non-finite) at step {}",
                self.id,
                self.step
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn worker_is_send() {
        // The parallel engine moves workers across threads.
        fn assert_send<T: Send>() {}
        assert_send::<Worker>();
    }

    fn runtime() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("nano.manifest.json")
            .exists()
            .then(|| Runtime::load(dir, "nano").unwrap())
    }

    fn make_worker(rt: &Runtime, seed: u64) -> Worker {
        let cfg = &rt.manifest.config;
        let stream: Vec<i32> =
            (0..8000).map(|i| (i % cfg.vocab_size as i64) as i32).collect();
        Worker::new(
            0,
            rt.init_params().unwrap(),
            Tensors::zeros(&rt.manifest),
            BatchIter::new(stream, cfg.batch_size, cfg.seq_len, Rng::new(seed)),
        )
    }

    #[test]
    fn chunked_equals_stepwise() {
        // 5 steps through train_chunk_5 must equal 5 × train_step exactly
        // (same batches, same order) — the core runtime-composition check.
        let Some(rt) = runtime() else { return };
        let mut w_chunk = make_worker(&rt, 42);
        let mut w_step = make_worker(&rt, 42);
        let mut l_chunk = Vec::new();
        let mut l_step = Vec::new();
        w_chunk.chunk_steps(&rt, 5, &mut l_chunk).unwrap();
        for _ in 0..5 {
            w_step.one_step(&rt, &mut l_step).unwrap();
        }
        assert_eq!(l_chunk.len(), 5);
        for (a, b) in l_chunk.iter().zip(&l_step) {
            assert!((a - b).abs() < 1e-4, "loss mismatch {a} vs {b}");
        }
        for (a, b) in w_chunk.params.leaves().iter().zip(w_step.params.leaves()) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-5, "param mismatch");
            }
        }
        assert_eq!(w_chunk.step, w_step.step);
    }

    #[test]
    fn run_inner_steps_composes_chunks() {
        let Some(rt) = runtime() else { return };
        let mut w = make_worker(&rt, 7);
        let mut losses = Vec::new();
        w.run_inner_steps(&rt, 33, &mut losses).unwrap(); // 25 + 5 + 3×1
        assert_eq!(losses.len(), 33);
        assert_eq!(w.step, 33.0);
        let counts = rt.exec_counts();
        assert_eq!(counts.get("train_chunk_25"), Some(&1));
        assert_eq!(counts.get("train_chunk_5"), Some(&1));
        assert_eq!(counts.get("train_step"), Some(&3));
    }

    #[test]
    fn loss_decreases_on_learnable_stream() {
        let Some(rt) = runtime() else { return };
        let mut w = make_worker(&rt, 1);
        let mut losses = Vec::new();
        w.run_inner_steps(&rt, 50, &mut losses).unwrap();
        let head: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let tail: f32 = losses[45..].iter().sum::<f32>() / 5.0;
        assert!(
            tail < head - 0.5,
            "loss did not drop: head {head}, tail {tail}"
        );
    }
}
