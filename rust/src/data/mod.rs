//! Data substrate: synthetic topic corpus, BPE tokenizer, sharding,
//! batch iterators.
//!
//! Stands in for the paper's C4 pipeline (DESIGN.md §2): the corpus has K
//! latent topics whose word distributions differ, so "shard by topic"
//! reproduces the paper's non-i.i.d. regime (they k-means-clustered C4 by
//! features) while "random split" reproduces i.i.d.

pub mod batch;
pub mod corpus;
pub mod shard;
pub mod tokenizer;

pub use batch::{BatchIter, EvalSet};
pub use corpus::{Corpus, Document};
pub use shard::{shard_corpus, ShardPlan};
pub use tokenizer::Tokenizer;

use crate::config::DataConfig;
use crate::util::rng::Rng;

/// Fully prepared dataset: tokenized shards + held-out eval windows.
pub struct Dataset {
    pub tokenizer: Tokenizer,
    /// Token stream per shard (train).
    pub shards: Vec<Vec<i32>>,
    /// Documents per shard (for weighted averaging, paper §6.1).
    pub shard_doc_counts: Vec<usize>,
    /// Held-out token stream (validation).
    pub holdout: Vec<i32>,
}

impl Dataset {
    /// Build corpus → tokenizer → shards for `k` workers. Fails (with a
    /// proper error, not a panic) when the corpus cannot cover `k`
    /// non-empty shards; [`crate::config::ExperimentConfig::validate`]
    /// rejects such configurations up front.
    pub fn build(
        cfg: &DataConfig,
        k: usize,
        vocab_size: usize,
        seed: u64,
    ) -> anyhow::Result<Dataset> {
        let rng = Rng::new(seed);
        let corpus = Corpus::synthesize(cfg, &mut rng.child(1));
        let tokenizer = Tokenizer::train(&corpus, vocab_size, &mut rng.child(2));

        // Hold out a fraction of documents (round-robin over topics so the
        // validation set covers every topic). One shared function decides
        // the split — config validation counts through the same code, so
        // the two sites cannot drift.
        let (hold_idx, train_idx) =
            shard::holdout_split(corpus.docs.len(), cfg.holdout);

        let plan = shard_corpus(&corpus, &train_idx, k, cfg, &mut rng.child(3))?;
        let shards: Vec<Vec<i32>> = plan
            .doc_assignment
            .iter()
            .map(|docs| tokenize_stream(&corpus, docs, &tokenizer))
            .collect();
        let holdout = tokenize_stream(&corpus, &hold_idx, &tokenizer);
        Ok(Dataset {
            tokenizer,
            shards,
            shard_doc_counts: plan.doc_assignment.iter().map(|d| d.len()).collect(),
            holdout,
        })
    }
}

/// Concatenate the given documents into one token stream with EOS breaks.
fn tokenize_stream(corpus: &Corpus, docs: &[usize], tok: &Tokenizer) -> Vec<i32> {
    let mut out = Vec::new();
    for &d in docs {
        out.extend(tok.encode(&corpus.docs[d].text));
        out.push(Tokenizer::EOS);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> DataConfig {
        DataConfig {
            n_topics: 4,
            n_docs: 40,
            doc_len: 60,
            non_iid: true,
            mix: 0.0,
            holdout: 0.1,
        }
    }

    #[test]
    fn dataset_builds_and_covers_all_shards() {
        let ds = Dataset::build(&small_cfg(), 4, 256, 0).unwrap();
        assert_eq!(ds.shards.len(), 4);
        assert!(ds.shards.iter().all(|s| s.len() > 100));
        assert!(ds.holdout.len() > 50);
        let total: usize = ds.shard_doc_counts.iter().sum();
        assert_eq!(total, 40 - 4); // 10% of 40 held out
        // The shared split function predicts exactly what was built —
        // this is the count ExperimentConfig::validate checks against.
        assert_eq!(total, shard::train_doc_count(40, 0.1));
    }

    #[test]
    fn dataset_is_deterministic() {
        let a = Dataset::build(&small_cfg(), 2, 256, 7).unwrap();
        let b = Dataset::build(&small_cfg(), 2, 256, 7).unwrap();
        assert_eq!(a.shards, b.shards);
        assert_eq!(a.holdout, b.holdout);
    }

    #[test]
    fn tokens_within_vocab() {
        let ds = Dataset::build(&small_cfg(), 2, 256, 1).unwrap();
        for s in ds.shards.iter().chain(std::iter::once(&ds.holdout)) {
            assert!(s.iter().all(|&t| (0..256).contains(&t)));
        }
    }
}
