//! Byte-pair-encoding tokenizer trained on the synthetic corpus.
//!
//! Stands in for the paper's 32k SentencePiece vocab: same interface
//! (text → token ids in `[0, vocab)`), trained with classic BPE merges
//! over whitespace-delimited words until the target vocab size is filled.
//! Special ids: 0 = PAD, 1 = EOS (document separator), 2 = UNK.

use crate::data::corpus::Corpus;
use crate::util::rng::Rng;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// piece string → id.
    vocab: HashMap<String, i32>,
    /// Ordered merge rules (left, right) by priority.
    merges: Vec<(String, String)>,
    pub vocab_size: usize,
}

impl Tokenizer {
    pub const PAD: i32 = 0;
    pub const EOS: i32 = 1;
    pub const UNK: i32 = 2;
    const SPECIALS: usize = 3;

    /// Train BPE on the corpus until `vocab_size` pieces exist.
    pub fn train(corpus: &Corpus, vocab_size: usize, _rng: &mut Rng) -> Tokenizer {
        assert!(vocab_size >= 32, "vocab too small for byte coverage");
        // Word frequency table (the classic BPE training corpus view).
        let mut word_freq: HashMap<Vec<String>, usize> = HashMap::new();
        for doc in &corpus.docs {
            for word in doc.text.split(' ') {
                // Word-final marker so merges respect word boundaries.
                let mut chars: Vec<String> =
                    word.chars().map(|c| c.to_string()).collect();
                if let Some(last) = chars.last_mut() {
                    last.push('_');
                }
                *word_freq.entry(chars).or_insert(0) += 1;
            }
        }

        // Seed vocab: specials + every base character piece.
        let mut vocab: HashMap<String, i32> = HashMap::new();
        let add = |vocab: &mut HashMap<String, i32>, piece: String| {
            let next = vocab.len() as i32 + Self::SPECIALS as i32;
            vocab.entry(piece).or_insert(next);
        };
        // detlint: allow(map_iter, order-safe: collected then sort()+dedup() below imposes a total order)
        let mut base: Vec<String> = word_freq
            .keys()
            .flat_map(|w| w.iter().cloned())
            .collect();
        base.sort();
        base.dedup();
        for piece in base {
            add(&mut vocab, piece);
        }

        // Greedy merges.
        let mut merges = Vec::new();
        while vocab.len() + Self::SPECIALS < vocab_size {
            let mut pair_counts: HashMap<(String, String), usize> = HashMap::new();
            // detlint: allow(map_iter, commutative += into pair_counts; visit order is erased by the total-order (count then lexicographic) max_by tie-break below)
            for (word, freq) in &word_freq {
                for pair in word.windows(2) {
                    *pair_counts
                        .entry((pair[0].clone(), pair[1].clone()))
                        .or_insert(0) += freq;
                }
            }
            // Deterministic tie-break: highest count, then lexicographic.
            let Some(best) = pair_counts.into_iter().max_by(|a, b| {
                a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0))
            }) else {
                break;
            };
            if best.1 < 2 {
                break; // nothing left worth merging
            }
            let (l, r) = best.0;
            let merged = format!("{l}{r}");
            add(&mut vocab, merged.clone());
            merges.push((l.clone(), r.clone()));
            // Apply the merge to the training view.
            let mut next: HashMap<Vec<String>, usize> = HashMap::new();
            // detlint: allow(map_iter, per-word rewrite is independent of visit order; freqs merge by commutative += and the next round re-ties via the max_by total order)
            for (word, freq) in word_freq {
                let mut out = Vec::with_capacity(word.len());
                let mut i = 0;
                while i < word.len() {
                    if i + 1 < word.len() && word[i] == l && word[i + 1] == r {
                        out.push(merged.clone());
                        i += 2;
                    } else {
                        out.push(word[i].clone());
                        i += 1;
                    }
                }
                *next.entry(out).or_insert(0) += freq;
            }
            word_freq = next;
        }

        Tokenizer { vocab, merges, vocab_size }
    }

    /// Encode text to token ids (never out of `[0, vocab_size)`).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut out = Vec::new();
        for word in text.split(' ') {
            if word.is_empty() {
                continue;
            }
            let mut pieces: Vec<String> =
                word.chars().map(|c| c.to_string()).collect();
            if let Some(last) = pieces.last_mut() {
                last.push('_');
            }
            // Replay merges in priority order.
            for (l, r) in &self.merges {
                let mut i = 0;
                while i + 1 < pieces.len() {
                    if &pieces[i] == l && &pieces[i + 1] == r {
                        pieces[i] = format!("{l}{r}");
                        pieces.remove(i + 1);
                    } else {
                        i += 1;
                    }
                }
            }
            for p in pieces {
                out.push(*self.vocab.get(&p).unwrap_or(&Self::UNK));
            }
        }
        out
    }

    /// Decode ids back to text (lossy across UNK).
    pub fn decode(&self, ids: &[i32]) -> String {
        // detlint: allow(map_iter, vocab ids are unique so the reverse map is visit-order independent)
        let rev: HashMap<i32, &String> =
            self.vocab.iter().map(|(k, v)| (*v, k)).collect();
        let mut s = String::new();
        for &id in ids {
            match id {
                Self::PAD => {}
                Self::EOS => s.push('\n'),
                Self::UNK => s.push('?'),
                _ => {
                    if let Some(piece) = rev.get(&id) {
                        if let Some(stripped) = piece.strip_suffix('_') {
                            s.push_str(stripped);
                            s.push(' ');
                        } else {
                            s.push_str(piece);
                        }
                    }
                }
            }
        }
        s.trim_end().to_string()
    }

    /// Number of pieces actually allocated (≤ vocab_size).
    pub fn pieces(&self) -> usize {
        self.vocab.len() + Self::SPECIALS
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataConfig;

    fn corpus() -> Corpus {
        Corpus::synthesize(
            &DataConfig {
                n_topics: 2,
                n_docs: 30,
                doc_len: 80,
                non_iid: false,
                mix: 0.0,
                holdout: 0.1,
            },
            &mut Rng::new(0),
        )
    }

    #[test]
    fn ids_always_in_range() {
        let c = corpus();
        let tok = Tokenizer::train(&c, 128, &mut Rng::new(1));
        for d in &c.docs {
            for id in tok.encode(&d.text) {
                assert!((0..128).contains(&id));
            }
        }
        assert!(tok.pieces() <= 128);
    }

    #[test]
    fn roundtrip_on_trained_text() {
        let c = corpus();
        let tok = Tokenizer::train(&c, 256, &mut Rng::new(1));
        let text = &c.docs[0].text;
        let decoded = tok.decode(&tok.encode(text));
        assert_eq!(&decoded, text);
    }

    #[test]
    fn merges_reduce_sequence_length() {
        let c = corpus();
        let small = Tokenizer::train(&c, 40, &mut Rng::new(1));
        let large = Tokenizer::train(&c, 256, &mut Rng::new(1));
        let text = &c.docs[1].text;
        assert!(
            large.encode(text).len() < small.encode(text).len(),
            "bigger vocab must compress better"
        );
    }

    #[test]
    fn unknown_chars_hit_unk_not_panic() {
        let c = corpus();
        let tok = Tokenizer::train(&c, 64, &mut Rng::new(1));
        let ids = tok.encode("xyzzy qwrt 日本");
        assert!(!ids.is_empty());
        assert!(ids.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn training_is_deterministic() {
        let c = corpus();
        let a = Tokenizer::train(&c, 128, &mut Rng::new(1));
        let b = Tokenizer::train(&c, 128, &mut Rng::new(2));
        assert_eq!(a.encode(&c.docs[3].text), b.encode(&c.docs[3].text));
    }
}
