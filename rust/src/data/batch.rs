//! Batch iterators over tokenized shards.
//!
//! Training batches are random windows of `seq_len + 1` tokens from the
//! shard stream (input = window[..S], target = window[1..]) — the standard
//! LM next-token setup the L2 artifacts expect. Each worker owns an
//! independently seeded iterator so data order is reproducible per
//! (seed, worker, step). Evaluation uses fixed non-overlapping windows.

use crate::util::rng::Rng;

/// One (tokens, targets) pair, row-major `[batch, seq]` i32.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch_size: usize,
    pub seq_len: usize,
}

/// Infinite sampler of training batches from one shard.
pub struct BatchIter {
    stream: Vec<i32>,
    batch_size: usize,
    seq_len: usize,
    rng: Rng,
}

impl BatchIter {
    pub fn new(stream: Vec<i32>, batch_size: usize, seq_len: usize, rng: Rng) -> Self {
        assert!(
            stream.len() > seq_len + 1,
            "shard stream too short: {} tokens for seq_len {}",
            stream.len(),
            seq_len
        );
        BatchIter { stream, batch_size, seq_len, rng }
    }

    /// The sampling RNG's cursor (for training-state checkpoints): a
    /// resumed iterator with this state replays the exact batch stream.
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the sampling cursor captured by [`BatchIter::rng_state`].
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    pub fn next_batch(&mut self) -> Batch {
        let b = self.batch_size;
        let s = self.seq_len;
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for _ in 0..b {
            let start = self.rng.below(self.stream.len() - s - 1);
            tokens.extend_from_slice(&self.stream[start..start + s]);
            targets.extend_from_slice(&self.stream[start + 1..start + s + 1]);
        }
        Batch { tokens, targets, batch_size: b, seq_len: s }
    }
}

/// Fixed validation windows — identical across runs for comparable PPL.
pub struct EvalSet {
    batches: Vec<Batch>,
}

impl EvalSet {
    /// Cut `holdout` into up to `max_batches` non-overlapping batches.
    pub fn new(
        holdout: &[i32],
        batch_size: usize,
        seq_len: usize,
        max_batches: usize,
    ) -> EvalSet {
        let window = seq_len + 1;
        let per_batch = batch_size * window;
        let n = (holdout.len() / per_batch).min(max_batches.max(1));
        assert!(
            n >= 1,
            "holdout too small: {} tokens < one {batch_size}x{window} batch",
            holdout.len()
        );
        let mut batches = Vec::with_capacity(n);
        for bi in 0..n {
            let mut tokens = Vec::with_capacity(batch_size * seq_len);
            let mut targets = Vec::with_capacity(batch_size * seq_len);
            for r in 0..batch_size {
                let start = (bi * batch_size + r) * window;
                tokens.extend_from_slice(&holdout[start..start + seq_len]);
                targets.extend_from_slice(&holdout[start + 1..start + window]);
            }
            batches.push(Batch { tokens, targets, batch_size, seq_len });
        }
        EvalSet { batches }
    }

    pub fn batches(&self) -> &[Batch] {
        &self.batches
    }

    pub fn len(&self) -> usize {
        self.batches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut it = BatchIter::new(stream(1000), 4, 16, Rng::new(0));
        let b = it.next_batch();
        assert_eq!(b.tokens.len(), 4 * 16);
        for row in 0..4 {
            for i in 0..15 {
                assert_eq!(
                    b.tokens[row * 16 + i + 1],
                    b.targets[row * 16 + i],
                    "target must be input shifted by one"
                );
            }
        }
    }

    #[test]
    fn iterator_is_deterministic_per_seed() {
        let mut a = BatchIter::new(stream(500), 2, 8, Rng::new(7));
        let mut b = BatchIter::new(stream(500), 2, 8, Rng::new(7));
        for _ in 0..5 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = BatchIter::new(stream(500), 2, 8, Rng::new(1));
        let mut b = BatchIter::new(stream(500), 2, 8, Rng::new(2));
        assert_ne!(a.next_batch().tokens, b.next_batch().tokens);
    }

    #[test]
    fn rng_state_roundtrip_replays_stream() {
        // The checkpoint/resume contract at the data layer: capturing the
        // cursor mid-stream and restoring it into a fresh iterator must
        // replay the identical batch sequence.
        let mut a = BatchIter::new(stream(500), 2, 8, Rng::new(7));
        for _ in 0..5 {
            a.next_batch();
        }
        let cursor = a.rng_state();
        let mut b = BatchIter::new(stream(500), 2, 8, Rng::new(999));
        b.set_rng_state(cursor);
        for _ in 0..10 {
            assert_eq!(a.next_batch().tokens, b.next_batch().tokens);
        }
    }

    #[test]
    fn eval_windows_do_not_overlap() {
        let es = EvalSet::new(&stream(10_000), 2, 16, 8);
        assert!(es.len() >= 2);
        let mut seen = std::collections::HashSet::new();
        for b in es.batches() {
            for &t in &b.tokens {
                assert!(seen.insert(t), "token {t} reused across eval windows");
            }
        }
    }

    #[test]
    fn eval_respects_max_batches() {
        let es = EvalSet::new(&stream(100_000), 2, 16, 3);
        assert_eq!(es.len(), 3);
    }

    #[test]
    #[should_panic]
    fn eval_panics_when_holdout_too_small() {
        EvalSet::new(&stream(10), 4, 16, 2);
    }

    #[test]
    #[should_panic]
    fn train_panics_when_stream_too_small() {
        BatchIter::new(stream(10), 4, 16, Rng::new(0));
    }
}
