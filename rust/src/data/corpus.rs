//! Synthetic topic-mixture corpus (the C4 substitution, DESIGN.md §2).
//!
//! A shared lexicon of pronounceable synthetic words is generated once;
//! each latent topic gets (a) its own Zipf-weighted permutation of the
//! lexicon — topic-specific word frequencies — and (b) a deterministic
//! first-order Markov transition (hash-derived successor sets), so text
//! has learnable bigram structure a language model can actually fit.
//! Topics differ in both unigram and bigram statistics, which is what
//! makes topic-sharding genuinely non-i.i.d.

use crate::config::DataConfig;
use crate::util::rng::Rng;

/// Number of distinct synthetic words in the shared lexicon.
pub const LEXICON_SIZE: usize = 600;
/// Candidate successors per (topic, word) in the Markov chain.
const SUCCESSORS: usize = 12;
/// Zipf exponent for topic unigram distributions.
const ZIPF_S: f64 = 1.1;
/// Probability of following the Markov chain vs. resampling a unigram.
const CHAIN_PROB: f64 = 0.75;

#[derive(Clone, Debug, PartialEq)]
pub struct Document {
    pub topic: usize,
    pub text: String,
}

pub struct Corpus {
    pub docs: Vec<Document>,
    pub n_topics: usize,
    pub lexicon: Vec<String>,
}

/// Deterministic pronounceable word for lexicon slot `i` ("bako", "rilu"…).
fn make_word(i: usize) -> String {
    const C: &[u8] = b"bcdfghjklmnprstvz";
    const V: &[u8] = b"aeiou";
    let mut w = String::new();
    let mut x = i + 1;
    loop {
        w.push(C[x % C.len()] as char);
        w.push(V[(x / C.len()) % V.len()] as char);
        x /= C.len() * V.len();
        if x == 0 {
            break;
        }
    }
    w
}

/// FNV-1a — deterministic topic/word mixing for successor sets.
fn fnv(parts: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for p in parts {
        for b in p.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

struct TopicModel {
    /// lexicon index by topic-specific rank (rank 0 = most frequent).
    ranked: Vec<usize>,
    /// Zipf weights by rank.
    weights: Vec<f64>,
    topic: usize,
}

impl TopicModel {
    fn new(topic: usize, rng: &mut Rng) -> TopicModel {
        let mut ranked: Vec<usize> = (0..LEXICON_SIZE).collect();
        rng.shuffle(&mut ranked);
        let weights: Vec<f64> = (0..LEXICON_SIZE)
            .map(|r| 1.0 / ((r + 1) as f64).powf(ZIPF_S))
            .collect();
        TopicModel { ranked, weights, topic }
    }

    fn sample_unigram(&self, rng: &mut Rng) -> usize {
        self.ranked[rng.weighted(&self.weights)]
    }

    /// Markov successor: one of SUCCESSORS hash-derived candidates.
    fn sample_successor(&self, word: usize, rng: &mut Rng) -> usize {
        let pick = rng.below(SUCCESSORS);
        (fnv(&[self.topic as u64, word as u64, pick as u64]) % LEXICON_SIZE as u64)
            as usize
    }

    fn generate(&self, len: usize, rng: &mut Rng) -> String {
        let mut words = Vec::with_capacity(len);
        let mut cur = self.sample_unigram(rng);
        words.push(cur);
        for _ in 1..len {
            cur = if rng.coin(CHAIN_PROB) {
                self.sample_successor(cur, rng)
            } else {
                self.sample_unigram(rng)
            };
            words.push(cur);
        }
        words
            .into_iter()
            .map(make_word)
            .collect::<Vec<_>>()
            .join(" ")
    }
}

impl Corpus {
    /// Synthesize `cfg.n_docs` documents across `cfg.n_topics` topics.
    pub fn synthesize(cfg: &DataConfig, rng: &mut Rng) -> Corpus {
        assert!(cfg.n_topics > 0 && cfg.n_docs > 0);
        let topics: Vec<TopicModel> = (0..cfg.n_topics)
            .map(|t| TopicModel::new(t, &mut rng.child(1000 + t as u64)))
            .collect();
        let mut docs = Vec::with_capacity(cfg.n_docs);
        for i in 0..cfg.n_docs {
            let topic = i % cfg.n_topics; // balanced topic coverage
            let mut drng = rng.child(2_000_000 + i as u64);
            // Mild length variation, ±25%.
            let len = (cfg.doc_len as f64 * (0.75 + 0.5 * drng.f64())) as usize;
            docs.push(Document {
                topic,
                text: topics[topic].generate(len.max(4), &mut drng),
            });
        }
        Corpus {
            docs,
            n_topics: cfg.n_topics,
            lexicon: (0..LEXICON_SIZE).map(make_word).collect(),
        }
    }

    pub fn total_words(&self) -> usize {
        self.docs
            .iter()
            .map(|d| d.text.split(' ').count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig {
            n_topics: 4,
            n_docs: 40,
            doc_len: 100,
            non_iid: true,
            mix: 0.0,
            holdout: 0.1,
        }
    }

    #[test]
    fn words_are_distinct_and_pronounceable() {
        let words: Vec<String> = (0..LEXICON_SIZE).map(make_word).collect();
        let mut dedup = words.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), LEXICON_SIZE);
        assert!(words.iter().all(|w| w.len() >= 2 && w.is_ascii()));
    }

    #[test]
    fn corpus_is_deterministic() {
        let a = Corpus::synthesize(&cfg(), &mut Rng::new(3));
        let b = Corpus::synthesize(&cfg(), &mut Rng::new(3));
        assert_eq!(a.docs, b.docs);
    }

    #[test]
    fn topics_are_balanced() {
        let c = Corpus::synthesize(&cfg(), &mut Rng::new(4));
        let mut counts = vec![0usize; 4];
        for d in &c.docs {
            counts[d.topic] += 1;
        }
        assert!(counts.iter().all(|&n| n == 10));
    }

    #[test]
    fn topics_have_distinct_statistics() {
        // Word-frequency vectors of different topics should correlate far
        // less than same-topic halves — the non-i.i.d. premise.
        let c = Corpus::synthesize(
            &DataConfig { n_docs: 60, doc_len: 300, ..cfg() },
            &mut Rng::new(5),
        );
        let freq = |topic: usize| -> Vec<f32> {
            let mut f = vec![0f32; LEXICON_SIZE];
            for d in c.docs.iter().filter(|d| d.topic == topic) {
                for w in d.text.split(' ') {
                    if let Some(i) = c.lexicon.iter().position(|x| x == w) {
                        f[i] += 1.0;
                    }
                }
            }
            f
        };
        let f0 = freq(0);
        let f1 = freq(1);
        let sim = crate::util::math::cosine(&f0, &f1);
        assert!(sim < 0.8, "topics too similar: {sim}");
    }

    #[test]
    fn doc_lengths_vary_but_bounded() {
        let c = Corpus::synthesize(&cfg(), &mut Rng::new(6));
        for d in &c.docs {
            let n = d.text.split(' ').count();
            assert!((50..=150).contains(&n), "len {n}");
        }
    }
}
