//! Document → shard assignment: i.i.d. vs non-i.i.d. regimes (paper Fig 5).
//!
//! Non-i.i.d. assigns by latent topic (topic t → shard t mod k), mirroring
//! the paper's k-means clustering of C4; `mix` re-assigns a fraction of
//! documents uniformly to interpolate between regimes. i.i.d. is a random
//! permutation split. Every shard is guaranteed non-empty.

use crate::config::DataConfig;
use crate::data::corpus::Corpus;
use crate::util::rng::Rng;

/// Strided holdout split over `n_docs` documents: returns
/// `(hold_idx, train_idx)`. `⌈n_docs · holdout⌉` documents are held out,
/// taken every `⌈n_docs / n_hold⌉`-th index so the validation set
/// round-robins over the topic-ordered corpus.
///
/// This is the **single** definition of the split:
/// [`crate::data::Dataset::build`] shards exactly `train_idx`, and
/// [`crate::config::ExperimentConfig::validate`] counts
/// [`train_doc_count`] through the same code path — the validator used to
/// hand-mirror this arithmetic and the two sites could drift.
pub fn holdout_split(n_docs: usize, holdout: f64) -> (Vec<usize>, Vec<usize>) {
    let n_hold = ((n_docs as f64) * holdout).ceil() as usize;
    let mut hold_idx: Vec<usize> = Vec::new();
    let mut train_idx: Vec<usize> = Vec::new();
    for i in 0..n_docs {
        if i % n_docs.div_ceil(n_hold.max(1)) == 0 && hold_idx.len() < n_hold {
            hold_idx.push(i);
        } else {
            train_idx.push(i);
        }
    }
    (hold_idx, train_idx)
}

/// Number of training documents [`holdout_split`] leaves after holdout —
/// what config validation checks against the shard count.
pub fn train_doc_count(n_docs: usize, holdout: f64) -> usize {
    holdout_split(n_docs, holdout).1.len()
}

#[derive(Clone, Debug)]
pub struct ShardPlan {
    /// doc indices per shard.
    pub doc_assignment: Vec<Vec<usize>>,
}

impl ShardPlan {
    pub fn counts(&self) -> Vec<usize> {
        self.doc_assignment.iter().map(|v| v.len()).collect()
    }
}

/// Assign `train_docs` (indices into `corpus.docs`) to `k` shards.
///
/// Degenerate inputs (zero shards, fewer documents than shards) are
/// proper [`anyhow`] errors, not panics — [`crate::config::ExperimentConfig::validate`]
/// checks the same invariants up front so misconfigured runs fail at
/// config time with the same message shape.
pub fn shard_corpus(
    corpus: &Corpus,
    train_docs: &[usize],
    k: usize,
    cfg: &DataConfig,
    rng: &mut Rng,
) -> anyhow::Result<ShardPlan> {
    anyhow::ensure!(k > 0, "need at least one shard");
    anyhow::ensure!(
        train_docs.len() >= k,
        "cannot spread {} documents over {k} shards",
        train_docs.len()
    );
    let mut assignment = vec![Vec::new(); k];
    if cfg.non_iid {
        for &d in train_docs {
            let shard = if cfg.mix > 0.0 && rng.coin(cfg.mix) {
                rng.below(k)
            } else {
                corpus.docs[d].topic % k
            };
            assignment[shard].push(d);
        }
    } else {
        let mut shuffled = train_docs.to_vec();
        rng.shuffle(&mut shuffled);
        for (i, d) in shuffled.into_iter().enumerate() {
            assignment[i % k].push(d);
        }
    }
    // Repair empty shards by stealing from the largest (can happen when
    // k > n_topics in the non-i.i.d. regime).
    for i in 0..k {
        if assignment[i].is_empty() {
            let donor = (0..k)
                .max_by_key(|&j| assignment[j].len())
                .expect("k > 0 ensured above");
            anyhow::ensure!(
                assignment[donor].len() > 1,
                "not enough documents to repair empty shard {i}"
            );
            let doc = assignment[donor].pop().unwrap();
            assignment[i].push(doc);
        }
    }
    Ok(ShardPlan { doc_assignment: assignment })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n_topics: usize, n_docs: usize) -> (Corpus, DataConfig) {
        let cfg = DataConfig {
            n_topics,
            n_docs,
            doc_len: 20,
            non_iid: true,
            mix: 0.0,
            holdout: 0.0,
        };
        let corpus = Corpus::synthesize(&cfg, &mut Rng::new(0));
        (corpus, cfg)
    }

    #[test]
    fn non_iid_shards_are_topic_pure() {
        let (corpus, cfg) = setup(4, 40);
        let docs: Vec<usize> = (0..40).collect();
        let plan = shard_corpus(&corpus, &docs, 4, &cfg, &mut Rng::new(1)).unwrap();
        for (shard, docs) in plan.doc_assignment.iter().enumerate() {
            for &d in docs {
                assert_eq!(corpus.docs[d].topic % 4, shard);
            }
        }
    }

    #[test]
    fn iid_shards_are_balanced_and_cover_all() {
        let (corpus, mut cfg) = setup(4, 40);
        cfg.non_iid = false;
        let docs: Vec<usize> = (0..40).collect();
        let plan = shard_corpus(&corpus, &docs, 8, &cfg, &mut Rng::new(2)).unwrap();
        assert!(plan.counts().iter().all(|&c| c == 5));
        let mut all: Vec<usize> =
            plan.doc_assignment.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, docs);
    }

    #[test]
    fn more_shards_than_topics_still_nonempty() {
        let (corpus, cfg) = setup(4, 64);
        let docs: Vec<usize> = (0..64).collect();
        let plan = shard_corpus(&corpus, &docs, 16, &cfg, &mut Rng::new(3)).unwrap();
        assert_eq!(plan.doc_assignment.len(), 16);
        assert!(plan.counts().iter().all(|&c| c >= 1));
        assert_eq!(plan.counts().iter().sum::<usize>(), 64);
    }

    #[test]
    fn mix_interpolates_regimes() {
        let (corpus, mut cfg) = setup(8, 400);
        cfg.mix = 1.0; // fully mixed = iid-like
        let docs: Vec<usize> = (0..400).collect();
        let plan = shard_corpus(&corpus, &docs, 8, &cfg, &mut Rng::new(4)).unwrap();
        // With full mixing, shard 0 should hold many topics, not one.
        let topics: std::collections::HashSet<usize> = plan.doc_assignment[0]
            .iter()
            .map(|&d| corpus.docs[d].topic)
            .collect();
        assert!(topics.len() >= 4, "only topics {topics:?}");
    }

    #[test]
    fn prop_holdout_split_partitions_and_matches_the_closed_form() {
        use crate::util::prop::check;
        check("holdout_split partitions 0..n and counts agree", 200, |g| {
            let n = g.usize_in(0..500);
            let holdout = g.f64_in(0.0..0.95);
            let (hold, train) = holdout_split(n, holdout);
            // Partition: disjoint, sorted, covering 0..n.
            let mut all: Vec<usize> =
                hold.iter().chain(train.iter()).copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>());
            assert_eq!(hold.len(), ((n as f64) * holdout).ceil() as usize);
            // The count the validator uses is the split actually built.
            assert_eq!(train_doc_count(n, holdout), train.len());
            // ... and equals the closed form validate() used to mirror by
            // hand (kept here as the regression oracle).
            let n_hold = ((n as f64) * holdout).ceil() as usize;
            let mirror = if n == 0 {
                0
            } else {
                let stride = n.div_ceil(n_hold.max(1));
                n - n.div_ceil(stride).min(n_hold)
            };
            assert_eq!(train.len(), mirror);
        });
    }

    #[test]
    fn too_few_docs_is_an_error_not_a_panic() {
        let (corpus, cfg) = setup(2, 4);
        let docs: Vec<usize> = (0..2).collect();
        let err = shard_corpus(&corpus, &docs, 4, &cfg, &mut Rng::new(5))
            .expect_err("2 docs over 4 shards");
        assert!(format!("{err:#}").contains("2 documents over 4 shards"));
    }

    #[test]
    fn zero_shards_is_an_error_not_a_panic() {
        let (corpus, cfg) = setup(2, 8);
        let docs: Vec<usize> = (0..8).collect();
        let err = shard_corpus(&corpus, &docs, 0, &cfg, &mut Rng::new(6))
            .expect_err("k = 0");
        assert!(format!("{err:#}").contains("at least one shard"));
    }
}
