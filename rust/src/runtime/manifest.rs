//! AOT manifest parsing — the contract between `python/compile/aot.py`
//! and the Rust runtime.
//!
//! The manifest pins, for every artifact, the exact positional buffer
//! layout of the lowered HLO (name/role/shape/dtype per input and output),
//! plus the model configuration that was baked in at lowering time. The
//! coordinator binds buffers **by role**, so nothing on the Rust side
//! hard-codes the parameter tree.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => anyhow::bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Buffer roles the coordinator understands.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    Param,
    OptM,
    OptV,
    Grad,
    Step,
    BatchTokens,
    BatchTargets,
    Loss,
    SumNll,
    TokenCount,
    OuterDelta,
    OuterMom,
    OuterLr,
    OuterMu,
    Logits,
}

impl Role {
    fn parse(s: &str) -> anyhow::Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "grad" => Role::Grad,
            "step" => Role::Step,
            "batch_tokens" => Role::BatchTokens,
            "batch_targets" => Role::BatchTargets,
            "loss" => Role::Loss,
            "sum_nll" => Role::SumNll,
            "token_count" => Role::TokenCount,
            "outer_delta" => Role::OuterDelta,
            "outer_mom" => Role::OuterMom,
            "outer_lr" => Role::OuterLr,
            "outer_mu" => Role::OuterMu,
            "logits" => Role::Logits,
            other => anyhow::bail!("unknown role {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub role: Role,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }
}

#[derive(Clone, Debug)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl LeafSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub key: String,
    pub file: String,
    pub sha256: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    /// Indices of inputs with the given role, in manifest order.
    pub fn input_indices(&self, role: Role) -> Vec<usize> {
        self.inputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.role == role)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn output_indices(&self, role: Role) -> Vec<usize> {
        self.outputs
            .iter()
            .enumerate()
            .filter(|(_, s)| s.role == role)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Model/training config echoed by the AOT step (configs.py values).
#[derive(Clone, Debug)]
pub struct ManifestConfig {
    pub name: String,
    pub kernels: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub vocab_size: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub param_count: usize,
    pub peak_lr: f64,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub weight_decay: f64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ManifestConfig,
    pub params: Vec<LeafSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let root = Json::parse(text)?;
        let cfg = root.expect("config")?;
        let config = ManifestConfig {
            name: cfg.expect("name")?.as_str()?.to_string(),
            kernels: cfg.expect("kernels")?.as_str()?.to_string(),
            n_layers: cfg.expect("n_layers")?.as_usize()?,
            d_model: cfg.expect("d_model")?.as_usize()?,
            n_heads: cfg.expect("n_heads")?.as_usize()?,
            d_head: cfg.expect("d_head")?.as_usize()?,
            vocab_size: cfg.expect("vocab_size")?.as_usize()?,
            seq_len: cfg.expect("seq_len")?.as_usize()?,
            batch_size: cfg.expect("batch_size")?.as_usize()?,
            param_count: cfg.expect("param_count")?.as_usize()?,
            peak_lr: cfg.expect("peak_lr")?.as_f64()?,
            warmup_steps: cfg.expect("warmup_steps")?.as_usize()?,
            total_steps: cfg.expect("total_steps")?.as_usize()?,
            weight_decay: cfg.expect("weight_decay")?.as_f64()?,
        };

        let params = root
            .expect("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(LeafSpec {
                    name: p.expect("name")?.as_str()?.to_string(),
                    shape: p
                        .expect("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<anyhow::Result<_>>()?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;

        let mut artifacts = BTreeMap::new();
        for (key, art) in root.expect("artifacts")?.as_obj()? {
            let parse_io = |list: &Json| -> anyhow::Result<Vec<IoSpec>> {
                list.as_arr()?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io.expect("name")?.as_str()?.to_string(),
                            role: Role::parse(io.expect("role")?.as_str()?)?,
                            shape: io
                                .expect("shape")?
                                .as_arr()?
                                .iter()
                                .map(|d| d.as_usize())
                                .collect::<anyhow::Result<_>>()?,
                            dtype: Dtype::parse(io.expect("dtype")?.as_str()?)?,
                        })
                    })
                    .collect()
            };
            artifacts.insert(
                key.clone(),
                ArtifactSpec {
                    key: key.clone(),
                    file: art.expect("file")?.as_str()?.to_string(),
                    sha256: art.expect("sha256")?.as_str()?.to_string(),
                    inputs: parse_io(art.expect("inputs")?)?,
                    outputs: parse_io(art.expect("outputs")?)?,
                },
            );
        }

        let man = Manifest { config, params, artifacts };
        man.validate()?;
        Ok(man)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e}"))?;
        Self::parse(&text)
    }

    /// Structural invariants every valid manifest satisfies.
    fn validate(&self) -> anyhow::Result<()> {
        let total: usize = self.params.iter().map(|l| l.elements()).sum();
        if total != self.config.param_count {
            anyhow::bail!(
                "param leaves sum to {total}, manifest says {}",
                self.config.param_count
            );
        }
        for required in ["train_step", "eval_step", "outer_step", "init_params"] {
            if !self.artifacts.contains_key(required) {
                anyhow::bail!("manifest missing required artifact {required:?}");
            }
        }
        let n = self.params.len();
        let train = &self.artifacts["train_step"];
        if train.input_indices(Role::Param).len() != n
            || train.output_indices(Role::Param).len() != n
        {
            anyhow::bail!("train_step param arity mismatch");
        }
        // Param leaf i must have identical name+shape across manifest lists.
        for (leaf, io) in self.params.iter().zip(train.inputs.iter()) {
            if leaf.name != io.name || leaf.shape != io.shape {
                anyhow::bail!(
                    "param order mismatch: {} vs {}",
                    leaf.name,
                    io.name
                );
            }
        }
        Ok(())
    }

    pub fn artifact(&self, key: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("no artifact {key:?} in manifest"))
    }

    /// Total parameter bytes — the per-round communication payload
    /// (one outer gradient) before compression.
    pub fn param_bytes(&self) -> usize {
        self.config.param_count * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano_manifest() -> Option<Manifest> {
        let path = std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/nano.manifest.json"
        ));
        path.exists().then(|| Manifest::load(path).unwrap())
    }

    #[test]
    fn parses_real_nano_manifest() {
        let Some(man) = nano_manifest() else { return };
        assert_eq!(man.config.name, "nano");
        assert_eq!(man.config.param_count, 134_400);
        assert!(man.artifacts.len() >= 5);
        let train = man.artifact("train_step").unwrap();
        let n = man.params.len();
        assert_eq!(train.inputs.len(), 3 * n + 3);
        assert_eq!(train.outputs.len(), 3 * n + 1);
        assert_eq!(train.output_indices(Role::Loss), vec![3 * n]);
    }

    #[test]
    fn role_binding_by_index() {
        let Some(man) = nano_manifest() else { return };
        let train = man.artifact("train_step").unwrap();
        let toks = train.input_indices(Role::BatchTokens);
        assert_eq!(toks.len(), 1);
        let spec = &train.inputs[toks[0]];
        assert_eq!(spec.shape, vec![man.config.batch_size, man.config.seq_len]);
        assert_eq!(spec.dtype, Dtype::I32);
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let text = r#"{
            "config": {"name":"x","kernels":"ref","n_layers":1,"d_model":2,
                "n_heads":1,"d_head":2,"vocab_size":4,"seq_len":2,
                "batch_size":1,"param_count":999,"peak_lr":1e-3,
                "warmup_steps":1,"total_steps":10,"weight_decay":0.1},
            "params": [{"name":"w","shape":[2,2],"dtype":"f32"}],
            "artifacts": {}
        }"#;
        assert!(Manifest::parse(text).is_err());
    }
}
