//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client): `HloModuleProto::
//! from_text_file` → `client.compile` → `execute`. Executables are
//! compiled on first use and cached for the process lifetime. Outputs
//! arrive from PJRT as a single tuple buffer; [`Runtime::execute`] reads
//! it back and decomposes it against the manifest's output specs, so
//! callers deal in `Tensors` (host `f32`/`i32` leaf vectors) only.
//!
//! `Runtime` is `Send + Sync`: the compile cache and execution counters
//! sit behind mutexes, and island threads execute concurrently against
//! shared `Arc<Artifact>`s (the PJRT C API guarantees `Execute` is
//! thread-safe on one loaded executable). This is what lets the
//! [`crate::engine::ParallelIslands`] executor run k workers on real OS
//! threads over a single runtime.
//!
//! Python never runs here — the artifacts are self-contained HLO.

pub mod manifest;
pub mod tensors;

pub use manifest::{ArtifactSpec, Dtype, IoSpec, Manifest, Role};
pub use tensors::Tensors;

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

/// Host-side value fed to / read from an artifact execution.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Borrowed view of an input value — the hot path feeds executions
/// without cloning host tensors (§Perf change 2: the owned-`Value` path
/// cloned params+m+v once per execute on top of the unavoidable
/// host→Literal copy).
#[derive(Clone, Copy, Debug)]
pub enum ValueView<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl<'a> ValueView<'a> {
    fn len(&self) -> usize {
        match self {
            ValueView::F32(v) => v.len(),
            ValueView::I32(v) => v.len(),
        }
    }
}

impl Value {
    pub fn view(&self) -> ValueView<'_> {
        match self {
            Value::F32(v) => ValueView::F32(v),
            Value::I32(v) => ValueView::I32(v),
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Value::F32(v) => Ok(v),
            Value::I32(_) => anyhow::bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Value::I32(v) => Ok(v),
            Value::F32(_) => anyhow::bail!("expected i32 value, got f32"),
        }
    }

    pub fn scalar_f32(&self) -> anyhow::Result<f32> {
        let v = self.as_f32()?;
        anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
        Ok(v[0])
    }
}

/// PJRT client handle asserted thread-safe (see the per-impl SAFETY
/// arguments below — rule D5 requires one on every `unsafe impl`).
struct SharedClient(xla::PjRtClient);
// SAFETY: moving the owner across threads is sound because the wrapped
// `PJRT_Client` is an opaque heap object whose address is stable — the
// `xla` wrapper is `!Send` only because it holds that raw pointer, not
// because the C object is thread-affine. The PJRT C API attaches no
// thread-local state to the client (creation thread included), and this
// crate owns the client uniquely inside `Runtime`, whose own mutable
// state (compile `cache`, `exec_counts`) is entirely behind `Mutex`es.
unsafe impl Send for SharedClient {}
// SAFETY: `&SharedClient` is only ever used to issue PJRT client calls
// (compilation, host↔device buffer transfers), which the PJRT C API
// documents as callable concurrently from any thread — the library does
// its own internal locking. No `&self` path mutates the wrapper itself,
// so shared references never race on Rust-side state either.
unsafe impl Sync for SharedClient {}

/// Loaded-executable handle asserted thread-safe (per-impl SAFETY
/// arguments below).
struct SharedExe(xla::PjRtLoadedExecutable);
// SAFETY: as with `SharedClient`, the wrapper is `!Send` purely through
// its raw pointer; the underlying `PJRT_LoadedExecutable` is an opaque
// heap object with no thread-local ties, so handing the unique owner
// (inside `Arc<Artifact>`) to another thread cannot violate any PJRT
// invariant.
unsafe impl Send for SharedExe {}
// SAFETY: `PJRT_LoadedExecutable_Execute` is specified thread-safe —
// concurrent executions of one loaded executable are the normal
// multi-replica serving path, serialized internally by PJRT where
// needed. Shared `&SharedExe` use in this crate only calls `execute`
// and never mutates the wrapper, so `Sync` adds no Rust-side races.
unsafe impl Sync for SharedExe {}

/// A compiled artifact + its manifest spec.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: SharedExe,
}

/// Loaded artifact set for one model preset, bound to a PJRT CPU client.
pub struct Runtime {
    pub manifest: Manifest,
    dir: PathBuf,
    client: SharedClient,
    cache: Mutex<HashMap<String, Arc<Artifact>>>,
    /// Executions performed, by artifact key (perf accounting).
    exec_counts: Mutex<HashMap<String, u64>>,
}

impl Runtime {
    /// Load `dir/<model>.manifest.json` and create the PJRT CPU client.
    pub fn load(dir: &str, model: &str) -> anyhow::Result<Runtime> {
        let dir = PathBuf::from(dir);
        let manifest = Manifest::load(&dir.join(format!("{model}.manifest.json")))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime {
            manifest,
            dir,
            client: SharedClient(client),
            cache: Mutex::new(HashMap::new()),
            exec_counts: Mutex::new(HashMap::new()),
        })
    }

    /// Compile (or fetch cached) an artifact by manifest key. The cache
    /// lock is held across compilation so concurrent islands touching the
    /// same cold key block on one compile instead of racing N compiles;
    /// compilation happens once per (process, key).
    pub fn artifact(&self, key: &str) -> anyhow::Result<Arc<Artifact>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(a) = cache.get(key) {
            return Ok(a.clone());
        }
        let spec = self.manifest.artifact(key)?.clone();
        let path = self.dir.join(&spec.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow::anyhow!("parsing {path_str}: {e}"))?;
        let exe = self
            .client
            .0
            .compile(&xla::XlaComputation::from_proto(&proto))
            .map_err(|e| anyhow::anyhow!("compiling {key}: {e}"))?;
        let artifact = Arc::new(Artifact { spec, exe: SharedExe(exe) });
        cache.insert(key.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// True if the manifest ships this artifact (e.g. optional chunk sizes).
    pub fn has_artifact(&self, key: &str) -> bool {
        self.manifest.artifacts.contains_key(key)
    }

    /// Largest available `train_chunk_*` size, if any.
    pub fn chunk_sizes(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .manifest
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("train_chunk_"))
            .filter_map(|s| s.parse().ok())
            .collect();
        sizes.sort_unstable();
        sizes
    }

    /// Execute an artifact on host values; returns outputs in manifest
    /// order. Convenience wrapper over [`Runtime::execute_views`].
    pub fn execute(&self, key: &str, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        let views: Vec<ValueView> = inputs.iter().map(Value::view).collect();
        self.execute_views(key, &views)
    }

    /// Execute on borrowed host slices — the hot path. Inputs are
    /// validated against the manifest (arity, element counts, dtypes)
    /// before touching the device.
    pub fn execute_views(
        &self,
        key: &str,
        inputs: &[ValueView<'_>],
    ) -> anyhow::Result<Vec<Value>> {
        let artifact = self.artifact(key)?;
        let spec = &artifact.spec;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "{key}: got {} inputs, manifest wants {}",
            inputs.len(),
            spec.inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (value, io) in inputs.iter().zip(&spec.inputs) {
            literals.push(self.to_literal(value, io)?);
        }
        *self
            .exec_counts
            .lock()
            .unwrap()
            .entry(key.to_string())
            .or_insert(0) += 1;
        let out = artifact
            .exe
            .0
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {key}: {e}"))?;
        anyhow::ensure!(
            out.len() == 1 && out[0].len() == 1,
            "{key}: unexpected replica/buffer layout"
        );
        let mut root = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("readback {key}: {e}"))?;
        let parts = root
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {key}: {e}"))?;
        anyhow::ensure!(
            parts.len() == spec.outputs.len(),
            "{key}: got {} outputs, manifest wants {}",
            parts.len(),
            spec.outputs.len()
        );
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, io)| self.from_literal(lit, io))
            .collect()
    }

    fn to_literal(&self, value: &ValueView<'_>, io: &IoSpec) -> anyhow::Result<xla::Literal> {
        anyhow::ensure!(
            value.len() == io.elements(),
            "{}: got {} elems, want {}",
            io.name,
            value.len(),
            io.elements()
        );
        let dims: Vec<i64> = io.shape.iter().map(|&d| d as i64).collect();
        let lit = match (value, io.dtype) {
            (ValueView::F32(v), Dtype::F32) => xla::Literal::vec1(v),
            (ValueView::I32(v), Dtype::I32) => xla::Literal::vec1(v),
            _ => anyhow::bail!("{}: dtype mismatch", io.name),
        };
        if io.shape.len() == 1 {
            Ok(lit)
        } else {
            // Covers scalars ([]) and rank ≥ 2.
            lit.reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape {:?}: {e}", io.shape))
        }
    }

    fn from_literal(&self, lit: xla::Literal, io: &IoSpec) -> anyhow::Result<Value> {
        match io.dtype {
            Dtype::F32 => {
                let v: Vec<f32> = lit
                    .to_vec()
                    .map_err(|e| anyhow::anyhow!("{}: to_vec f32: {e}", io.name))?;
                anyhow::ensure!(v.len() == io.elements(), "{}: output size", io.name);
                Ok(Value::F32(v))
            }
            Dtype::I32 => {
                let v: Vec<i32> = lit
                    .to_vec()
                    .map_err(|e| anyhow::anyhow!("{}: to_vec i32: {e}", io.name))?;
                anyhow::ensure!(v.len() == io.elements(), "{}: output size", io.name);
                Ok(Value::I32(v))
            }
        }
    }

    /// Per-artifact execution counters (for perf accounting / tests).
    ///
    /// Returned as a `BTreeMap` so probe/metrics reporting that iterates
    /// the counters is iteration-order deterministic; the raw `HashMap`
    /// never escapes the API.
    pub fn exec_counts(&self) -> BTreeMap<String, u64> {
        let counts = self.exec_counts.lock().unwrap();
        counts.iter().map(|(k, &v)| (k.clone(), v)).collect()
    }

    // ---- high-level steps the coordinator uses --------------------------

    /// Run `init_params` → fresh parameter tensors.
    pub fn init_params(&self) -> anyhow::Result<Tensors> {
        let out = self.execute("init_params", &[])?;
        Tensors::from_values(&self.manifest, out)
    }

    /// One eval pass: mean nll over the given batch.
    pub fn eval_batch(
        &self,
        params: &Tensors,
        tokens: &[i32],
        targets: &[i32],
    ) -> anyhow::Result<(f64, f64)> {
        let mut inputs = params.to_views();
        inputs.push(ValueView::I32(tokens));
        inputs.push(ValueView::I32(targets));
        let out = self.execute_views("eval_step", &inputs)?;
        let sum_nll = out[0].scalar_f32()? as f64;
        let count = out[1].scalar_f32()? as f64;
        Ok((sum_nll, count))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_is_send_and_sync() {
        // Compile-time contract the parallel engine depends on: a shared
        // `&Runtime` (and cached `Arc<Artifact>`s) may cross threads.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Runtime>();
        assert_send_sync::<Arc<Artifact>>();
        assert_send_sync::<Tensors>();
    }

    fn runtime() -> Option<Runtime> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        std::path::Path::new(dir)
            .join("nano.manifest.json")
            .exists()
            .then(|| Runtime::load(dir, "nano").unwrap())
    }

    #[test]
    fn init_params_matches_manifest_count() {
        let Some(rt) = runtime() else { return };
        let params = rt.init_params().unwrap();
        assert_eq!(params.total_elements(), rt.manifest.config.param_count);
    }

    #[test]
    fn execute_rejects_wrong_arity() {
        let Some(rt) = runtime() else { return };
        assert!(rt.execute("eval_step", &[]).is_err());
    }

    #[test]
    fn execute_rejects_wrong_size() {
        let Some(rt) = runtime() else { return };
        let params = rt.init_params().unwrap();
        let mut inputs = params.to_values();
        inputs.push(Value::I32(vec![0; 3])); // wrong token count
        inputs.push(Value::I32(vec![0; 3]));
        assert!(rt.execute("eval_step", &inputs).is_err());
    }

    #[test]
    fn eval_loss_near_log_vocab_at_init() {
        let Some(rt) = runtime() else { return };
        let params = rt.init_params().unwrap();
        let cfg = &rt.manifest.config;
        let n = cfg.batch_size * cfg.seq_len;
        let tokens: Vec<i32> = (0..n).map(|i| (i % cfg.vocab_size) as i32).collect();
        let (sum_nll, count) = rt.eval_batch(&params, &tokens, &tokens).unwrap();
        assert_eq!(count as usize, n);
        let mean = sum_nll / count;
        let logv = (cfg.vocab_size as f64).ln();
        assert!((mean - logv).abs() < 1.0, "mean nll {mean} vs log V {logv}");
    }
}
