//! `Tensors` — host-side parameter/optimizer-state storage.
//!
//! One `Vec<f32>` per manifest leaf, in canonical manifest order. All
//! outer-loop algebra (deltas, averaging, outer optimizers, pruning,
//! cosine stats) operates on these through flat-slice views; the runtime
//! converts to/from `Value`s at execution boundaries.

use crate::runtime::manifest::Manifest;
use crate::runtime::Value;
use crate::util::math;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensors {
    leaves: Vec<Vec<f32>>,
}

impl Tensors {
    /// All-zero tensors shaped like the manifest's parameter tree
    /// (used for AdamW m/v state and outer momentum).
    pub fn zeros(manifest: &Manifest) -> Tensors {
        Tensors {
            leaves: manifest
                .params
                .iter()
                .map(|l| vec![0f32; l.elements()])
                .collect(),
        }
    }

    /// Wrap raw leaf vectors without a manifest (tests / synthetic state).
    pub fn from_raw(leaves: Vec<Vec<f32>>) -> Tensors {
        Tensors { leaves }
    }

    /// Wrap leaf vectors (must match manifest arity and sizes).
    pub fn from_leaves(manifest: &Manifest, leaves: Vec<Vec<f32>>) -> anyhow::Result<Tensors> {
        anyhow::ensure!(
            leaves.len() == manifest.params.len(),
            "got {} leaves, manifest wants {}",
            leaves.len(),
            manifest.params.len()
        );
        for (leaf, spec) in leaves.iter().zip(&manifest.params) {
            anyhow::ensure!(
                leaf.len() == spec.elements(),
                "leaf {} has {} elems, want {}",
                spec.name,
                leaf.len(),
                spec.elements()
            );
        }
        Ok(Tensors { leaves })
    }

    /// Consume the first `n_params` f32 values from an execution output.
    pub fn from_values(manifest: &Manifest, values: Vec<Value>) -> anyhow::Result<Tensors> {
        let leaves = values
            .into_iter()
            .take(manifest.params.len())
            .map(|v| match v {
                Value::F32(x) => Ok(x),
                Value::I32(_) => anyhow::bail!("param leaf is i32"),
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Self::from_leaves(manifest, leaves)
    }

    pub fn to_values(&self) -> Vec<Value> {
        self.leaves.iter().map(|l| Value::F32(l.clone())).collect()
    }

    /// Borrowed views for the zero-copy execution path (§Perf change 2).
    pub fn to_views(&self) -> Vec<crate::runtime::ValueView<'_>> {
        self.leaves
            .iter()
            .map(|l| crate::runtime::ValueView::F32(l))
            .collect()
    }

    /// Append views to an existing argument list.
    pub fn append_views<'a>(&'a self, out: &mut Vec<crate::runtime::ValueView<'a>>) {
        out.extend(self.leaves.iter().map(|l| crate::runtime::ValueView::F32(l)));
    }

    pub fn leaves(&self) -> &[Vec<f32>] {
        &self.leaves
    }

    pub fn leaves_mut(&mut self) -> &mut [Vec<f32>] {
        &mut self.leaves
    }

    pub fn n_leaves(&self) -> usize {
        self.leaves.len()
    }

    pub fn total_elements(&self) -> usize {
        self.leaves.iter().map(|l| l.len()).sum()
    }

    /// Bytes when transmitted uncompressed (f32).
    pub fn byte_size(&self) -> usize {
        self.total_elements() * 4
    }

    // ---- algebra ---------------------------------------------------------

    /// self - other, leafwise (the outer gradient Δ = θ_prev - θ_worker).
    pub fn delta(&self, other: &Tensors) -> Tensors {
        assert_eq!(self.leaves.len(), other.leaves.len());
        Tensors {
            leaves: self
                .leaves
                .iter()
                .zip(&other.leaves)
                .map(|(a, b)| math::sub(a, b))
                .collect(),
        }
    }

    /// self += c * other.
    pub fn axpy(&mut self, c: f32, other: &Tensors) {
        assert_eq!(self.leaves.len(), other.leaves.len());
        for (a, b) in self.leaves.iter_mut().zip(&other.leaves) {
            math::axpy(a, c, b);
        }
    }

    pub fn scale(&mut self, c: f32) {
        for l in &mut self.leaves {
            math::scale(l, c);
        }
    }

    pub fn l2_norm(&self) -> f64 {
        self.leaves
            .iter()
            .map(|l| math::dot(l, l))
            .sum::<f64>()
            .sqrt()
    }

    /// Cosine similarity across the full flattened vector.
    pub fn cosine(&self, other: &Tensors) -> f64 {
        let dot: f64 = self
            .leaves
            .iter()
            .zip(&other.leaves)
            .map(|(a, b)| math::dot(a, b))
            .sum();
        let na = self.l2_norm();
        let nb = other.l2_norm();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            dot / (na * nb)
        }
    }

    /// Flat iterator over every element (read-only).
    pub fn iter_flat(&self) -> impl Iterator<Item = f32> + '_ {
        self.leaves.iter().flat_map(|l| l.iter().copied())
    }

    /// Visit every element mutably.
    pub fn for_each_mut(&mut self, mut f: impl FnMut(&mut f32)) {
        for l in &mut self.leaves {
            for x in l {
                f(x);
            }
        }
    }

    pub fn all_finite(&self) -> bool {
        self.iter_flat().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn toy(leaves: Vec<Vec<f32>>) -> Tensors {
        Tensors { leaves }
    }

    #[test]
    fn delta_and_axpy_roundtrip() {
        let a = toy(vec![vec![1.0, 2.0], vec![3.0]]);
        let b = toy(vec![vec![0.5, 1.0], vec![1.0]]);
        let d = a.delta(&b); // a - b
        let mut b2 = b.clone();
        b2.axpy(1.0, &d); // b + (a-b) = a
        assert_eq!(b2, a);
    }

    #[test]
    fn norm_and_cosine() {
        let a = toy(vec![vec![3.0], vec![4.0]]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-9);
        assert!((a.cosine(&a) - 1.0).abs() < 1e-9);
        let zero = toy(vec![vec![0.0], vec![0.0]]);
        assert_eq!(a.cosine(&zero), 0.0);
    }

    #[test]
    fn prop_delta_antisymmetric() {
        check("delta(a,b) = -delta(b,a)", 50, |g| {
            let x = g.f32_vec(1..40, 5.0);
            let y: Vec<f32> = x.iter().map(|v| v + 1.0).collect();
            let a = toy(vec![x.clone()]);
            let b = toy(vec![y]);
            let mut ab = a.delta(&b);
            let ba = b.delta(&a);
            ab.axpy(1.0, &ba);
            assert!(ab.iter_flat().all(|v| v.abs() < 1e-5));
        });
    }

    #[test]
    fn prop_scale_linear_in_norm() {
        check("‖c·x‖ = |c|·‖x‖", 50, |g| {
            let x = g.f32_vec(1..60, 3.0);
            let c = g.f64_in(-4.0..4.0) as f32;
            let t = toy(vec![x]);
            let mut s = t.clone();
            s.scale(c);
            let want = t.l2_norm() * c.abs() as f64;
            assert!((s.l2_norm() - want).abs() < 1e-3 * (1.0 + want));
        });
    }

    #[test]
    fn finite_detection() {
        let mut t = toy(vec![vec![1.0, 2.0]]);
        assert!(t.all_finite());
        t.leaves_mut()[0][1] = f32::NAN;
        assert!(!t.all_finite());
    }
}
