//! Tiny property-based testing harness (substrate — no proptest available).
//!
//! A property is a closure over a [`Gen`]; the runner executes it for
//! `cases` random inputs and, on failure, re-runs with progressively
//! simpler inputs (smaller sizes, values pulled toward zero) to report a
//! minimized counterexample. Deterministic from the ambient seed so CI
//! failures reproduce.
//!
//! ```no_run
//! use diloco::util::prop::{check, Gen};
//! check("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.f32_vec(1..50, 10.0);
//!     let mut b = a.clone();
//!     b.reverse();
//!     let s1: f64 = a.iter().map(|x| *x as f64).sum();
//!     let s2: f64 = b.iter().map(|x| *x as f64).sum();
//!     assert!((s1 - s2).abs() < 1e-6);
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Input generator handed to properties. `shrink_level` (0 = full range)
/// scales sizes and magnitudes down when minimizing a failure.
pub struct Gen {
    rng: Rng,
    shrink_level: u32,
}

impl Gen {
    fn new(seed: u64, shrink_level: u32) -> Self {
        Gen { rng: Rng::new(seed), shrink_level }
    }

    fn shrunk(&self, x: f64) -> f64 {
        x / (1u64 << self.shrink_level.min(40)) as f64
    }

    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(!r.is_empty());
        let span = r.end - r.start;
        let shrunk_span = (self.shrunk(span as f64).ceil() as usize).max(1);
        r.start + self.rng.below(shrunk_span.min(span))
    }

    pub fn f64_in(&mut self, r: Range<f64>) -> f64 {
        let x = r.start + self.rng.f64() * (r.end - r.start);
        if self.shrink_level == 0 {
            x
        } else {
            // Pull toward the midpoint as we shrink.
            let mid = (r.start + r.end) / 2.0;
            mid + self.shrunk(x - mid)
        }
    }

    pub fn f32_vec(&mut self, len: Range<usize>, mag: f64) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| (self.rng.normal() * self.shrunk(mag).max(1e-6)) as f32)
            .collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.coin(0.5)
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` on `cases` random inputs; panic with a minimized
/// counterexample seed on failure.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    let base_seed = 0xD11_0C0_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let failed = catch_unwind(AssertUnwindSafe(|| {
            let mut g = Gen::new(seed, 0);
            prop(&mut g);
        }))
        .is_err();
        if failed {
            // Shrink: re-run the same seed with increasing shrink levels;
            // report the deepest level that still fails.
            let mut minimal = 0;
            for level in 1..=12 {
                let still_fails = catch_unwind(AssertUnwindSafe(|| {
                    let mut g = Gen::new(seed, level);
                    prop(&mut g);
                }))
                .is_err();
                if still_fails {
                    minimal = level;
                }
            }
            // Re-run the minimized case WITHOUT catching, so the original
            // assertion message surfaces.
            eprintln!(
                "property {name:?} failed: case {case}, seed {seed:#x}, \
                 minimized shrink_level {minimal}"
            );
            let mut g = Gen::new(seed, minimal);
            prop(&mut g);
            unreachable!("property failed under catch_unwind but not re-run");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 50, |g| {
            let x = g.f64_in(-100.0..100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics_with_counterexample() {
        check("all vecs shorter than 5", 200, |g| {
            let v = g.f32_vec(0..20, 1.0);
            assert!(v.len() < 5);
        });
    }

    #[test]
    fn generator_ranges_respected() {
        check("usize_in respects range", 100, |g| {
            let x = g.usize_in(3..17);
            assert!((3..17).contains(&x));
        });
    }
}
