//! Flat-vector math used by the coordinator hot path.
//!
//! All outer-loop algebra (averaging, deltas, cosine similarity, norms)
//! operates on `&[f32]` slices over parameter leaves. The mutating
//! kernels (`scale`, `axpy`, `add_assign`, `sub_into`) are written as
//! fixed-width chunks plus a scalar tail so the autovectorizer can lift
//! the body into SIMD without bounds checks; element order and the
//! per-element scalar operations are identical to the one-at-a-time
//! reference loops (`*_scalar` below), so the chunked forms are bitwise
//! drop-in replacements — the property tests pin this for every length,
//! including the odd tails.

/// Chunk width for the vectorizable kernels. Eight f32 lanes = one
/// AVX2 register; the tail (len % LANES elements) runs the same scalar
/// body, so results never depend on LANES.
const LANES: usize = 8;

/// Σ xs — the audited sequential f64 reduction (DESIGN.md §15, D4).
///
/// A plain left-to-right fold: summation order is part of the
/// determinism contract, so every deterministic-zone f64 total (mixing
/// row normalization, weighted RNG choice, consensus distances) routes
/// through this one kernel instead of ad-hoc `Iterator::sum` calls
/// that a refactor could silently reorder or parallelize.
pub fn sum_f64(xs: &[f64]) -> f64 {
    xs.iter().sum()
}

/// Σ (xs[i] as f64) — audited widening sum over f32 slices, same
/// left-to-right order discipline as [`sum_f64`].
pub fn sum_as_f64(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| x as f64).sum()
}

/// dot(a, b) in f64 accumulation (f32 inputs, stable for large vectors).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

pub fn l2_norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Cosine similarity; 0.0 when either vector is all-zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = l2_norm(a);
    let nb = l2_norm(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// Σ (a[i] - b[i])² in f64 accumulation — audited squared L2 distance.
///
/// Krum's pairwise distance matrix routes through this kernel so the
/// fold order stays pinned (DESIGN.md §15, D4) no matter how the
/// caller iterates the worker pairs.
pub fn sq_dist(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = *x as f64 - *y as f64;
            d * d
        })
        .sum()
}

/// out[i] += x[i]
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (ob, xb) in oc.by_ref().zip(xc.by_ref()) {
        for i in 0..LANES {
            ob[i] += xb[i];
        }
    }
    for (o, v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += v;
    }
}

/// out[i] += c * x[i]
pub fn axpy(out: &mut [f32], c: f32, x: &[f32]) {
    assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (ob, xb) in oc.by_ref().zip(xc.by_ref()) {
        for i in 0..LANES {
            ob[i] += c * xb[i];
        }
    }
    for (o, v) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += c * v;
    }
}

/// out[i] *= c
pub fn scale(out: &mut [f32], c: f32) {
    let mut oc = out.chunks_exact_mut(LANES);
    for ob in oc.by_ref() {
        for o in ob.iter_mut() {
            *o *= c;
        }
    }
    for o in oc.into_remainder() {
        *o *= c;
    }
}

/// Element-at-a-time reference for [`axpy`]. The chunked kernel performs
/// the same scalar op per element in the same order; this is the golden
/// baseline the property tests and the hot-path microbench compare
/// against.
pub fn axpy_scalar(out: &mut [f32], c: f32, x: &[f32]) {
    assert_eq!(out.len(), x.len());
    for (o, v) in out.iter_mut().zip(x) {
        *o += c * v;
    }
}

/// Element-at-a-time reference for [`scale`] (see [`axpy_scalar`]).
pub fn scale_scalar(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o *= c;
    }
}

/// a - b elementwise into a fresh vec.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    sub_into(a, b, &mut out);
    out
}

/// a - b elementwise into a reused buffer (cleared first) — the
/// allocation-free form for scratch-arena callers.
pub fn sub_into(a: &[f32], b: &[f32], out: &mut Vec<f32>) {
    assert_eq!(a.len(), b.len());
    out.clear();
    out.reserve(a.len());
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (ab, bb) in ac.by_ref().zip(bc.by_ref()) {
        for i in 0..LANES {
            out.push(ab[i] - bb[i]);
        }
    }
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        out.push(x - y);
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Perplexity from mean negative log-likelihood.
pub fn ppl(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn audited_sums_are_left_to_right() {
        // Bitwise-equal to the sequential fold they replace.
        let xs = [1.0e16, 1.0, -1.0e16, 7.5];
        let mut acc = 0.0f64;
        for x in xs {
            acc += x;
        }
        assert_eq!(sum_f64(&xs), acc);

        let fs = [0.1f32, 0.2, 0.3, -0.15];
        let mut wide = 0.0f64;
        for f in fs {
            wide += f as f64;
        }
        assert_eq!(sum_as_f64(&fs), wide);
        assert_eq!(sum_f64(&[]), 0.0);
        assert_eq!(sum_as_f64(&[]), 0.0);
    }

    #[test]
    fn sq_dist_matches_reference_fold() {
        let a = [1.0f32, -2.0, 3.5, 0.25];
        let b = [0.5f32, 2.0, -1.5, 0.25];
        let mut acc = 0.0f64;
        for (x, y) in a.iter().zip(&b) {
            let d = *x as f64 - *y as f64;
            acc += d * d;
        }
        assert_eq!(sq_dist(&a, &b), acc);
        assert_eq!(sq_dist(&a, &a), 0.0);
        assert_eq!(sq_dist(&[], &[]), 0.0);
        // Symmetric: the per-pair squared term is order-free.
        assert_eq!(sq_dist(&a, &b), sq_dist(&b, &a));
    }

    #[test]
    fn cosine_of_self_is_one() {
        let v = vec![1.0, -2.0, 3.0];
        assert!((cosine(&v, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_orthogonal_is_zero() {
        assert_eq!(cosine(&[1.0, 0.0], &[0.0, 5.0]), 0.0);
    }

    #[test]
    fn cosine_opposite_is_minus_one() {
        let v = vec![1.0f32, 2.0, 3.0];
        let w: Vec<f32> = v.iter().map(|x| -x).collect();
        assert!((cosine(&v, &w) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_zero_vector_is_zero_not_nan() {
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn axpy_and_scale() {
        let mut out = vec![1.0f32, 2.0];
        axpy(&mut out, 2.0, &[3.0, 4.0]);
        assert_eq!(out, vec![7.0, 10.0]);
        scale(&mut out, 0.5);
        assert_eq!(out, vec![3.5, 5.0]);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert!((ppl((16.0f64).ln()) - 16.0).abs() < 1e-9);
    }

    #[test]
    fn prop_chunked_kernels_match_scalar_bitwise() {
        use crate::util::prop::check;
        // The chunked kernels must be indistinguishable from the scalar
        // reference at every length — especially the tails around the
        // LANES boundary (len % 8 ∈ {0..7}).
        check("chunked axpy/scale/add/sub == scalar bitwise", 80, |g| {
            let n = g.usize_in(0..40);
            let mut a = g.f32_vec(n..n + 1, 4.0);
            a.resize(n, 0.0);
            let mut x = g.f32_vec(n..n + 1, 4.0);
            x.resize(n, 0.0);
            let c = g.f64_in(-3.0..3.0) as f32;

            let mut chunked = a.clone();
            let mut scalar = a.clone();
            axpy(&mut chunked, c, &x);
            axpy_scalar(&mut scalar, c, &x);
            for (p, q) in chunked.iter().zip(&scalar) {
                assert_eq!(p.to_bits(), q.to_bits(), "axpy {p} != {q}");
            }

            scale(&mut chunked, c);
            scale_scalar(&mut scalar, c);
            for (p, q) in chunked.iter().zip(&scalar) {
                assert_eq!(p.to_bits(), q.to_bits(), "scale {p} != {q}");
            }

            let mut added = a.clone();
            add_assign(&mut added, &x);
            for ((o, &ai), &xi) in added.iter().zip(&a).zip(&x) {
                assert_eq!(o.to_bits(), (ai + xi).to_bits(), "add_assign");
            }

            // sub_into over a dirty reused buffer == fresh collect.
            let mut buf = vec![f32::NAN; 3];
            sub_into(&a, &x, &mut buf);
            let fresh: Vec<f32> =
                a.iter().zip(&x).map(|(p, q)| p - q).collect();
            assert_eq!(buf.len(), fresh.len());
            for (p, q) in buf.iter().zip(&fresh) {
                assert_eq!(p.to_bits(), q.to_bits(), "sub_into {p} != {q}");
            }
        });
    }

    #[test]
    fn chunked_kernels_cover_exact_multiples_of_lanes() {
        // len == LANES and len == 2·LANES exercise the no-tail path.
        for n in [8usize, 16] {
            let mut a: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
            let x: Vec<f32> = (0..n).map(|i| 1.0 - i as f32 * 0.5).collect();
            let mut r = a.clone();
            axpy(&mut a, 1.5, &x);
            axpy_scalar(&mut r, 1.5, &x);
            assert_eq!(a, r);
        }
    }
}
