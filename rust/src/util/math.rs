//! Flat-vector math used by the coordinator hot path.
//!
//! All outer-loop algebra (averaging, deltas, cosine similarity, norms)
//! operates on `&[f32]` slices over parameter leaves. These are simple
//! loops the compiler auto-vectorizes; the profile in EXPERIMENTS.md §Perf
//! confirms they are not the bottleneck at any tested scale.

/// dot(a, b) in f64 accumulation (f32 inputs, stable for large vectors).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

pub fn l2_norm(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Cosine similarity; 0.0 when either vector is all-zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    let na = l2_norm(a);
    let nb = l2_norm(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

/// out[i] += x[i]
pub fn add_assign(out: &mut [f32], x: &[f32]) {
    assert_eq!(out.len(), x.len());
    for (o, v) in out.iter_mut().zip(x) {
        *o += v;
    }
}

/// out[i] += c * x[i]
pub fn axpy(out: &mut [f32], c: f32, x: &[f32]) {
    assert_eq!(out.len(), x.len());
    for (o, v) in out.iter_mut().zip(x) {
        *o += c * v;
    }
}

/// out[i] *= c
pub fn scale(out: &mut [f32], c: f32) {
    for o in out.iter_mut() {
        *o *= c;
    }
}

/// a - b elementwise into a fresh vec.
pub fn sub(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Perplexity from mean negative log-likelihood.
pub fn ppl(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_of_self_is_one() {
        let v = vec![1.0, -2.0, 3.0];
        assert!((cosine(&v, &v) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_orthogonal_is_zero() {
        assert_eq!(cosine(&[1.0, 0.0], &[0.0, 5.0]), 0.0);
    }

    #[test]
    fn cosine_opposite_is_minus_one() {
        let v = vec![1.0f32, 2.0, 3.0];
        let w: Vec<f32> = v.iter().map(|x| -x).collect();
        assert!((cosine(&v, &w) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_zero_vector_is_zero_not_nan() {
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn axpy_and_scale() {
        let mut out = vec![1.0f32, 2.0];
        axpy(&mut out, 2.0, &[3.0, 4.0]);
        assert_eq!(out, vec![7.0, 10.0]);
        scale(&mut out, 0.5);
        assert_eq!(out, vec![3.5, 5.0]);
    }

    #[test]
    fn stats() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
        assert!((ppl((16.0f64).ln()) - 16.0).abs() < 1e-9);
    }
}
