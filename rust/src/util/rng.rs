//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! Every stochastic decision in the system (corpus synthesis, sharding,
//! drop injection, init seeds) flows through this type, so a run is fully
//! reproducible from one `u64` seed. `child(tag)` derives independent
//! streams (worker i, round t, …) without correlated state.

/// xoshiro256++ with SplitMix64 seed expansion.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Independent child stream, e.g. `rng.child(worker_id as u64)`.
    pub fn child(&self, tag: u64) -> Rng {
        // Mix the current state with the tag through SplitMix64.
        let mut sm = self.s[0] ^ self.s[2] ^ tag.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    /// The full generator state — the stream cursor a training-state
    /// checkpoint records so a resumed run continues the exact sequence.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at an exact cursor captured by [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Bernoulli(p).
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        // The total scales the draw, so it must be the audited
        // order-pinned sum (D4) — bitwise-identical fold.
        let total = crate::util::math::sum_f64(weights);
        assert!(total > 0.0, "weighted(): all-zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of n (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn child_streams_are_independent_of_parent_progress() {
        let parent = Rng::new(7);
        let c1 = parent.child(3);
        let mut parent2 = Rng::new(7);
        let _ = parent2.next_u64(); // child() must not consume parent state
        let c2 = parent.child(3);
        let mut c1 = c1;
        let mut c2 = c2;
        for _ in 0..10 {
            assert_eq!(c1.next_u64(), c2.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_exact_sequence() {
        let mut a = Rng::new(13);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            assert_ne!(r.weighted(&[1.0, 0.0, 2.0]), 1);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Rng::new(9);
        let picked = r.choose(50, 20);
        let mut dedup = picked.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 20);
        assert!(picked.iter().all(|&i| i < 50));
    }
}
