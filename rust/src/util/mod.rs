//! Substrate utilities the fixed crate universe forced us to build:
//! a PRNG ([`rng`]), a JSON reader/writer ([`json`]), vector math
//! ([`math`]) and a property-testing harness ([`prop`]).

pub mod json;
pub mod math;
pub mod prop;
pub mod rng;
