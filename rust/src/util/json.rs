//! Minimal JSON reader + writer (substrate — no serde in the crate universe).
//!
//! The reader supports the full JSON grammar the AOT manifests use
//! (objects, arrays, strings with escapes, numbers, booleans, null); the
//! writer is used by [`crate::metrics`] for JSONL/CSV sinks and run
//! summaries. Not a general-purpose streaming parser — manifests are
//! tens of KB, so a recursive descent over a char buffer is plenty.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

// Hand-rolled (the crate universe is fixed: `thiserror` is not a
// dependency, and a derive on two fields is not worth one).
impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors (error on type mismatch) -------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => anyhow::bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            anyhow::bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> anyhow::Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> anyhow::Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("expected object, got {self:?}"),
        }
    }

    /// Compact serialization (stable key order — Obj is a BTreeMap).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) if !x.is_finite() => out.push_str("null"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Advance one UTF-8 char.
                    let start = self.pos;
                    let rest = &self.b[start..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(v.dump(), r#""a\"b\\c\n""#);
    }

    #[test]
    fn parses_real_manifest() {
        // The actual AOT output, if present (built by `make artifacts`).
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/nano.manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("artifacts").is_some());
            assert!(v.get("params").unwrap().as_arr().unwrap().len() > 10);
        }
    }
}
