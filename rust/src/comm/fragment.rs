//! Parameter-space fragmentation for streaming partial synchronization.
//!
//! Streaming DiLoCo (arXiv:2501.18512) synchronizes *fragments* of the
//! model on a staggered schedule instead of shipping one monolithic
//! outer gradient. A [`FragmentPlan`] partitions the flattened parameter
//! space into `P` contiguous, near-equal element ranges and maps each
//! back onto `(leaf, sub-range)` slices of a [`Tensors`] tree, so every
//! layer (billing, codecs, averaging, outer-optimizer state) can address
//! "fragment f" without knowing the leaf structure.
//!
//! `P = 1` yields a single fragment covering every element — the
//! monolithic path, bitwise identical to the pre-streaming fabric.

use crate::runtime::Tensors;

/// One contiguous run of elements inside one parameter leaf.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeafSlice {
    pub leaf: usize,
    pub start: usize,
    pub end: usize,
}

impl LeafSlice {
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }
}

/// A fixed partition of the flattened parameter space into fragments.
///
/// ```
/// use diloco::comm::fragment::FragmentPlan;
/// use diloco::runtime::Tensors;
///
/// // Two parameter leaves (3 + 5 elements) split into two fragments.
/// let plan = FragmentPlan::new(&[3, 5], 2);
/// assert_eq!(plan.n_fragments(), 2);
/// assert_eq!(plan.elements(0) + plan.elements(1), plan.total_elements());
///
/// // extract → scatter round-trips a fragment bitwise.
/// let t = Tensors::from_raw(vec![vec![1.0, 2.0, 3.0], vec![4.0; 5]]);
/// let payload = plan.extract(&t, 0);
/// let mut out = Tensors::from_raw(vec![vec![0.0; 3], vec![0.0; 5]]);
/// plan.scatter(&payload, 0, &mut out);
/// assert_eq!(out.leaves()[0], vec![1.0, 2.0, 3.0]);
/// ```
#[derive(Clone, Debug)]
pub struct FragmentPlan {
    fragments: Vec<Vec<LeafSlice>>,
    elements: Vec<usize>,
    total_elements: usize,
}

impl FragmentPlan {
    /// Split `leaf_sizes` into (up to) `requested` contiguous fragments.
    /// The count is clamped to `[1, total_elements]` so no fragment is
    /// ever empty; fragment `f` covers flat range
    /// `[f·N/P, (f+1)·N/P)`.
    pub fn new(leaf_sizes: &[usize], requested: usize) -> FragmentPlan {
        let total: usize = leaf_sizes.iter().sum();
        let p = requested.max(1).min(total.max(1));
        let mut fragments = Vec::with_capacity(p);
        let mut elements = Vec::with_capacity(p);
        for f in 0..p {
            let lo = f * total / p;
            let hi = (f + 1) * total / p;
            let mut slices = Vec::new();
            let mut off = 0usize;
            for (leaf, &n) in leaf_sizes.iter().enumerate() {
                let a = lo.max(off);
                let b = hi.min(off + n);
                if a < b {
                    slices.push(LeafSlice { leaf, start: a - off, end: b - off });
                }
                off += n;
            }
            elements.push(hi - lo);
            fragments.push(slices);
        }
        FragmentPlan { fragments, elements, total_elements: total }
    }

    /// Plan over the leaves of an existing tensor tree.
    pub fn for_tensors(t: &Tensors, requested: usize) -> FragmentPlan {
        let sizes: Vec<usize> = t.leaves().iter().map(|l| l.len()).collect();
        FragmentPlan::new(&sizes, requested)
    }

    pub fn n_fragments(&self) -> usize {
        self.fragments.len()
    }

    pub fn total_elements(&self) -> usize {
        self.total_elements
    }

    /// The `(leaf, range)` slices making up fragment `f`.
    pub fn slices(&self, f: usize) -> &[LeafSlice] {
        &self.fragments[f]
    }

    pub fn elements(&self, f: usize) -> usize {
        self.elements[f]
    }

    /// Flatten fragment `f` of `t` into one contiguous payload.
    pub fn extract(&self, t: &Tensors, f: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.extract_into(t, f, &mut out);
        out
    }

    /// As [`Self::extract`], into a reused buffer (cleared first) — the
    /// allocation-free form for scratch-arena callers. Bitwise identical
    /// output: both are straight `extend_from_slice` copies in slice
    /// order.
    pub fn extract_into(&self, t: &Tensors, f: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.elements[f]);
        for s in &self.fragments[f] {
            out.extend_from_slice(&t.leaves()[s.leaf][s.start..s.end]);
        }
    }

    /// Write a flat payload back into fragment `f` of `into`.
    pub fn scatter(&self, values: &[f32], f: usize, into: &mut Tensors) {
        assert_eq!(values.len(), self.elements[f], "payload arity");
        let mut off = 0usize;
        for s in &self.fragments[f] {
            into.leaves_mut()[s.leaf][s.start..s.end]
                .copy_from_slice(&values[off..off + s.len()]);
            off += s.len();
        }
    }

    /// Copy fragment `f` from one tensor tree to another (bitwise).
    pub fn copy_fragment(&self, from: &Tensors, into: &mut Tensors, f: usize) {
        for s in &self.fragments[f] {
            let src = &from.leaves()[s.leaf][s.start..s.end];
            into.leaves_mut()[s.leaf][s.start..s.end].copy_from_slice(src);
        }
    }

    /// Add fragment `f` of `from` elementwise into the same fragment of
    /// `into` — the error-feedback replay: a residual fragment is folded
    /// back into the next outer delta before prune/codec.
    pub fn add_fragment(&self, from: &Tensors, into: &mut Tensors, f: usize) {
        for s in &self.fragments[f] {
            let src = &from.leaves()[s.leaf][s.start..s.end];
            for (d, &x) in into.leaves_mut()[s.leaf][s.start..s.end]
                .iter_mut()
                .zip(src)
            {
                *d += x;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn toy(leaves: &[&[f32]]) -> Tensors {
        Tensors::from_raw(leaves.iter().map(|l| l.to_vec()).collect())
    }

    #[test]
    fn single_fragment_covers_everything() {
        let plan = FragmentPlan::new(&[3, 5, 2], 1);
        assert_eq!(plan.n_fragments(), 1);
        assert_eq!(plan.elements(0), 10);
        assert_eq!(
            plan.slices(0),
            &[
                LeafSlice { leaf: 0, start: 0, end: 3 },
                LeafSlice { leaf: 1, start: 0, end: 5 },
                LeafSlice { leaf: 2, start: 0, end: 2 },
            ]
        );
    }

    #[test]
    fn fragments_partition_disjointly() {
        check("fragments tile the element space exactly once", 60, |g| {
            let n_leaves = g.usize_in(1..6);
            let sizes: Vec<usize> =
                (0..n_leaves).map(|_| g.usize_in(1..40)).collect();
            let total: usize = sizes.iter().sum();
            let p = g.usize_in(1..20);
            let plan = FragmentPlan::new(&sizes, p);
            assert_eq!(plan.n_fragments(), p.min(total));
            // Count coverage of every (leaf, element) coordinate.
            let mut seen: Vec<Vec<u32>> =
                sizes.iter().map(|&n| vec![0; n]).collect();
            let mut sum = 0;
            for f in 0..plan.n_fragments() {
                let mut frag_elems = 0;
                for s in plan.slices(f) {
                    assert!(!s.is_empty(), "empty slice emitted");
                    for i in s.start..s.end {
                        seen[s.leaf][i] += 1;
                    }
                    frag_elems += s.len();
                }
                assert_eq!(frag_elems, plan.elements(f));
                sum += frag_elems;
            }
            assert_eq!(sum, total);
            assert!(seen.iter().flatten().all(|&c| c == 1), "overlap or gap");
        });
    }

    #[test]
    fn fragment_sizes_near_equal() {
        let plan = FragmentPlan::new(&[100], 7);
        let sizes: Vec<usize> = (0..7).map(|f| plan.elements(f)).collect();
        let (lo, hi) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(hi - lo <= 1, "{sizes:?}");
        assert_eq!(sizes.iter().sum::<usize>(), 100);
    }

    #[test]
    fn requested_count_is_clamped() {
        assert_eq!(FragmentPlan::new(&[3], 10).n_fragments(), 3);
        assert_eq!(FragmentPlan::new(&[3], 0).n_fragments(), 1);
    }

    #[test]
    fn extract_scatter_roundtrip() {
        check("scatter(extract(t)) reassembles t bitwise", 60, |g| {
            let a = g.f32_vec(1..20, 5.0);
            let b = g.f32_vec(1..20, 5.0);
            let t = toy(&[&a, &b]);
            let p = g.usize_in(1..8);
            let plan = FragmentPlan::for_tensors(&t, p);
            let mut rebuilt = t.clone();
            rebuilt.scale(0.0);
            for f in 0..plan.n_fragments() {
                let vals = plan.extract(&t, f);
                plan.scatter(&vals, f, &mut rebuilt);
            }
            assert_eq!(rebuilt, t);
        });
    }

    #[test]
    fn extract_into_reused_dirty_buffer_matches_extract() {
        check("extract_into(reused buf) == extract bitwise", 40, |g| {
            let a = g.f32_vec(1..30, 5.0);
            let b = g.f32_vec(1..30, 5.0);
            let t = toy(&[&a, &b]);
            let p = g.usize_in(1..6);
            let plan = FragmentPlan::for_tensors(&t, p);
            // Seed the buffer with garbage longer than any fragment so a
            // missing clear() would leak stale values.
            let mut buf = vec![f32::NAN; a.len() + b.len() + 7];
            for f in 0..plan.n_fragments() {
                plan.extract_into(&t, f, &mut buf);
                let fresh = plan.extract(&t, f);
                assert_eq!(buf.len(), fresh.len());
                for (x, y) in buf.iter().zip(&fresh) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
        });
    }

    #[test]
    fn copy_fragment_moves_only_that_fragment() {
        let src = toy(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut dst = toy(&[&[0.0, 0.0], &[0.0, 0.0]]);
        let plan = FragmentPlan::for_tensors(&src, 2);
        plan.copy_fragment(&src, &mut dst, 0);
        let got: Vec<f32> = dst.iter_flat().collect();
        assert_eq!(got, vec![1.0, 2.0, 0.0, 0.0]);
    }

    #[test]
    fn add_fragment_adds_only_that_fragment() {
        let src = toy(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let mut dst = toy(&[&[10.0, 10.0], &[10.0, 10.0]]);
        let plan = FragmentPlan::for_tensors(&src, 2);
        plan.add_fragment(&src, &mut dst, 1);
        let got: Vec<f32> = dst.iter_flat().collect();
        assert_eq!(got, vec![10.0, 10.0, 13.0, 14.0]);
        // Adding an all-zero tree is the identity (the EF-off residual).
        let zeros = toy(&[&[0.0, 0.0], &[0.0, 0.0]]);
        plan.add_fragment(&zeros, &mut dst, 0);
        plan.add_fragment(&zeros, &mut dst, 1);
        assert_eq!(dst.iter_flat().collect::<Vec<f32>>(), got);
    }
}
