//! The `Fabric` trait — the transport seam between the round loops and
//! whatever actually carries (or accounts for) the bytes.
//!
//! Everything the coordinator ever asked of [`SimNet`] is captured here:
//! keyed droppable sends (`try_send_gen` and its convenience wrappers),
//! reliable lane-billed sends, round barriers (eager and deferred), and
//! the per-round [`CommStats`] rows. Two lifecycle hooks extend that
//! surface for backends with real machinery behind them:
//!
//! * [`Fabric::filter_roster`] — called once per round with the
//!   schedule/churn roster *before* any compute. A backend may shrink it
//!   (a vanished TCP peer maps onto the existing `[churn]` leave
//!   semantics) or perform maintenance (heartbeats, reconnect drains,
//!   respawns). SimNet is the identity.
//! * [`Fabric::run_phase`] — offered the inner phase. A backend that
//!   owns remote compute (TcpFabric) runs the phase on its peers and
//!   returns `Some(PhaseOutcome)`; SimNet returns `None`, telling the
//!   coordinator to run the phase in-process through its
//!   `InnerPhaseExecutor` exactly as before.
//!
//! The split keeps the simulator the bitwise golden path: with the
//! default `fabric = "sim"` every call delegates to the same `SimNet`
//! inherent methods the loops called directly before the trait existed,
//! so traces, drop keys, and byte bills are unchanged by construction.
//! See DESIGN.md §14 for the TCP backend and the cross-backend
//! differential contract.

use super::{CommStats, Direction, SimNet};
use crate::engine::InnerPhaseReport;
use crate::worker::Worker;

/// Result of a fabric-run inner phase ([`Fabric::run_phase`]).
pub struct PhaseOutcome {
    /// Per-roster-position loss/compute traces, same shape as the
    /// in-process engine path produces.
    pub report: InnerPhaseReport,
    /// Per-roster-position "peer vanished mid-phase" flags. A vanished
    /// worker contributed no losses this round: the coordinator averages
    /// loss over live workers only and books the worker's sync as a
    /// drop. All-false on healthy rounds — and the healthy-round fold is
    /// bitwise identical to the pre-trait code.
    pub vanished: Vec<bool>,
}

/// Transport abstraction for one training run. Object-safe: the
/// coordinator holds a `Box<dyn Fabric>` chosen by `[fabric] kind`.
pub trait Fabric {
    /// Droppable send with the full (round, worker, fragment, hop, gen)
    /// drop key. Returns `false` when the message was dropped; billing
    /// happens either way.
    #[allow(clippy::too_many_arguments)]
    fn try_send_gen(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
        gen: usize,
    ) -> bool;

    /// Reliable send on a fresh anonymous lane (no overlap with any
    /// other transfer).
    fn send_reliable(&mut self, bytes: u64, dir: Direction);

    /// Reliable send on worker `worker`'s per-direction lane.
    fn send_reliable_to(&mut self, bytes: u64, dir: Direction, worker: usize);

    /// Close the round: fold the lane barrier into the billed time.
    fn end_round(&mut self);

    /// Close the round but *return* the barrier instead of billing it,
    /// so an overlapped schedule can hide it behind the next phase.
    fn end_round_deferred(&mut self) -> f64;

    /// Cumulative + per-round accounting.
    fn stats(&self) -> &CommStats;

    /// Modeled serialization time for `bytes` on this fabric's link.
    fn transfer_time(&self, bytes: u64) -> f64;

    /// Round-start roster hook: heartbeat peers, drain reconnects, and
    /// return the subset of `roster` that is actually reachable this
    /// round. The default (and SimNet) is the identity.
    fn filter_roster(
        &mut self,
        round: usize,
        roster: Vec<usize>,
    ) -> anyhow::Result<Vec<usize>> {
        let _ = round;
        Ok(roster)
    }

    /// Offer the inner phase to the fabric. Return `Ok(None)` to let the
    /// coordinator run it in-process (the simulator path); return
    /// `Ok(Some(outcome))` after running `h` inner steps for each roster
    /// member in `ids` on remote peers, with `workers[id]` state updated
    /// in place for every non-vanished peer.
    fn run_phase(
        &mut self,
        workers: &mut [Worker],
        ids: &[usize],
        h: usize,
    ) -> anyhow::Result<Option<PhaseOutcome>> {
        let _ = (workers, ids, h);
        Ok(None)
    }

    /// Droppable send with the legacy (round, worker) key.
    fn try_send(&mut self, bytes: u64, dir: Direction, round: usize, worker: usize) -> bool {
        self.try_send_gen(bytes, dir, round, worker, 0, 0, 0)
    }

    /// Droppable send keyed by fragment (hop 0, generation 0).
    fn try_send_fragment(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
    ) -> bool {
        self.try_send_gen(bytes, dir, round, worker, fragment, 0, 0)
    }

    /// Droppable send keyed by hop (generation 0).
    fn try_send_hop(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
    ) -> bool {
        self.try_send_gen(bytes, dir, round, worker, fragment, hop, 0)
    }
}

/// SimNet is the first (and golden) implementor: pure delegation to the
/// inherent methods, identity roster, in-process compute.
impl Fabric for SimNet {
    fn try_send_gen(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
        gen: usize,
    ) -> bool {
        SimNet::try_send_gen(self, bytes, dir, round, worker, fragment, hop, gen)
    }

    fn send_reliable(&mut self, bytes: u64, dir: Direction) {
        SimNet::send_reliable(self, bytes, dir)
    }

    fn send_reliable_to(&mut self, bytes: u64, dir: Direction, worker: usize) {
        SimNet::send_reliable_to(self, bytes, dir, worker)
    }

    fn end_round(&mut self) {
        SimNet::end_round(self)
    }

    fn end_round_deferred(&mut self) -> f64 {
        SimNet::end_round_deferred(self)
    }

    fn stats(&self) -> &CommStats {
        SimNet::stats(self)
    }

    fn transfer_time(&self, bytes: u64) -> f64 {
        SimNet::transfer_time(self, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sim() -> SimNet {
        SimNet::new(1e6, 0.01, 0.0, Rng::new(7))
    }

    /// Calling SimNet through `dyn Fabric` must be indistinguishable
    /// from calling it directly: same bills, same drop keys, same
    /// barrier fold — the trait is a seam, not a behavior change.
    #[test]
    fn dyn_simnet_matches_direct_calls() {
        let mut direct = sim();
        let mut boxed: Box<dyn Fabric> = Box::new(sim());

        for round in 0..3 {
            for w in 0..4 {
                let a = SimNet::try_send_gen(
                    &mut direct,
                    1000 + w as u64,
                    Direction::Up,
                    round,
                    w,
                    w % 2,
                    w % 3,
                    round % 2,
                );
                let b = boxed.try_send_gen(
                    1000 + w as u64,
                    Direction::Up,
                    round,
                    w,
                    w % 2,
                    w % 3,
                    round % 2,
                );
                assert_eq!(a, b);
                SimNet::send_reliable_to(&mut direct, 512, Direction::Down, w);
                boxed.send_reliable_to(512, Direction::Down, w);
            }
            if round == 1 {
                let a = SimNet::end_round_deferred(&mut direct);
                let b = boxed.end_round_deferred();
                assert_eq!(a.to_bits(), b.to_bits());
            } else {
                SimNet::end_round(&mut direct);
                boxed.end_round();
            }
        }
        assert_eq!(SimNet::stats(&direct), boxed.stats());
    }

    /// With drops enabled the decision stream must also agree: the drop
    /// RNG is keyed, not sequential, so delegation cannot perturb it.
    #[test]
    fn dyn_simnet_matches_direct_drop_decisions() {
        let mut direct = SimNet::new(1e6, 0.0, 0.5, Rng::new(3));
        let mut boxed: Box<dyn Fabric> = Box::new(SimNet::new(1e6, 0.0, 0.5, Rng::new(3)));
        for round in 0..8 {
            for w in 0..5 {
                for f in 0..2 {
                    let a = SimNet::try_send_fragment(
                        &mut direct,
                        64,
                        Direction::Up,
                        round,
                        w,
                        f,
                    );
                    let b = boxed.try_send_fragment(64, Direction::Up, round, w, f);
                    assert_eq!(a, b, "round {round} worker {w} fragment {f}");
                }
            }
            SimNet::end_round(&mut direct);
            boxed.end_round();
        }
        assert_eq!(SimNet::stats(&direct), boxed.stats());
    }

    /// Default hook contracts: identity roster, `None` phase (the
    /// coordinator runs the engine path).
    #[test]
    fn simnet_hooks_are_passthrough() {
        let mut net = sim();
        let roster = Fabric::filter_roster(&mut net, 0, vec![0, 2, 3]).unwrap();
        assert_eq!(roster, vec![0, 2, 3]);
        let out = Fabric::run_phase(&mut net, &mut [], &[], 5).unwrap();
        assert!(out.is_none());
    }
}
