//! Length-prefixed, checksummed frame codec for the TCP fabric.
//!
//! Every message between the coordinator and a worker process is one
//! frame:
//!
//! ```text
//! +-------+---------+-------+--------------+--------+-------------+
//! | magic | version | ftype | body_len u32 |  body  | fnv64(h+b)  |
//! | DLFR  |   0x01  |  u8   |   LE         |        |   LE        |
//! +-------+---------+-------+--------------+--------+-------------+
//!    4        1        1          4          len         8
//! ```
//!
//! The checksum is the same FNV-1a 64 the checkpoint container uses
//! (`checkpoint::fnv_update`), computed over header + body. Frame bodies
//! reuse the checkpoint writer/Reader primitives (`w_u32`/`w_u64`/
//! `w_f64`/`w_tensors` and the bounds-checked `Reader`), so the decoder
//! inherits the same discipline: every length is validated against a
//! caller-supplied cap *before* any allocation, and malformed input is
//! an `Err`, never a panic or an over-allocation.

use crate::checkpoint::{fnv_update, FNV_OFFSET};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

/// Frame magic, first on the wire so a stray peer fails fast.
pub const MAGIC: [u8; 4] = *b"DLFR";
/// Protocol version; bumped on any layout change.
pub const VERSION: u8 = 1;
/// Fixed prefix: magic + version + ftype + body_len.
pub const HEADER_LEN: usize = 10;
/// Trailing FNV-1a 64 checksum.
pub const TRAILER_LEN: usize = 8;
/// Absolute backstop on body size, independent of the caller's cap.
pub const MAX_FRAME_BODY: usize = 1 << 28;

/// Worker → coordinator: rendezvous (body = run-ID string).
pub const HELLO: u8 = 1;
/// Coordinator → worker: slot assignment (body = slot u32).
pub const HELLO_ACK: u8 = 2;
/// Coordinator → worker: data-shard + batch-shape bootstrap.
pub const INIT: u8 = 3;
/// Coordinator → worker: full island state, run `h` inner steps.
pub const RUN_PHASE: u8 = 4;
/// Worker → coordinator: losses + updated island state.
pub const PHASE_DONE: u8 = 5;
/// Coordinator → worker: heartbeat probe.
pub const PING: u8 = 6;
/// Worker → coordinator: heartbeat reply.
pub const PONG: u8 = 7;
/// Coordinator → worker: clean exit.
pub const SHUTDOWN: u8 = 8;

fn checksum(header: &[u8], body: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    fnv_update(&mut h, header);
    fnv_update(&mut h, body);
    h
}

/// Encode one frame into a fresh buffer.
pub fn encode(ftype: u8, body: &[u8]) -> Vec<u8> {
    assert!(body.len() <= MAX_FRAME_BODY, "frame body over backstop");
    let mut out = Vec::with_capacity(HEADER_LEN + body.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(ftype);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(body);
    let c = checksum(&out[..HEADER_LEN], body);
    out.extend_from_slice(&c.to_le_bytes());
    out
}

/// Validate a header and return the body length. `cap` is the largest
/// body the caller is prepared to hold (derived from the manifest /
/// message kind), checked before the caller allocates anything.
fn parse_header(header: &[u8; HEADER_LEN], cap: usize) -> Result<(u8, usize)> {
    ensure!(header[..4] == MAGIC, "bad frame magic {:02x?}", &header[..4]);
    ensure!(
        header[4] == VERSION,
        "unsupported frame version {} (want {VERSION})",
        header[4]
    );
    let ftype = header[5];
    let len = u32::from_le_bytes([header[6], header[7], header[8], header[9]]) as usize;
    ensure!(
        len <= cap && len <= MAX_FRAME_BODY,
        "frame body length {len} exceeds cap {} for frame type {ftype}",
        cap.min(MAX_FRAME_BODY)
    );
    Ok((ftype, len))
}

/// Decode one frame from a byte slice. Returns `(ftype, body, consumed)`.
pub fn decode(buf: &[u8], cap: usize) -> Result<(u8, &[u8], usize)> {
    ensure!(
        buf.len() >= HEADER_LEN,
        "truncated frame header: {} of {HEADER_LEN} bytes",
        buf.len()
    );
    let mut header = [0u8; HEADER_LEN];
    header.copy_from_slice(&buf[..HEADER_LEN]);
    let (ftype, len) = parse_header(&header, cap)?;
    let total = HEADER_LEN + len + TRAILER_LEN;
    ensure!(
        buf.len() >= total,
        "truncated frame: have {} of {total} bytes",
        buf.len()
    );
    let body = &buf[HEADER_LEN..HEADER_LEN + len];
    let got = u64::from_le_bytes(
        buf[HEADER_LEN + len..total].try_into().expect("8 trailer bytes"),
    );
    let want = checksum(&header, body);
    ensure!(got == want, "frame checksum mismatch ({got:#x} != {want:#x})");
    Ok((ftype, body, total))
}

/// Write one frame to a stream.
pub fn write_frame(w: &mut impl Write, ftype: u8, body: &[u8]) -> Result<()> {
    w.write_all(&encode(ftype, body)).context("frame write")?;
    w.flush().context("frame flush")
}

/// Read one frame from a stream. A short read (peer died mid-frame) or
/// a stream timeout surfaces as an `Err`; the body buffer is only
/// allocated after its declared length passes the `cap` check.
pub fn read_frame(r: &mut impl Read, cap: usize) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).context("frame header read")?;
    let (ftype, len) = parse_header(&header, cap)?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("frame body read")?;
    let mut trailer = [0u8; TRAILER_LEN];
    r.read_exact(&mut trailer).context("frame trailer read")?;
    let got = u64::from_le_bytes(trailer);
    let want = checksum(&header, &body);
    if got != want {
        bail!("frame checksum mismatch ({got:#x} != {want:#x})");
    }
    Ok((ftype, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_slice_and_stream() {
        for body in [&b""[..], b"x", &[7u8; 1000]] {
            let wire = encode(RUN_PHASE, body);
            assert_eq!(wire.len(), HEADER_LEN + body.len() + TRAILER_LEN);

            let (t, got, used) = decode(&wire, body.len()).unwrap();
            assert_eq!((t, got, used), (RUN_PHASE, body, wire.len()));

            let (t, got) = read_frame(&mut Cursor::new(&wire), body.len()).unwrap();
            assert_eq!((t, got.as_slice()), (RUN_PHASE, body));
        }
    }

    #[test]
    fn two_frames_back_to_back() {
        let mut wire = encode(PING, b"");
        wire.extend_from_slice(&encode(PONG, b"abc"));
        let mut r = Cursor::new(&wire);
        assert_eq!(read_frame(&mut r, 16).unwrap().0, PING);
        let (t, body) = read_frame(&mut r, 16).unwrap();
        assert_eq!((t, body.as_slice()), (PONG, &b"abc"[..]));
    }

    #[test]
    fn truncated_length_prefix_errors() {
        // Every strict prefix of the header must error, never panic.
        let wire = encode(HELLO, b"run-id");
        for n in 0..HEADER_LEN {
            assert!(decode(&wire[..n], 64).is_err(), "prefix {n}");
            assert!(read_frame(&mut Cursor::new(&wire[..n]), 64).is_err());
        }
    }

    #[test]
    fn mid_frame_disconnect_errors() {
        // Peer dies after the header but before the full body+trailer:
        // the stream reader must surface an error, not block or panic.
        let wire = encode(PHASE_DONE, &[9u8; 256]);
        for n in [HEADER_LEN, HEADER_LEN + 1, wire.len() - TRAILER_LEN, wire.len() - 1] {
            assert!(read_frame(&mut Cursor::new(&wire[..n]), 256).is_err(), "cut {n}");
            assert!(decode(&wire[..n], 256).is_err(), "cut {n}");
        }
    }

    #[test]
    fn checksum_mismatch_errors() {
        let mut wire = encode(INIT, &[1, 2, 3, 4]);
        // Flip one bit in the body, then one in the trailer.
        let body_at = HEADER_LEN + 1;
        wire[body_at] ^= 0x40;
        assert!(decode(&wire, 16).unwrap_err().to_string().contains("checksum"));
        wire[body_at] ^= 0x40;
        let trailer_at = wire.len() - 2;
        wire[trailer_at] ^= 0x01;
        let err = read_frame(&mut Cursor::new(&wire), 16).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn corrupt_header_errors() {
        let good = encode(PING, b"");
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        assert!(decode(&bad_magic, 16).unwrap_err().to_string().contains("magic"));
        let mut bad_version = good.clone();
        bad_version[4] = 9;
        assert!(decode(&bad_version, 16).unwrap_err().to_string().contains("version"));
    }

    /// A hostile length prefix (u32::MAX, or merely bigger than the
    /// manifest-derived cap) is rejected from the 10-byte header alone —
    /// before any body allocation could happen.
    #[test]
    fn oversized_length_rejected_before_allocation() {
        let mut wire = encode(RUN_PHASE, &[0u8; 8]);
        wire[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = decode(&wire, 1 << 20).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        let err = read_frame(&mut Cursor::new(&wire), 1 << 20).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");

        // A frame that is well-formed but larger than this message
        // kind's cap (e.g. a state frame where a PONG belongs) is
        // rejected the same way.
        let big = encode(PONG, &[0u8; 4096]);
        assert!(decode(&big, 16).unwrap_err().to_string().contains("exceeds cap"));

        // The absolute backstop holds even with a huge caller cap.
        let mut huge = encode(PING, b"");
        huge[6..10].copy_from_slice(&((MAX_FRAME_BODY as u32) + 1).to_le_bytes());
        assert!(decode(&huge, usize::MAX).is_err());
    }

    /// Pin the layout constants: golden bytes for an empty PING frame.
    #[test]
    fn wire_layout_is_pinned() {
        let wire = encode(PING, b"");
        assert_eq!(&wire[..4], b"DLFR");
        assert_eq!(wire[4], 1);
        assert_eq!(wire[5], PING);
        assert_eq!(&wire[6..10], &[0, 0, 0, 0]);
        assert_eq!(wire.len(), 18);
    }
}
