//! Outer-gradient wire codecs (DiLoCoX-style compression).
//!
//! DiLoCo already communicates ~500× less than synchronous data
//! parallelism by syncing rarely; the follow-up work (DiLoCoX,
//! arXiv:2506.21263) compresses what *is* sent. A [`Codec`] transforms an
//! outer-gradient payload before it crosses the [`super::SimNet`]:
//! the coordinator always averages the **dequantized** values, so the
//! quantization error is part of the simulated algorithm, not just of the
//! byte accounting, and every round's error is reported deterministically
//! (`RoundStats::codec_err_l2`).
//!
//! **Determinism contract:** `transcode` is a pure elementwise function
//! of its input (no RNG, no dithering), so traces are reproducible and
//! the `f32` codec is bitwise exact — the default configuration stays on
//! the golden trace.

use super::fragment::LeafSlice;

/// How an outer-gradient fragment is encoded on the wire.
///
/// ```
/// use diloco::comm::codec::Codec;
/// use diloco::comm::fragment::LeafSlice;
///
/// let mut payload = vec![1.0f32, -2.0, 0.5];
/// let slices = [LeafSlice { leaf: 0, start: 0, end: 3 }];
/// let err = Codec::F32.transcode(&mut payload, &slices);
/// assert_eq!(err, 0.0);                       // f32 is bitwise exact
/// assert_eq!(payload, vec![1.0, -2.0, 0.5]);
/// // q8 bills 1 byte/element plus an 8-byte (min, scale) sidecar per slice.
/// assert_eq!(Codec::Q8.encoded_bytes(100, 2), 116);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// Full precision — bitwise exact, 4 bytes/element (the default).
    F32,
    /// IEEE half precision, round-to-nearest-even, 2 bytes/element.
    F16,
    /// 8-bit uniform quantization per leaf slice (min/scale sidecar),
    /// 1 byte/element + 8 bytes per slice.
    Q8,
    /// 4-bit uniform quantization (16 levels), ½ byte/element + 8 bytes
    /// per slice (MuLoCo, arXiv 2505.23725, pairs this with error
    /// feedback).
    Q4,
    /// 2-bit uniform quantization (4 levels), ¼ byte/element + 8 bytes
    /// per slice — the MuLoCo headline rate.
    Q2,
}

impl Codec {
    pub fn parse(s: &str) -> anyhow::Result<Codec> {
        match s {
            "f32" => Ok(Codec::F32),
            "f16" => Ok(Codec::F16),
            "q8" => Ok(Codec::Q8),
            "q4" => Ok(Codec::Q4),
            "q2" => Ok(Codec::Q2),
            other => anyhow::bail!("unknown codec {other:?} (want f32|f16|q8|q4|q2)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::F16 => "f16",
            Codec::Q8 => "q8",
            Codec::Q4 => "q4",
            Codec::Q2 => "q2",
        }
    }

    /// Quantization grid size minus one (the divisor of the uniform
    /// step), `None` for the float codecs.
    pub fn quant_levels(&self) -> Option<f32> {
        match self {
            Codec::F32 | Codec::F16 => None,
            Codec::Q8 => Some(255.0),
            Codec::Q4 => Some(15.0),
            Codec::Q2 => Some(3.0),
        }
    }

    /// Billed wire bytes for a payload of `n_elements` spread over
    /// `n_slices` contiguous leaf slices.
    pub fn encoded_bytes(&self, n_elements: usize, n_slices: usize) -> u64 {
        match self {
            Codec::F32 => 4 * n_elements as u64,
            Codec::F16 => 2 * n_elements as u64,
            // 1/½/¼ byte per value + f32 (min, scale) sidecar per slice.
            Codec::Q8 => n_elements as u64 + 8 * n_slices as u64,
            Codec::Q4 => (n_elements as u64).div_ceil(2) + 8 * n_slices as u64,
            Codec::Q2 => (n_elements as u64).div_ceil(4) + 8 * n_slices as u64,
        }
    }

    /// Encode + decode `values` in place (what the receiver will see) and
    /// return the squared L2 dequantization error, accumulated in f64 in
    /// slice order — deterministic for a given input.
    pub fn transcode(&self, values: &mut [f32], slices: &[LeafSlice]) -> f64 {
        match self {
            Codec::F32 => 0.0,
            Codec::F16 => {
                let mut err_sq = 0.0f64;
                for x in values.iter_mut() {
                    let orig = *x;
                    *x = f16_bits_to_f32(f32_to_f16_bits(orig));
                    let e = (orig - *x) as f64;
                    err_sq += e * e;
                }
                err_sq
            }
            Codec::Q8 | Codec::Q4 | Codec::Q2 => {
                let levels = self.quant_levels().expect("quantized codec");
                let mut err_sq = 0.0f64;
                let mut off = 0usize;
                for s in slices {
                    let part = &mut values[off..off + s.len()];
                    err_sq += quant_roundtrip(part, levels);
                    off += s.len();
                }
                debug_assert_eq!(off, values.len(), "slice lens cover payload");
                err_sq
            }
        }
    }

    /// Sparse-aware encode + decode: exact zeros (the positions a sparse
    /// payload never ships — they live in the bitmap) stay exactly `0.0`,
    /// and the quantized codecs fit their per-slice `(min, scale)` grid
    /// over the **non-zeros only**, since those are the only values on
    /// the wire. Returns the squared L2 error, accumulated in f64 in
    /// slice order.
    ///
    /// For `f32` and `f16` this is exactly [`Codec::transcode`] (both map
    /// `±0.0` to itself bitwise, so sparsity is preserved for free); the
    /// separate entry point matters for `q8|q4|q2`, where a dense grid
    /// over a pruned payload would decode the zeroed positions to
    /// `min + q·scale ≠ 0` and silently densify the fragment.
    pub fn transcode_sparse(&self, values: &mut [f32], slices: &[LeafSlice]) -> f64 {
        match self {
            Codec::F32 | Codec::F16 => self.transcode(values, slices),
            Codec::Q8 | Codec::Q4 | Codec::Q2 => {
                let levels = self.quant_levels().expect("quantized codec");
                let mut err_sq = 0.0f64;
                let mut off = 0usize;
                for s in slices {
                    let part = &mut values[off..off + s.len()];
                    err_sq += quant_roundtrip_nonzero(part, levels);
                    off += s.len();
                }
                debug_assert_eq!(off, values.len(), "slice lens cover payload");
                err_sq
            }
        }
    }
}

/// Fused extract + encode/decode: flatten fragment `f` of `t` into the
/// reused buffer `out` (cleared first) with the codec round-trip applied,
/// returning the squared L2 dequantization error. Bitwise identical to
/// `plan.extract_into(...)` followed by `codec.transcode(...)`:
///
/// * `f32` is a plain copy (no transcode pass at all);
/// * `f16` converts each element as it is copied — same per-element
///   function in the same element order as the two-pass form, one memory
///   pass instead of two;
/// * `q8|q4|q2` need each slice's min/max before they can quantize, so
///   they keep the copy-then-transcode structure (the wire format does
///   not permit a single pass).
pub fn extract_transcode(
    codec: Codec,
    plan: &crate::comm::fragment::FragmentPlan,
    t: &crate::runtime::Tensors,
    f: usize,
    out: &mut Vec<f32>,
) -> f64 {
    match codec {
        Codec::F32 => {
            plan.extract_into(t, f, out);
            0.0
        }
        Codec::F16 => {
            out.clear();
            out.reserve(plan.elements(f));
            let mut err_sq = 0.0f64;
            for s in plan.slices(f) {
                for &orig in &t.leaves()[s.leaf][s.start..s.end] {
                    let x = f16_bits_to_f32(f32_to_f16_bits(orig));
                    let e = (orig - x) as f64;
                    err_sq += e * e;
                    out.push(x);
                }
            }
            err_sq
        }
        Codec::Q8 | Codec::Q4 | Codec::Q2 => {
            plan.extract_into(t, f, out);
            codec.transcode(out, plan.slices(f))
        }
    }
}

/// Sparse-aware sibling of [`extract_transcode`]: flatten fragment `f`
/// of `t` into `out` with [`Codec::transcode_sparse`] applied. Used by
/// the coordinator when the payload is sparse (`prune_frac > 0`) so
/// pruned-to-zero positions survive the codec round trip exactly.
pub fn extract_transcode_sparse(
    codec: Codec,
    plan: &crate::comm::fragment::FragmentPlan,
    t: &crate::runtime::Tensors,
    f: usize,
    out: &mut Vec<f32>,
) -> f64 {
    match codec {
        // The float codecs preserve ±0.0 bitwise — reuse the fused path.
        Codec::F32 | Codec::F16 => extract_transcode(codec, plan, t, f, out),
        Codec::Q8 | Codec::Q4 | Codec::Q2 => {
            plan.extract_into(t, f, out);
            codec.transcode_sparse(out, plan.slices(f))
        }
    }
}

/// Uniform `levels+1`-point round trip over one contiguous slice;
/// returns the squared error. `scale = (max - min) / levels`; a constant
/// slice encodes exactly (scale 0 ⇒ every value decodes to `min`).
/// `levels = 255` reproduces the original q8 arithmetic bit for bit.
fn quant_roundtrip(values: &mut [f32], levels: f32) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in values.iter() {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    let scale = (hi - lo) / levels;
    let mut err_sq = 0.0f64;
    for x in values.iter_mut() {
        let orig = *x;
        *x = if scale == 0.0 {
            lo
        } else {
            let q = ((orig - lo) / scale).round().clamp(0.0, levels);
            lo + q * scale
        };
        let e = (orig - *x) as f64;
        err_sq += e * e;
    }
    err_sq
}

/// [`quant_roundtrip`] restricted to the non-zero entries: the grid is
/// fitted over non-zeros only and exact zeros pass through untouched
/// (they are bitmap positions, not wire values, in a sparse payload).
fn quant_roundtrip_nonzero(values: &mut [f32], levels: f32) -> f64 {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    let mut nnz = 0usize;
    for &x in values.iter() {
        if x != 0.0 {
            lo = lo.min(x);
            hi = hi.max(x);
            nnz += 1;
        }
    }
    if nnz == 0 {
        return 0.0;
    }
    let scale = (hi - lo) / levels;
    let mut err_sq = 0.0f64;
    for x in values.iter_mut() {
        if *x == 0.0 {
            continue;
        }
        let orig = *x;
        *x = if scale == 0.0 {
            lo
        } else {
            let q = ((orig - lo) / scale).round().clamp(0.0, levels);
            lo + q * scale
        };
        let e = (orig - *x) as f64;
        err_sq += e * e;
    }
    err_sq
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 255 {
        // Inf / NaN (quiet the NaN payload into one bit).
        return sign | 0x7c00 | u16::from(mant != 0) << 9;
    }
    let e = exp - 127 + 15; // rebased target exponent
    if e >= 31 {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflows past the smallest subnormal → ±0
        }
        // Subnormal: M = round(1.mant × 2^(e-15) / 2^-24).
        let mant = mant | 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = (mant >> shift) as u16;
        let rem = mant & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && half & 1 == 1);
        return sign | (half + u16::from(round_up));
    }
    // Normal: 10-bit mantissa, ties-to-even; a rounding carry into the
    // exponent (possibly up to inf) is correct by construction.
    let h = ((e as u32) << 10) as u16 | (mant >> 13) as u16;
    let rem = mant & 0x1fff;
    let round_up = rem > 0x1000 || (rem == 0x1000 && h & 1 == 1);
    sign | h.wrapping_add(u16::from(round_up))
}

/// IEEE 754 binary16 bits → f32 (exact — every f16 is an f32).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign_neg = h & 0x8000 != 0;
    let exp = (h >> 10) & 0x1f;
    let mant = (h & 0x3ff) as u32;
    let v = if exp == 0 {
        // ±0 and subnormals: M × 2^-24 (exactly representable in f32).
        mant as f32 * (1.0 / 16_777_216.0)
    } else if exp == 31 {
        if mant != 0 {
            f32::NAN
        } else {
            f32::INFINITY
        }
    } else {
        f32::from_bits(((exp as u32 + 112) << 23) | (mant << 13))
    };
    if sign_neg {
        -v
    } else {
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    fn one_slice(n: usize) -> Vec<LeafSlice> {
        vec![LeafSlice { leaf: 0, start: 0, end: n }]
    }

    #[test]
    fn parse_and_names() {
        for c in [Codec::F32, Codec::F16, Codec::Q8, Codec::Q4, Codec::Q2] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert!(Codec::parse("q3").is_err());
        assert!(Codec::parse("int8").is_err());
    }

    #[test]
    fn byte_sizes() {
        assert_eq!(Codec::F32.encoded_bytes(100, 3), 400);
        assert_eq!(Codec::F16.encoded_bytes(100, 3), 200);
        assert_eq!(Codec::Q8.encoded_bytes(100, 3), 124);
        // Sub-byte codecs round the packed nibble/crumb array up.
        assert_eq!(Codec::Q4.encoded_bytes(100, 3), 74);
        assert_eq!(Codec::Q4.encoded_bytes(101, 3), 75);
        assert_eq!(Codec::Q2.encoded_bytes(100, 3), 49);
        assert_eq!(Codec::Q2.encoded_bytes(101, 3), 50);
    }

    #[test]
    fn f32_codec_is_exact() {
        let mut v = vec![0.1f32, -2.5, 1e-20, 3.4e38];
        let orig = v.clone();
        let err = Codec::F32.transcode(&mut v, &one_slice(4));
        assert_eq!(v, orig);
        assert_eq!(err, 0.0);
    }

    #[test]
    fn f16_roundtrip_exact_on_representable_values() {
        // Values exactly representable in f16 must survive bitwise.
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, 6.103_515_6e-5] {
            let y = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(x.to_bits(), y.to_bits(), "{x} -> {y}");
        }
    }

    #[test]
    fn f16_special_values() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(
            f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)),
            f32::NEG_INFINITY
        );
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // Overflow saturates to inf, deep underflow flushes to signed zero.
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY);
        let tiny = f16_bits_to_f32(f32_to_f16_bits(1e-12));
        assert_eq!(tiny, 0.0);
        let ntiny = f16_bits_to_f32(f32_to_f16_bits(-1e-12));
        assert_eq!(ntiny, 0.0);
        assert!(ntiny.is_sign_negative());
    }

    #[test]
    fn f16_relative_error_bound() {
        check("f16 round trip stays within 2^-11 relative error", 100, |g| {
            let v = g.f32_vec(1..50, 4.0);
            for &x in &v {
                let y = f16_bits_to_f32(f32_to_f16_bits(x));
                let tol = x.abs() as f64 * (1.0 / 2048.0) + 1e-7;
                assert!(
                    ((x - y) as f64).abs() <= tol,
                    "f16({x}) = {y} off by more than {tol}"
                );
            }
        });
    }

    #[test]
    fn q8_error_bounded_by_half_step() {
        check("q8 error ≤ (max-min)/510 per element", 100, |g| {
            let mut v = g.f32_vec(2..80, 3.0);
            let orig = v.clone();
            let lo = orig.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = orig.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let n = v.len();
            Codec::Q8.transcode(&mut v, &one_slice(n));
            let half_step = ((hi - lo) as f64 / 255.0) / 2.0 + 1e-6;
            for (a, b) in orig.iter().zip(&v) {
                assert!(
                    ((a - b) as f64).abs() <= half_step,
                    "q8 moved {a} to {b}, step/2 = {half_step}"
                );
            }
        });
    }

    #[test]
    fn quantized_constant_slice_is_exact() {
        for codec in [Codec::Q8, Codec::Q4, Codec::Q2] {
            let mut v = vec![0.25f32; 9];
            let err = codec.transcode(&mut v, &one_slice(9));
            assert!(v.iter().all(|&x| x == 0.25), "{codec:?}");
            assert_eq!(err, 0.0, "{codec:?}");
        }
    }

    #[test]
    fn q4_q2_error_bounded_by_half_step() {
        // Satellite: q4/q2 round-trip error bound — each element moves by
        // at most half a grid step, step = (max-min)/levels.
        check("q4/q2 error ≤ (max-min)/(2·levels) per element", 100, |g| {
            let orig = g.f32_vec(2..80, 3.0);
            let lo = orig.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = orig.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let n = orig.len();
            for (codec, levels) in [(Codec::Q4, 15.0f64), (Codec::Q2, 3.0f64)] {
                let mut v = orig.clone();
                codec.transcode(&mut v, &one_slice(n));
                let half_step = ((hi - lo) as f64 / levels) / 2.0 + 1e-6;
                for (a, b) in orig.iter().zip(&v) {
                    assert!(
                        ((a - b) as f64).abs() <= half_step,
                        "{codec:?} moved {a} to {b}, step/2 = {half_step}"
                    );
                }
            }
        });
    }

    #[test]
    fn sparse_transcode_keeps_zeros_and_bounds_nonzero_error() {
        // The sparse round trip never touches exact zeros, and its grid is
        // fitted over the non-zeros, so each surviving value moves by at
        // most half a non-zero-range step.
        check("sparse transcode preserves zeros", 100, |g| {
            let mut orig = g.f32_vec(4..80, 3.0);
            // Zero out a random prefix-strided subset to fake a pruned payload.
            let stride = g.usize_in(2..5);
            for (i, x) in orig.iter_mut().enumerate() {
                if i % stride == 0 {
                    *x = 0.0;
                }
            }
            let nz: Vec<f32> = orig.iter().copied().filter(|&x| x != 0.0).collect();
            let n = orig.len();
            for codec in [Codec::F32, Codec::F16, Codec::Q8, Codec::Q4, Codec::Q2] {
                let mut v = orig.clone();
                let err = codec.transcode_sparse(&mut v, &one_slice(n));
                for (a, b) in orig.iter().zip(&v) {
                    if *a == 0.0 {
                        assert_eq!(b.to_bits(), 0.0f32.to_bits(), "{codec:?}");
                    }
                }
                if codec == Codec::F32 {
                    assert_eq!(err, 0.0);
                    assert_eq!(v, orig);
                }
                if let Some(levels) = codec.quant_levels() {
                    if nz.len() >= 2 {
                        let lo = nz.iter().cloned().fold(f32::INFINITY, f32::min);
                        let hi =
                            nz.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        let half = ((hi - lo) as f64 / levels as f64) / 2.0 + 1e-6;
                        for (a, b) in orig.iter().zip(&v) {
                            if *a != 0.0 {
                                assert!(
                                    ((a - b) as f64).abs() <= half,
                                    "{codec:?}: {a} -> {b}, half-step {half}"
                                );
                            }
                        }
                    }
                }
            }
        });
    }

    #[test]
    fn sparse_and_dense_transcode_agree_on_fully_dense_input() {
        // With no zeros present the non-zero grid IS the dense grid, so
        // the two entry points are bitwise identical.
        check("sparse==dense transcode on dense input", 60, |g| {
            let mut orig = g.f32_vec(1..60, 2.0);
            for x in orig.iter_mut() {
                if *x == 0.0 {
                    *x = 1.0; // the generator essentially never emits 0.0, but be safe
                }
            }
            let n = orig.len();
            for codec in [Codec::F32, Codec::F16, Codec::Q8, Codec::Q4, Codec::Q2] {
                let mut dense = orig.clone();
                let mut sparse = orig.clone();
                let e1 = codec.transcode(&mut dense, &one_slice(n));
                let e2 = codec.transcode_sparse(&mut sparse, &one_slice(n));
                assert_eq!(e1, e2, "{codec:?}");
                for (a, b) in dense.iter().zip(&sparse) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{codec:?}");
                }
            }
        });
    }

    #[test]
    fn q8_endpoints_land_on_grid() {
        // min encodes exactly (q = 0); max lands on the last grid point,
        // within one float rounding of itself.
        let mut v = vec![-1.0f32, 0.33, 1.0];
        Codec::Q8.transcode(&mut v, &one_slice(3));
        assert_eq!(v[0], -1.0);
        assert!((v[2] - 1.0).abs() < 1e-5, "{}", v[2]);
    }

    #[test]
    fn q8_quantizes_per_slice() {
        // Two slices with very different ranges must not share a scale:
        // the small-magnitude slice keeps fine resolution (a shared scale
        // of ~2000/255 would flatten ±0.001 to the same grid point).
        let mut v = vec![1000.0f32, -1000.0, 0.001, -0.001];
        let slices = vec![
            LeafSlice { leaf: 0, start: 0, end: 2 },
            LeafSlice { leaf: 1, start: 0, end: 2 },
        ];
        Codec::Q8.transcode(&mut v, &slices);
        assert!((v[0] - 1000.0).abs() < 0.01);
        assert!((v[2] - 0.001).abs() < 1e-5, "{}", v[2]);
        assert!((v[3] + 0.001).abs() < 1e-5, "{}", v[3]);
        assert!(v[2] > v[3], "fine structure lost to a shared scale");
    }

    #[test]
    fn transcode_error_matches_reported() {
        check("reported err² equals recomputed err²", 50, |g| {
            let orig = g.f32_vec(1..60, 2.0);
            for codec in [Codec::F16, Codec::Q8, Codec::Q4, Codec::Q2] {
                let mut v = orig.clone();
                let n = v.len();
                let err = codec.transcode(&mut v, &one_slice(n));
                let recomputed: f64 = orig
                    .iter()
                    .zip(&v)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum();
                assert_eq!(err, recomputed, "{:?}", codec);
            }
        });
    }

    #[test]
    fn prop_extract_transcode_fusion_is_bitwise() {
        use crate::comm::fragment::FragmentPlan;
        use crate::runtime::Tensors;
        check("fused extract+transcode == two-pass bitwise", 50, |g| {
            let a = g.f32_vec(1..40, 3.0);
            let b = g.f32_vec(1..40, 3.0);
            let t = Tensors::from_raw(vec![a, b]);
            let p = g.usize_in(1..6);
            let plan = FragmentPlan::for_tensors(&t, p);
            for codec in [Codec::F32, Codec::F16, Codec::Q8, Codec::Q4, Codec::Q2] {
                for f in 0..plan.n_fragments() {
                    let mut two_pass = plan.extract(&t, f);
                    let want_err = codec.transcode(&mut two_pass, plan.slices(f));
                    let mut fused = vec![f32::NAN; 5]; // dirty reused buffer
                    let got_err =
                        extract_transcode(codec, &plan, &t, f, &mut fused);
                    assert_eq!(got_err, want_err, "{codec:?} err");
                    assert_eq!(fused.len(), two_pass.len());
                    for (x, y) in fused.iter().zip(&two_pass) {
                        assert_eq!(x.to_bits(), y.to_bits(), "{codec:?}");
                    }
                }
            }
        });
    }

    #[test]
    fn prop_error_feedback_residual_drains_instead_of_accumulating() {
        // The invariant the `[stream] error_feedback` knob rests on:
        // with residual carry-over, the *cumulative* values shipped over
        // T rounds drift from the cumulative intended values by at most
        // one round's quantization error (the telescoping sum leaves
        // only the final residual), instead of T rounds' worth.
        check("EF drift telescopes to one round's quant error", 30, |g| {
            let n = g.usize_in(4..60);
            let x: Vec<f32> =
                (0..n).map(|_| g.f64_in(-1.0..1.0) as f32).collect();
            for codec in [Codec::Q8, Codec::Q4, Codec::Q2] {
                let levels = codec.quant_levels().unwrap() as f64;
                let mut residual = vec![0.0f32; n];
                let mut sent_sum = vec![0.0f64; n];
                let rounds = 25usize;
                for _ in 0..rounds {
                    let intended: Vec<f32> =
                        x.iter().zip(&residual).map(|(a, b)| a + b).collect();
                    let mut sent = intended.clone();
                    codec.transcode(&mut sent, &one_slice(n));
                    // One round's quant cell bounds the fresh residual —
                    // it never compounds across rounds.
                    let lo = intended.iter().cloned().fold(f64::INFINITY, |m, v| m.min(v as f64));
                    let hi = intended.iter().cloned().fold(f64::NEG_INFINITY, |m, v| m.max(v as f64));
                    let cell = (hi - lo) / levels;
                    for i in 0..n {
                        residual[i] = intended[i] - sent[i];
                        assert!(
                            (residual[i] as f64).abs() <= cell + 1e-6,
                            "{codec:?}: residual {} exceeds one quant cell {cell}",
                            residual[i]
                        );
                        sent_sum[i] += sent[i] as f64;
                    }
                }
                for i in 0..n {
                    let drift = rounds as f64 * x[i] as f64 - sent_sum[i];
                    assert!(
                        (drift - residual[i] as f64).abs() < 1e-3,
                        "{codec:?}: cumulative drift {drift} is not the final residual {}",
                        residual[i]
                    );
                }
            }
        });
    }

    #[test]
    fn f16_transcode_is_idempotent() {
        check("transcoding twice equals once", 50, |g| {
            let mut v = g.f32_vec(1..40, 3.0);
            let n = v.len();
            Codec::F16.transcode(&mut v, &one_slice(n));
            let once = v.clone();
            let err2 = Codec::F16.transcode(&mut v, &one_slice(n));
            assert_eq!(v, once);
            assert_eq!(err2, 0.0);
        });
    }
}
