//! Wire formats for outer-gradient fragments: dense vs sparse payloads.
//!
//! Historically every payload was billed dense — `codec.encoded_bytes(n)`
//! for the fragment's full element count — which is why the config layer
//! used to hard-reject any composition that produced sparsity the wire
//! could not represent (sign-pruning with a non-f32 codec, pruning on the
//! ring, pruning under the hierarchical topology). [`WireFormat`] is the
//! missing representation: a payload is either
//!
//! * **Dense** — every element ships, `codec.encoded_bytes(n, s)` bytes; or
//! * **Sparse** — a presence bitmap (1 bit per fragment element) plus the
//!   `nnz` non-zero values codec-encoded: `⌈n/8⌉ + codec.encoded_bytes(nnz, s)`.
//!
//! **Reconciliation contract:** for the `f32` codec a sparse payload over
//! the *whole* delta bills `4·nnz + ⌈n/8⌉` — exactly
//! [`crate::coordinator::prune::pruned_payload_bytes`], the formula the
//! pruning bench has asserted since it existed. The sparse format is the
//! per-fragment generalization of that number, not a new cost model
//! (property-pinned below).
//!
//! [`Support`] is the receiver-side view of the bitmap: which positions of
//! a fragment are non-zero. The topology layer unions supports to bill
//! aggregated hops as the density they actually ship — the ring's
//! reduce-scatter chunks re-densify as partial sums accumulate, and the
//! hierarchical leader hop ships the union of its group's supports.

use super::codec::Codec;

/// How one fragment payload is laid out on the wire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// All `n_elements` values ship, codec-encoded.
    Dense,
    /// A presence bitmap over the fragment plus `nnz` codec-encoded
    /// non-zero values.
    Sparse {
        /// Number of non-zero values on the wire (counted on the pruned
        /// payload *before* quantization — quantization never changes
        /// what positions ship, only their precision).
        nnz: usize,
    },
}

impl WireFormat {
    /// Billed bytes for a payload of `n_elements` over `n_slices`
    /// contiguous leaf slices.
    pub fn bytes(&self, codec: Codec, n_elements: usize, n_slices: usize) -> u64 {
        match *self {
            WireFormat::Dense => codec.encoded_bytes(n_elements, n_slices),
            WireFormat::Sparse { nnz } => {
                debug_assert!(nnz <= n_elements, "support exceeds payload");
                (n_elements as u64).div_ceil(8) + codec.encoded_bytes(nnz, n_slices)
            }
        }
    }
}

/// Billed bytes for a sparse payload: presence bitmap + codec-encoded
/// non-zeros. Shorthand for `WireFormat::Sparse { nnz }.bytes(..)`.
pub fn sparse_payload_bytes(
    codec: Codec,
    n_elements: usize,
    nnz: usize,
    n_slices: usize,
) -> u64 {
    WireFormat::Sparse { nnz }.bytes(codec, n_elements, n_slices)
}

/// A fragment payload's non-zero positions as a packed bitmap — the
/// receiver-side view of the sparse format's presence bits. Supports
/// cheap unioning (for aggregated-hop billing) and ranged counting (for
/// the ring's per-chunk bills).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Support {
    words: Vec<u64>,
    len: usize,
}

impl Support {
    /// Empty support over `len` positions.
    pub fn empty(len: usize) -> Support {
        Support { words: vec![0u64; len.div_ceil(64)], len }
    }

    /// Mark every non-zero position of `values`.
    pub fn from_values(values: &[f32]) -> Support {
        let mut s = Support::empty(values.len());
        for (i, &x) in values.iter().enumerate() {
            if x != 0.0 {
                s.words[i / 64] |= 1u64 << (i % 64);
            }
        }
        s
    }

    /// Number of positions covered (the fragment's element count).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of non-zero positions.
    pub fn nnz(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Union `other` into `self` (both must cover the same positions).
    pub fn union_with(&mut self, other: &Support) {
        assert_eq!(self.len, other.len, "support length mismatch");
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Non-zero count within positions `[start, end)` — the ring bills
    /// each hop's chunk by the density of the partial sum it carries.
    pub fn nnz_in_range(&self, start: usize, end: usize) -> usize {
        assert!(start <= end && end <= self.len, "range out of bounds");
        let mut count = 0usize;
        let mut i = start;
        while i < end {
            let word = i / 64;
            let lo_bit = i % 64;
            let hi = ((word + 1) * 64).min(end);
            let n_bits = hi - i;
            let mask = if n_bits == 64 {
                u64::MAX
            } else {
                ((1u64 << n_bits) - 1) << lo_bit
            };
            count += (self.words[word] & mask).count_ones() as usize;
            i = hi;
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::prune;
    use crate::util::prop::check;

    #[test]
    fn dense_bytes_match_codec() {
        for codec in [Codec::F32, Codec::F16, Codec::Q8, Codec::Q4, Codec::Q2] {
            assert_eq!(
                WireFormat::Dense.bytes(codec, 100, 3),
                codec.encoded_bytes(100, 3)
            );
        }
    }

    #[test]
    fn prop_sparse_f32_reconciles_with_pruned_payload_bytes() {
        // Satellite: the sparse wire format at f32 IS the pruning bench's
        // historical closed form — bitmap + 4 bytes per survivor.
        check("sparse f32 == pruned_payload_bytes", 200, |g| {
            let total = g.usize_in(1..5000);
            let zeroed = g.usize_in(0..total + 1);
            let nnz = total - zeroed;
            assert_eq!(
                sparse_payload_bytes(Codec::F32, total, nnz, 1),
                prune::pruned_payload_bytes(total, zeroed)
            );
            // Slice count is irrelevant at f32 (no per-slice sidecar).
            assert_eq!(
                sparse_payload_bytes(Codec::F32, total, nnz, 7),
                prune::pruned_payload_bytes(total, zeroed)
            );
        });
    }

    #[test]
    fn sparse_bytes_closed_forms() {
        // 100 elements, 40 survivors, 2 slices.
        assert_eq!(sparse_payload_bytes(Codec::F32, 100, 40, 2), 13 + 160);
        assert_eq!(sparse_payload_bytes(Codec::F16, 100, 40, 2), 13 + 80);
        assert_eq!(sparse_payload_bytes(Codec::Q8, 100, 40, 2), 13 + 40 + 16);
        assert_eq!(sparse_payload_bytes(Codec::Q4, 100, 40, 2), 13 + 20 + 16);
        assert_eq!(sparse_payload_bytes(Codec::Q2, 100, 40, 2), 13 + 10 + 16);
    }

    #[test]
    fn prop_support_counts_and_ranges() {
        check("support nnz and ranged counts agree with the values", 100, |g| {
            let mut v = g.f32_vec(1..300, 2.0);
            let stride = g.usize_in(1..6);
            for (i, x) in v.iter_mut().enumerate() {
                if i % stride == 0 {
                    *x = 0.0;
                }
            }
            let s = Support::from_values(&v);
            let want = v.iter().filter(|&&x| x != 0.0).count();
            assert_eq!(s.nnz(), want);
            assert_eq!(s.nnz_in_range(0, v.len()), want);
            // A split partitions the count.
            let mid = g.usize_in(0..v.len() + 1);
            assert_eq!(
                s.nnz_in_range(0, mid) + s.nnz_in_range(mid, v.len()),
                want
            );
        });
    }

    #[test]
    fn prop_union_is_bitwise_or() {
        check("union support == elementwise either-nonzero", 100, |g| {
            let n = g.usize_in(1..200);
            let mk = |g: &mut crate::util::prop::Gen, stride: usize| -> Vec<f32> {
                (0..n)
                    .map(|i| if i % stride == 0 { 0.0 } else { g.f64_in(0.1..1.0) as f32 })
                    .collect()
            };
            let sa = g.usize_in(2..5);
            let sb = g.usize_in(2..5);
            let a = mk(g, sa);
            let b = mk(g, sb);
            let mut u = Support::from_values(&a);
            u.union_with(&Support::from_values(&b));
            let want = (0..n).filter(|&i| a[i] != 0.0 || b[i] != 0.0).count();
            assert_eq!(u.nnz(), want);
        });
    }

    #[test]
    fn ranged_count_crosses_word_boundaries() {
        let mut v = vec![0.0f32; 130];
        for i in [0usize, 63, 64, 65, 127, 128, 129] {
            v[i] = 1.0;
        }
        let s = Support::from_values(&v);
        assert_eq!(s.nnz(), 7);
        assert_eq!(s.nnz_in_range(0, 64), 2);
        assert_eq!(s.nnz_in_range(63, 66), 3);
        assert_eq!(s.nnz_in_range(64, 130), 5);
        assert_eq!(s.nnz_in_range(130, 130), 0);
    }
}
