//! Simulated inter-island network fabric.
//!
//! The paper's workers live on "islands of devices that are poorly
//! connected"; all results are perplexity-vs-steps plus communication
//! accounting. `SimNet` reproduces both: every transfer is billed in
//! bytes and simulated seconds (latency + size/bandwidth), and drop
//! injection models reboots/packet loss (paper Fig 8). The simulated
//! clock lets Table 2's "Time" column be *measured*: compute time from
//! per-step costs, communication time from the fabric — overlapping
//! workers take the max, as islands run in parallel.
//!
//! **Determinism contract:** a drop decision is a *pure function* of
//! `(fabric seed, round, worker_id, fragment, hop, delay generation)` —
//! never of how many messages were sent before it. Uploads may therefore
//! land in any order (sequential loop, parallel islands, the delayed
//! async loop) and the communication outcome is identical. This replaced
//! a shared sequentially-consumed RNG and intentionally changed seeded
//! drop patterns once. Generation 0 of hop 0 of fragment 0 keys exactly
//! as the pre-streaming fabric did, so default star runs reproduce
//! historical traces bitwise.
//!
//! The streaming and topology extensions live alongside: [`fragment`]
//! partitions the parameter space for partial synchronization, [`codec`]
//! compresses outer-gradient payloads, [`topology`] generalizes the star
//! reduction into pluggable sync schedules (ring / gossip /
//! hierarchical), and [`CommStats::per_round`] records one billing row
//! per communication barrier (the golden-trace tests assert against
//! these rows).
//!
//! Transports themselves are pluggable behind the [`fabric::Fabric`]
//! trait: `SimNet` is the golden in-process backend, and [`tcp`] runs
//! each island as a real OS process over TCP ([`frame`] is its wire
//! framing), differential-tested bitwise against the simulator.

pub mod codec;
pub mod fabric;
pub mod fragment;
pub mod frame;
pub mod tcp;
pub mod topology;
pub mod wire;

pub use fabric::{Fabric, PhaseOutcome};
pub use tcp::{serve_worker, TcpFabric, TcpFabricSetup, WorkerOpts};

use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// One message on the fabric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Direction {
    /// Worker → coordinator (outer gradient).
    Up,
    /// Coordinator → worker (fresh global parameters).
    Down,
}

/// Billing for one communication barrier (one coordinator round).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundComm {
    pub messages: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub dropped: u64,
    /// Barrier seconds charged to this round; 0.0 when the round's
    /// transfer was deferred into the next compute phase (overlapped
    /// streaming schedule).
    pub barrier_s: f64,
}

/// Billing record of everything that crossed the fabric.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CommStats {
    pub messages: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub dropped: u64,
    /// Simulated seconds spent in communication barriers (per round, the
    /// slowest island's transfer time — islands transfer in parallel).
    pub sim_comm_seconds: f64,
    /// One billing row per closed round, in round order.
    pub per_round: Vec<RoundComm>,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_up + self.bytes_down
    }
}

/// Bandwidth/latency/drop model shared by all islands.
///
/// ```
/// use diloco::comm::{Direction, SimNet};
/// use diloco::util::rng::Rng;
///
/// // 1 MB/s, 10 ms latency, no drops.
/// let mut net = SimNet::new(1e6, 0.01, 0.0, Rng::new(0));
/// assert!(net.try_send(1_000_000, Direction::Up, 0, 0)); // worker 0, round 0
/// assert!(net.try_send(500_000, Direction::Up, 0, 1));   // worker 1: own lane
/// net.end_round();
/// // Lanes overlap at the barrier: the round costs the slowest lane.
/// assert!((net.stats().sim_comm_seconds - 1.01).abs() < 1e-9);
/// assert_eq!(net.stats().bytes_up, 1_500_000);
/// ```
pub struct SimNet {
    bandwidth_bps: f64,
    latency_s: f64,
    drop_prob: f64,
    /// Base stream for keyed drop decisions; never advanced — per-message
    /// decisions derive fresh children from `(round, worker, fragment)`.
    drop_rng: Rng,
    stats: CommStats,
    /// Per-lane transfer seconds for the open round. A lane is one
    /// worker's link in one direction: messages on the same lane
    /// serialize (sum — a worker's fragments share its WAN uplink),
    /// distinct lanes overlap (max at the barrier, islands transfer in
    /// parallel). Reset by `end_round*`.
    round_lanes: BTreeMap<(u8, u64), f64>,
    /// Distinct lane per legacy `send_reliable` call (each such message
    /// modeled as its own parallel transfer, as before fragments).
    anon_lane: u64,
    /// Billing accumulated since the last `end_round*` call.
    cur_round: RoundComm,
}

/// Lane tags: worker uplink, worker downlink, anonymous one-shot.
const LANE_UP: u8 = 0;
const LANE_DOWN: u8 = 1;
const LANE_ANON: u8 = 2;

impl SimNet {
    pub fn new(bandwidth_bps: f64, latency_s: f64, drop_prob: f64, rng: Rng) -> SimNet {
        assert!(bandwidth_bps > 0.0, "bandwidth must be positive");
        assert!((0.0..=1.0).contains(&drop_prob), "drop_prob in [0,1]");
        SimNet {
            bandwidth_bps,
            latency_s,
            drop_prob,
            drop_rng: rng,
            stats: CommStats::default(),
            round_lanes: BTreeMap::new(),
            anon_lane: 0,
            cur_round: RoundComm::default(),
        }
    }

    /// Charge a transfer to a lane (same lane ⇒ serialized).
    fn add_transfer(&mut self, lane: (u8, u64), bytes: u64) {
        let dt = self.transfer_time(bytes);
        *self.round_lanes.entry(lane).or_insert(0.0) += dt;
    }

    /// Transfer time for a payload (one-way).
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Keyed drop decision — pure in `(fabric seed, round, worker)`, so
    /// the outcome is independent of message order. Equivalent to
    /// [`Self::drops_fragment`] for fragment 0.
    pub fn drops(&self, round: usize, worker: usize) -> bool {
        if self.drop_prob <= 0.0 {
            return false;
        }
        self.drop_rng
            .child(round as u64)
            .child(worker as u64)
            .coin(self.drop_prob)
    }

    /// Fragment-keyed drop decision — pure in
    /// `(fabric seed, round, worker, fragment)`. Fragment 0 uses the
    /// legacy two-level key so single-fragment runs reproduce
    /// pre-streaming drop patterns bitwise; higher fragments derive one
    /// further child stream.
    pub fn drops_fragment(&self, round: usize, worker: usize, fragment: usize) -> bool {
        if self.drop_prob <= 0.0 {
            return false;
        }
        if fragment == 0 {
            return self.drops(round, worker);
        }
        self.drop_rng
            .child(round as u64)
            .child(worker as u64)
            .child(fragment as u64)
            .coin(self.drop_prob)
    }

    /// Hop-keyed drop decision — pure in
    /// `(fabric seed, round, worker, fragment, hop)`. Hop 0 is a
    /// worker's first-hop upload and uses the legacy
    /// [`Self::drops_fragment`] key (so star traces are unchanged);
    /// higher hops — e.g. a hierarchical group leader's aggregate upload
    /// ([`topology::HOP_LEADER_UP`]) — derive one further child stream.
    pub fn drops_hop(
        &self,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
    ) -> bool {
        if hop == 0 {
            return self.drops_fragment(round, worker, fragment);
        }
        if self.drop_prob <= 0.0 {
            return false;
        }
        self.drop_rng
            .child(round as u64)
            .child(worker as u64)
            .child(fragment as u64)
            .child(hop as u64)
            .coin(self.drop_prob)
    }

    /// Delay-generation-keyed drop decision — pure in
    /// `(fabric seed, round, worker, fragment, hop, gen)`, where `gen`
    /// is the delay generation of the message (the async scheduling
    /// layer's `sync.delay_rounds`). Generation 0 is the synchronous
    /// fabric and uses the legacy [`Self::drops_hop`] key exactly, so
    /// `delay_rounds = 0` runs reproduce every historical drop pattern
    /// bitwise; higher generations derive one further child stream (a
    /// delayed upload is a different message on the wire, not a replay
    /// of the synchronous one).
    pub fn drops_gen(
        &self,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
        gen: usize,
    ) -> bool {
        if gen == 0 {
            return self.drops_hop(round, worker, fragment, hop);
        }
        if self.drop_prob <= 0.0 {
            return false;
        }
        self.drop_rng
            .child(round as u64)
            .child(worker as u64)
            .child(fragment as u64)
            .child(hop as u64)
            .child(gen as u64)
            .coin(self.drop_prob)
    }

    /// Attempt an upload of `bytes` from `worker` in `round`; returns
    /// `false` if the message is dropped (worker reboot / packet loss —
    /// Fig 8 semantics: the coordinator simply does not receive this
    /// outer gradient). The drop decision is keyed, never sequential.
    /// Monolithic payloads are fragment 0 of the streaming fabric.
    pub fn try_send(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
    ) -> bool {
        self.try_send_fragment(bytes, dir, round, worker, 0)
    }

    /// As [`Self::try_send`], for one fragment of a streaming partial
    /// sync. Each fragment is its own message with its own keyed drop
    /// decision, so a worker can lose one fragment and land the rest.
    /// Equivalent to [`Self::try_send_hop`] with hop 0.
    pub fn try_send_fragment(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
    ) -> bool {
        self.try_send_hop(bytes, dir, round, worker, fragment, 0)
    }

    /// As [`Self::try_send_fragment`], for one hop of a multi-hop sync
    /// topology ([`topology`]): the drop decision is keyed on the full
    /// `(fabric seed, round, worker, fragment, hop)` tuple, and the
    /// bytes bill on `worker`'s lane in `dir` exactly like any other
    /// message on that link.
    pub fn try_send_hop(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
    ) -> bool {
        self.try_send_gen(bytes, dir, round, worker, fragment, hop, 0)
    }

    /// As [`Self::try_send_hop`], for one message of a delayed sync
    /// generation ([`Self::drops_gen`]): generation 0 is exactly the
    /// synchronous hop fabric, higher generations key their own drop
    /// stream. Billing is identical — the payload rides `worker`'s lane
    /// in `dir` like any other message on that link.
    #[allow(clippy::too_many_arguments)]
    pub fn try_send_gen(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
        fragment: usize,
        hop: usize,
        gen: usize,
    ) -> bool {
        self.stats.messages += 1;
        self.cur_round.messages += 1;
        if self.drops_gen(round, worker, fragment, hop, gen) {
            self.stats.dropped += 1;
            self.cur_round.dropped += 1;
            return false;
        }
        let lane_tag = match dir {
            Direction::Up => {
                self.stats.bytes_up += bytes;
                self.cur_round.bytes_up += bytes;
                LANE_UP
            }
            Direction::Down => {
                self.stats.bytes_down += bytes;
                self.cur_round.bytes_down += bytes;
                LANE_DOWN
            }
        };
        // All of one worker's fragments share its link: they serialize
        // within the round, while different workers' lanes overlap.
        self.add_transfer((lane_tag, worker as u64), bytes);
        true
    }

    /// Reliable transfer — billed, never dropped. Used for the
    /// coordinator → worker re-dispatch: the paper's drop injection (Fig 8)
    /// models *outer gradients* failing to arrive, not the broadcast.
    /// Each call is its own parallel lane (pre-fragment semantics); use
    /// [`Self::send_reliable_to`] when several messages share one
    /// worker's link.
    pub fn send_reliable(&mut self, bytes: u64, dir: Direction) {
        self.anon_lane += 1;
        let lane = (LANE_ANON, self.anon_lane);
        self.bill_reliable(bytes, dir, lane);
    }

    /// Reliable transfer on `worker`'s link: fragments broadcast to the
    /// same worker in one round serialize, like its uploads.
    pub fn send_reliable_to(&mut self, bytes: u64, dir: Direction, worker: usize) {
        let tag = match dir {
            Direction::Up => LANE_UP,
            Direction::Down => LANE_DOWN,
        };
        self.bill_reliable(bytes, dir, (tag, worker as u64));
    }

    fn bill_reliable(&mut self, bytes: u64, dir: Direction, lane: (u8, u64)) {
        self.stats.messages += 1;
        self.cur_round.messages += 1;
        match dir {
            Direction::Up => {
                self.stats.bytes_up += bytes;
                self.cur_round.bytes_up += bytes;
            }
            Direction::Down => {
                self.stats.bytes_down += bytes;
                self.cur_round.bytes_down += bytes;
            }
        }
        self.add_transfer(lane, bytes);
    }

    /// Slowest lane of the open round (lanes transfer in parallel,
    /// messages within a lane serialize); clears the per-round lanes.
    fn round_barrier(&mut self) -> f64 {
        let max = self.round_lanes.values().cloned().fold(0.0f64, f64::max);
        self.round_lanes.clear();
        self.anon_lane = 0;
        max
    }

    /// Close a communication barrier: lanes transfer concurrently, so
    /// the round's wall-clock cost is the slowest lane.
    pub fn end_round(&mut self) {
        let barrier = self.round_barrier();
        self.stats.sim_comm_seconds += barrier;
        self.cur_round.barrier_s = barrier;
        let row = std::mem::take(&mut self.cur_round);
        self.stats.per_round.push(row);
    }

    /// Close a round whose transfer overlaps the *next* compute phase
    /// (streaming `overlapped` schedule): the round's billing row is
    /// recorded with zero barrier cost and the slowest transfer time is
    /// returned for the caller to charge against upcoming compute.
    pub fn end_round_deferred(&mut self) -> f64 {
        let barrier = self.round_barrier();
        self.cur_round.barrier_s = 0.0;
        let row = std::mem::take(&mut self.cur_round);
        self.stats.per_round.push(row);
        barrier
    }

    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    pub fn set_drop_prob(&mut self, p: f64) {
        assert!((0.0..=1.0).contains(&p));
        self.drop_prob = p;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(drop: f64) -> SimNet {
        SimNet::new(1e6, 0.01, drop, Rng::new(0))
    }

    #[test]
    fn transfer_time_is_latency_plus_serialization() {
        let n = net(0.0);
        assert!((n.transfer_time(1_000_000) - 1.01).abs() < 1e-9);
        assert!((n.transfer_time(0) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn billing_accumulates_by_direction() {
        let mut n = net(0.0);
        assert!(n.try_send(100, Direction::Up, 0, 0));
        assert!(n.try_send(300, Direction::Down, 0, 1));
        assert_eq!(n.stats().bytes_up, 100);
        assert_eq!(n.stats().bytes_down, 300);
        assert_eq!(n.stats().total_bytes(), 400);
        assert_eq!(n.stats().messages, 2);
    }

    #[test]
    fn round_cost_is_max_not_sum() {
        let mut n = net(0.0);
        n.try_send(1_000_000, Direction::Up, 0, 0); // 1.01 s
        n.try_send(500_000, Direction::Up, 0, 1); // 0.51 s
        n.end_round();
        assert!((n.stats().sim_comm_seconds - 1.01).abs() < 1e-9);
    }

    #[test]
    fn empty_round_costs_nothing() {
        let mut n = net(0.0);
        n.end_round();
        assert_eq!(n.stats().sim_comm_seconds, 0.0);
    }

    #[test]
    fn drop_rate_matches_probability() {
        let mut n = net(0.3);
        let mut dropped = 0;
        for round in 0..1000 {
            for worker in 0..10 {
                if !n.try_send(10, Direction::Up, round, worker) {
                    dropped += 1;
                }
            }
        }
        let rate = dropped as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.02, "drop rate {rate}");
        assert_eq!(n.stats().dropped, dropped);
    }

    #[test]
    fn dropped_messages_are_not_billed() {
        let mut n = net(1.0);
        assert!(!n.try_send(100, Direction::Up, 0, 0));
        assert_eq!(n.stats().bytes_up, 0);
        n.end_round();
        assert_eq!(n.stats().sim_comm_seconds, 0.0);
    }

    #[test]
    fn keyed_drops_are_order_independent() {
        // The same (seed, round, worker) keys must give the same per-key
        // outcome whatever order uploads land in — the contract that lets
        // parallel islands share one fabric.
        let keys: Vec<(usize, usize)> =
            (0..16).flat_map(|r| (0..8).map(move |w| (r, w))).collect();
        let mut reversed = keys.clone();
        reversed.reverse();
        let mut shuffled = keys.clone();
        Rng::new(99).shuffle(&mut shuffled);

        let outcomes = |order: &[(usize, usize)]| {
            let mut n = net(0.5);
            let mut out: Vec<((usize, usize), bool)> = order
                .iter()
                .map(|&(r, w)| ((r, w), n.try_send(10, Direction::Up, r, w)))
                .collect();
            out.sort();
            (out, n.stats().dropped)
        };
        let (a, da) = outcomes(&keys);
        let (b, db) = outcomes(&reversed);
        let (c, dc) = outcomes(&shuffled);
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(da, db);
        assert_eq!(da, dc);
        // And the pure predicate agrees with what try_send did.
        let n = net(0.5);
        for ((r, w), sent) in &a {
            assert_eq!(n.drops(*r, *w), !sent);
        }
        // Sanity: a 50% fabric over 128 keys both drops and delivers.
        assert!(da > 0 && (da as usize) < keys.len());
    }

    #[test]
    fn fragment_drops_are_order_independent() {
        // Extends the PR-1 contract to the streaming fabric: a fragment
        // upload's outcome is a pure function of (seed, round, worker,
        // fragment), whatever order fragments land in.
        let keys: Vec<(usize, usize, usize)> = (0..6)
            .flat_map(|r| (0..4).flat_map(move |w| (0..3).map(move |f| (r, w, f))))
            .collect();
        let mut reversed = keys.clone();
        reversed.reverse();
        let mut shuffled = keys.clone();
        Rng::new(4242).shuffle(&mut shuffled);

        let outcomes = |order: &[(usize, usize, usize)]| {
            let mut n = net(0.5);
            let mut out: Vec<((usize, usize, usize), bool)> = order
                .iter()
                .map(|&(r, w, f)| {
                    ((r, w, f), n.try_send_fragment(10, Direction::Up, r, w, f))
                })
                .collect();
            out.sort();
            (out, n.stats().dropped)
        };
        let (a, da) = outcomes(&keys);
        let (b, db) = outcomes(&reversed);
        let (c, dc) = outcomes(&shuffled);
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(da, db);
        assert_eq!(da, dc);
        // The pure predicate agrees with what try_send_fragment did.
        let n = net(0.5);
        for ((r, w, f), sent) in &a {
            assert_eq!(n.drops_fragment(*r, *w, *f), !sent);
        }
        // Sanity: a 50% fabric over 72 keys both drops and delivers.
        assert!(da > 0 && (da as usize) < keys.len());
    }

    #[test]
    fn fragment_zero_keys_like_legacy_sends() {
        // The pre-streaming fabric keyed drops by (round, worker) only.
        // Fragment 0 must reproduce those decisions bitwise so the
        // default single-fragment configuration stays on the golden
        // trace.
        let n = net(0.5);
        for r in 0..32 {
            for w in 0..8 {
                assert_eq!(n.drops_fragment(r, w, 0), n.drops(r, w));
            }
        }
        // Higher fragments must be a *different* keyed stream, not a
        // copy of fragment 0 (astronomically unlikely to tie over 256
        // keys at p = 0.5 unless the key ignores the fragment).
        let differs = (0..32).any(|r| {
            (0..8).any(|w| {
                n.drops_fragment(r, w, 1) != n.drops_fragment(r, w, 0)
                    || n.drops_fragment(r, w, 2) != n.drops_fragment(r, w, 0)
            })
        });
        assert!(differs, "fragment index is not part of the drop key");
    }

    #[test]
    fn same_worker_fragments_serialize_other_workers_overlap() {
        // Splitting a worker's payload into fragments must NOT fake a
        // barrier speedup: its fragments share one uplink and serialize,
        // while different workers still transfer in parallel.
        let mut n = net(0.0);
        n.try_send_fragment(1_000_000, Direction::Up, 0, 0, 0); // 1.01 s
        n.try_send_fragment(1_000_000, Direction::Up, 0, 0, 1); // same link
        n.try_send_fragment(1_000_000, Direction::Up, 0, 1, 0); // parallel
        n.end_round();
        assert!((n.stats().sim_comm_seconds - 2.02).abs() < 1e-9);
        // Downlink lanes behave the same when addressed per worker...
        let mut d = net(0.0);
        d.send_reliable_to(1_000_000, Direction::Down, 3);
        d.send_reliable_to(1_000_000, Direction::Down, 3);
        d.end_round();
        assert!((d.stats().sim_comm_seconds - 2.02).abs() < 1e-9);
        // ...while anonymous reliable sends keep one-lane-per-message
        // semantics (pre-fragment behavior for the DP baselines).
        let mut a = net(0.0);
        a.send_reliable(1_000_000, Direction::Down);
        a.send_reliable(1_000_000, Direction::Down);
        a.end_round();
        assert!((a.stats().sim_comm_seconds - 1.01).abs() < 1e-9);
        // Up and down lanes of the same worker also overlap (full duplex).
        let mut fd = net(0.0);
        fd.try_send_fragment(1_000_000, Direction::Up, 0, 0, 0);
        fd.send_reliable_to(1_000_000, Direction::Down, 0);
        fd.end_round();
        assert!((fd.stats().sim_comm_seconds - 1.01).abs() < 1e-9);
    }

    #[test]
    fn per_round_billing_rows() {
        let mut n = net(0.0);
        n.try_send_fragment(100, Direction::Up, 0, 0, 0);
        n.try_send_fragment(50, Direction::Up, 0, 0, 1);
        n.send_reliable(200, Direction::Down);
        n.end_round();
        n.end_round(); // empty round still records a row
        let rows = &n.stats().per_round;
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].messages, 3);
        assert_eq!(rows[0].bytes_up, 150);
        assert_eq!(rows[0].bytes_down, 200);
        assert_eq!(rows[0].dropped, 0);
        assert!(rows[0].barrier_s > 0.0);
        assert_eq!(rows[1], RoundComm::default());
        // Rows sum to the cumulative stats.
        assert_eq!(
            rows.iter().map(|r| r.bytes_up + r.bytes_down).sum::<u64>(),
            n.stats().total_bytes()
        );
    }

    #[test]
    fn deferred_round_returns_barrier_without_billing_it() {
        let mut n = net(0.0);
        n.try_send(1_000_000, Direction::Up, 0, 0); // 1.01 s
        let carried = n.end_round_deferred();
        assert!((carried - 1.01).abs() < 1e-9);
        assert_eq!(n.stats().sim_comm_seconds, 0.0);
        assert_eq!(n.stats().per_round.len(), 1);
        assert_eq!(n.stats().per_round[0].barrier_s, 0.0);
        assert_eq!(n.stats().per_round[0].bytes_up, 1_000_000);
        // A later blocking round bills normally.
        n.try_send(500_000, Direction::Up, 1, 0);
        n.end_round();
        assert!((n.stats().sim_comm_seconds - 0.51).abs() < 1e-9);
    }

    #[test]
    fn hop_zero_keys_like_fragment_sends() {
        // Hop 0 is a worker's first-hop upload and must reproduce the
        // fragment-keyed (and, at fragment 0, the legacy) drop pattern
        // bitwise; higher hops are distinct keyed streams.
        let n = net(0.5);
        for r in 0..16 {
            for w in 0..6 {
                for f in 0..3 {
                    assert_eq!(n.drops_hop(r, w, f, 0), n.drops_fragment(r, w, f));
                }
            }
        }
        let differs = (0..16).any(|r| {
            (0..6).any(|w| {
                n.drops_hop(r, w, 0, 1) != n.drops_hop(r, w, 0, 0)
                    || n.drops_hop(r, w, 0, 2) != n.drops_hop(r, w, 0, 1)
            })
        });
        assert!(differs, "hop index is not part of the drop key");
        // The pure predicate agrees with what try_send_hop bills.
        let mut m = net(0.5);
        for r in 0..8 {
            for w in 0..4 {
                let sent = m.try_send_hop(10, Direction::Up, r, w, 0, 1);
                assert_eq!(sent, !n.drops_hop(r, w, 0, 1));
            }
        }
    }

    #[test]
    fn gen_zero_keys_like_hop_sends() {
        // Generation 0 is the synchronous fabric: its drop decisions must
        // reproduce the hop-keyed (and transitively fragment- and
        // legacy-keyed) pattern bitwise, so `delay_rounds = 0` stays on
        // the golden trace. Higher generations are distinct streams.
        let n = net(0.5);
        for r in 0..16 {
            for w in 0..6 {
                for f in 0..2 {
                    for h in 0..2 {
                        assert_eq!(n.drops_gen(r, w, f, h, 0), n.drops_hop(r, w, f, h));
                    }
                }
            }
        }
        let differs = (0..16).any(|r| {
            (0..6).any(|w| {
                n.drops_gen(r, w, 0, 0, 1) != n.drops_gen(r, w, 0, 0, 0)
                    || n.drops_gen(r, w, 0, 0, 2) != n.drops_gen(r, w, 0, 0, 1)
            })
        });
        assert!(differs, "delay generation is not part of the drop key");
        // The pure predicate agrees with what try_send_gen bills.
        let mut m = net(0.5);
        for r in 0..8 {
            for w in 0..4 {
                let sent = m.try_send_gen(10, Direction::Up, r, w, 0, 0, 2);
                assert_eq!(sent, !n.drops_gen(r, w, 0, 0, 2));
            }
        }
    }

    #[test]
    fn keyed_drops_vary_across_keys_and_seeds() {
        let n = net(0.5);
        let per_key: Vec<bool> = (0..64).map(|w| n.drops(0, w)).collect();
        assert!(per_key.iter().any(|&d| d) && per_key.iter().any(|&d| !d));
        let other = SimNet::new(1e6, 0.01, 0.5, Rng::new(12345));
        let differs = (0..64).any(|w| n.drops(0, w) != other.drops(0, w));
        assert!(differs, "drop pattern must depend on the fabric seed");
    }
}
