//! Simulated inter-island network fabric.
//!
//! The paper's workers live on "islands of devices that are poorly
//! connected"; all results are perplexity-vs-steps plus communication
//! accounting. `SimNet` reproduces both: every transfer is billed in
//! bytes and simulated seconds (latency + size/bandwidth), and drop
//! injection models reboots/packet loss (paper Fig 8). The simulated
//! clock lets Table 2's "Time" column be *measured*: compute time from
//! per-step costs, communication time from the fabric — overlapping
//! workers take the max, as islands run in parallel.
//!
//! **Determinism contract:** a drop decision is a *pure function* of
//! `(fabric seed, round, worker_id)` — never of how many messages were
//! sent before it. Uploads may therefore land in any order (sequential
//! loop, parallel islands, future async variants) and the communication
//! outcome is identical. This replaced a shared sequentially-consumed
//! RNG and intentionally changed seeded drop patterns once.

use crate::util::rng::Rng;

/// One message on the fabric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Direction {
    /// Worker → coordinator (outer gradient).
    Up,
    /// Coordinator → worker (fresh global parameters).
    Down,
}

/// Billing record of everything that crossed the fabric.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub messages: u64,
    pub bytes_up: u64,
    pub bytes_down: u64,
    pub dropped: u64,
    /// Simulated seconds spent in communication barriers (per round, the
    /// slowest island's transfer time — islands transfer in parallel).
    pub sim_comm_seconds: f64,
}

impl CommStats {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_up + self.bytes_down
    }
}

/// Bandwidth/latency/drop model shared by all islands.
pub struct SimNet {
    bandwidth_bps: f64,
    latency_s: f64,
    drop_prob: f64,
    /// Base stream for keyed drop decisions; never advanced — per-message
    /// decisions derive fresh children from `(round, worker)`.
    drop_rng: Rng,
    stats: CommStats,
    /// Per-round transfer times, reset by `end_round`.
    round_transfers: Vec<f64>,
}

impl SimNet {
    pub fn new(bandwidth_bps: f64, latency_s: f64, drop_prob: f64, rng: Rng) -> SimNet {
        assert!(bandwidth_bps > 0.0, "bandwidth must be positive");
        assert!((0.0..=1.0).contains(&drop_prob), "drop_prob in [0,1]");
        SimNet {
            bandwidth_bps,
            latency_s,
            drop_prob,
            drop_rng: rng,
            stats: CommStats::default(),
            round_transfers: Vec::new(),
        }
    }

    /// Transfer time for a payload (one-way).
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Keyed drop decision — pure in `(fabric seed, round, worker)`, so
    /// the outcome is independent of message order.
    pub fn drops(&self, round: usize, worker: usize) -> bool {
        if self.drop_prob <= 0.0 {
            return false;
        }
        self.drop_rng
            .child(round as u64)
            .child(worker as u64)
            .coin(self.drop_prob)
    }

    /// Attempt an upload of `bytes` from `worker` in `round`; returns
    /// `false` if the message is dropped (worker reboot / packet loss —
    /// Fig 8 semantics: the coordinator simply does not receive this
    /// outer gradient). The drop decision is keyed, never sequential.
    pub fn try_send(
        &mut self,
        bytes: u64,
        dir: Direction,
        round: usize,
        worker: usize,
    ) -> bool {
        self.stats.messages += 1;
        if self.drops(round, worker) {
            self.stats.dropped += 1;
            return false;
        }
        match dir {
            Direction::Up => self.stats.bytes_up += bytes,
            Direction::Down => self.stats.bytes_down += bytes,
        }
        self.round_transfers.push(self.transfer_time(bytes));
        true
    }

    /// Reliable transfer — billed, never dropped. Used for the
    /// coordinator → worker re-dispatch: the paper's drop injection (Fig 8)
    /// models *outer gradients* failing to arrive, not the broadcast.
    pub fn send_reliable(&mut self, bytes: u64, dir: Direction) {
        self.stats.messages += 1;
        match dir {
            Direction::Up => self.stats.bytes_up += bytes,
            Direction::Down => self.stats.bytes_down += bytes,
        }
        self.round_transfers.push(self.transfer_time(bytes));
    }

    /// Close a communication barrier: islands transfer concurrently, so
    /// the round's wall-clock cost is the slowest single transfer.
    pub fn end_round(&mut self) {
        if let Some(max) = self
            .round_transfers
            .iter()
            .cloned()
            .fold(None::<f64>, |acc, x| Some(acc.map_or(x, |a| a.max(x))))
        {
            self.stats.sim_comm_seconds += max;
        }
        self.round_transfers.clear();
    }

    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    pub fn set_drop_prob(&mut self, p: f64) {
        assert!((0.0..=1.0).contains(&p));
        self.drop_prob = p;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(drop: f64) -> SimNet {
        SimNet::new(1e6, 0.01, drop, Rng::new(0))
    }

    #[test]
    fn transfer_time_is_latency_plus_serialization() {
        let n = net(0.0);
        assert!((n.transfer_time(1_000_000) - 1.01).abs() < 1e-9);
        assert!((n.transfer_time(0) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn billing_accumulates_by_direction() {
        let mut n = net(0.0);
        assert!(n.try_send(100, Direction::Up, 0, 0));
        assert!(n.try_send(300, Direction::Down, 0, 1));
        assert_eq!(n.stats().bytes_up, 100);
        assert_eq!(n.stats().bytes_down, 300);
        assert_eq!(n.stats().total_bytes(), 400);
        assert_eq!(n.stats().messages, 2);
    }

    #[test]
    fn round_cost_is_max_not_sum() {
        let mut n = net(0.0);
        n.try_send(1_000_000, Direction::Up, 0, 0); // 1.01 s
        n.try_send(500_000, Direction::Up, 0, 1); // 0.51 s
        n.end_round();
        assert!((n.stats().sim_comm_seconds - 1.01).abs() < 1e-9);
    }

    #[test]
    fn empty_round_costs_nothing() {
        let mut n = net(0.0);
        n.end_round();
        assert_eq!(n.stats().sim_comm_seconds, 0.0);
    }

    #[test]
    fn drop_rate_matches_probability() {
        let mut n = net(0.3);
        let mut dropped = 0;
        for round in 0..1000 {
            for worker in 0..10 {
                if !n.try_send(10, Direction::Up, round, worker) {
                    dropped += 1;
                }
            }
        }
        let rate = dropped as f64 / 10_000.0;
        assert!((rate - 0.3).abs() < 0.02, "drop rate {rate}");
        assert_eq!(n.stats().dropped, dropped);
    }

    #[test]
    fn dropped_messages_are_not_billed() {
        let mut n = net(1.0);
        assert!(!n.try_send(100, Direction::Up, 0, 0));
        assert_eq!(n.stats().bytes_up, 0);
        n.end_round();
        assert_eq!(n.stats().sim_comm_seconds, 0.0);
    }

    #[test]
    fn keyed_drops_are_order_independent() {
        // The same (seed, round, worker) keys must give the same per-key
        // outcome whatever order uploads land in — the contract that lets
        // parallel islands share one fabric.
        let keys: Vec<(usize, usize)> =
            (0..16).flat_map(|r| (0..8).map(move |w| (r, w))).collect();
        let mut reversed = keys.clone();
        reversed.reverse();
        let mut shuffled = keys.clone();
        Rng::new(99).shuffle(&mut shuffled);

        let outcomes = |order: &[(usize, usize)]| {
            let mut n = net(0.5);
            let mut out: Vec<((usize, usize), bool)> = order
                .iter()
                .map(|&(r, w)| ((r, w), n.try_send(10, Direction::Up, r, w)))
                .collect();
            out.sort();
            (out, n.stats().dropped)
        };
        let (a, da) = outcomes(&keys);
        let (b, db) = outcomes(&reversed);
        let (c, dc) = outcomes(&shuffled);
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_eq!(da, db);
        assert_eq!(da, dc);
        // And the pure predicate agrees with what try_send did.
        let n = net(0.5);
        for ((r, w), sent) in &a {
            assert_eq!(n.drops(*r, *w), !sent);
        }
        // Sanity: a 50% fabric over 128 keys both drops and delivers.
        assert!(da > 0 && (da as usize) < keys.len());
    }

    #[test]
    fn keyed_drops_vary_across_keys_and_seeds() {
        let n = net(0.5);
        let per_key: Vec<bool> = (0..64).map(|w| n.drops(0, w)).collect();
        assert!(per_key.iter().any(|&d| d) && per_key.iter().any(|&d| !d));
        let other = SimNet::new(1e6, 0.01, 0.5, Rng::new(12345));
        let differs = (0..64).any(|w| n.drops(0, w) != other.drops(0, w));
        assert!(differs, "drop pattern must depend on the fabric seed");
    }
}
